"""``device-sharded``: the batched serving executor across a device mesh.

The batched :class:`~repro.serving.runtime.device.DeviceExecutor` runs one
jitted stage fn per (stage, bucket) shape on a single device.  This module
lifts exactly that engine onto a ``(dp, tp)`` mesh from
:func:`repro.launch.mesh.make_serving_mesh`:

* **Data parallelism** — batch rows are sharded over the ``dp`` axis.  The
  bucket set is scaled to *dp-divisible* global sizes (each base bucket
  ``b`` becomes a global batch of ``b * dp`` rows, ``b`` per device), so
  padded batches always split evenly and steady state still never
  recompiles: the per-device shapes are the same small pre-compiled set.
* **Tensor parallelism** — stage weights are placed with the decode
  (TP-only) layout from :func:`repro.launch.shardings.param_shardings`, so
  a stage's matmuls shard over the ``tp`` axis without per-dispatch weight
  gathers; ``tp=1`` degenerates to full replication.
* **Hidden-state caching** — per-request state keeps the DeviceExecutor
  contract (registered at admission, persisted across stage dispatches,
  evicted on retire) but stays *device-resident*: a committed row is a
  slice of the sharded stage output, never copied back to host between
  stages.  ``cache_stats()`` exposes live/peak/evicted counts.

Everything above the executor contract — :class:`StageBatcher` formation,
admission control, pipelined dispatch, traffic scenarios — runs unchanged;
:func:`sharded_time_model` re-prices the ``BatchTimeModel`` so feasibility
checks and §II-B deadline adjustments see the dp-wide bucket set.

Registered as ``register_executor("device-sharded")`` from
:mod:`repro.launch.serve` — *outside* the serving package, like the
``traffic`` source: the registry extension-point proof at executor scale.

On a single-device host the mesh falls back to 1x1 and every result is
bit-for-bit identical to ``device-batched`` (tests/test_sharded.py pins
this parity), so CI exercises the full sharded path.
"""
from __future__ import annotations

import jax

from repro.launch.shardings import batch_shardings, param_shardings
from repro.serving.batch.batcher import BatchTimeModel
from repro.serving.batch.stage_fns import BatchedStageFns
from repro.serving.runtime.device import DeviceExecutor

#: executor_args keys understood by the ``device-sharded`` factory —
#: the single source of truth ``ServeSpec._validate_sharded_args`` reads
#: to reject anything else (typo guard)
SHARDED_ARGS = ("dp", "tp", "mesh", "require", "collective")


def dp_buckets(buckets, dp: int) -> tuple:
    """Global (dp-divisible) batch buckets for a dp-way row-sharded engine.

    Each base bucket ``b`` holds ``b`` rows *per device*, so the global
    batch the engine forms and prices is ``b * dp`` rows.  ``dp=1`` is the
    identity — the single-device bucket discipline unchanged."""
    if int(dp) < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    return tuple(int(b) * int(dp) for b in sorted(buckets))


def sharded_time_model(tm: BatchTimeModel, dp: int, *,
                       collective: float = 0.0) -> BatchTimeModel:
    """Price dp-way row-sharded dispatches.

    A global batch padded to bucket ``b * dp`` puts ``b`` rows on each
    device, so its WCET is the *single-device* WCET of bucket ``b`` plus a
    per-dispatch ``collective`` term (cross-replica sync / logit gather)
    when ``dp > 1``.  ``dp=1`` returns ``tm`` itself, keeping single-device
    pricing (and golden parity) exactly intact.
    """
    dp = int(dp)
    if dp == 1:
        return tm
    rows = tuple(tuple(float(t) + float(collective) for t in row)
                 for row in tm.times)
    return BatchTimeModel(buckets=dp_buckets(tm.buckets, dp), times=rows)


def _constrain_rows(tree, mesh, dp_axes):
    """Constrain every leaf's leading (batch-row) axis onto the dp axes
    (divisibility-guarded — :func:`batch_shardings` falls back to
    replication for non-dividing leaves, so any pytree lowers)."""
    sh = batch_shardings(mesh, tree, dp_axes)
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, sh)


class ShardedStageFns(BatchedStageFns):
    """``BatchedStageFns`` whose jitted stage fns carry mesh sharding
    constraints: inputs and hidden outputs row-sharded over ``dp``, weight
    layout (tp) inherited from the committed params.

    The bucket set is the dp-divisible global set (:func:`dp_buckets`), so
    ``pad_batch`` always produces row counts that split evenly over the dp
    axis; per-device shapes stay the base pre-compiled buckets."""

    def __init__(self, cfg, buckets, mesh):
        self.mesh = mesh
        self.dp_axis, self.tp_axis = mesh.axis_names
        self.dp = int(mesh.shape[self.dp_axis])
        super().__init__(cfg, dp_buckets(buckets, self.dp))

    def fn(self, stage: int):
        if stage not in self._fns:
            from repro.models import stage_forward
            dp_axes = (self.dp_axis,)

            def f(params, h, _s=stage):
                h = _constrain_rows(h, self.mesh, dp_axes)
                h_out, logits, conf = stage_forward(self.cfg, params, _s, h,
                                                    mode="train")
                h_out = _constrain_rows(h_out, self.mesh, dp_axes)
                return h_out, logits, conf
            self._fns[stage] = jax.jit(f)
        return self._fns[stage]


class ShardedDeviceExecutor(DeviceExecutor):
    """:class:`DeviceExecutor` over a mesh — same contract (async XLA
    dispatch, single in-flight batch, per-request hidden-state cache),
    params committed once with the TP weight layout.

    ``fallback`` records that the requested ``(dp, tp)`` exceeded the
    host's device count and the mesh degenerated to 1x1."""

    def __init__(self, stage_fns, params, time_model, mesh, *,
                 fallback: bool = False):
        params = jax.device_put(params,
                                param_shardings(mesh, params, layout="tp"))
        super().__init__(stage_fns, params, time_model)
        self.mesh = mesh
        self.dp = int(mesh.shape[mesh.axis_names[0]])
        self.tp = int(mesh.shape[mesh.axis_names[1]])
        self.fallback = fallback


def build_sharded_executor(args: dict, ctx):
    """Factory behind ``register_executor("device-sharded")``.

    ``args`` (all JSON-able; validated by ``ServeSpec.validate()``):

    * ``dp`` / ``tp`` — data- / tensor-parallel ways (default 1 / 1).
    * ``mesh`` — optional ``[dp_axis, tp_axis]`` axis names (default
      ``["data", "model"]``); a ready ``jax.sharding.Mesh`` may instead be
      passed as the ``mesh`` *resource*, skipping construction.
    * ``require`` — raise instead of falling back to 1x1 when the host
      lacks ``dp * tp`` devices (default False: CI-friendly fallback).
    * ``collective`` — seconds added to every dispatch's WCET when
      ``dp > 1`` (cross-replica sync pricing; default 0).

    Refines ``ctx.time_model`` to the dp-scaled model so the batcher,
    admission controller and §II-B deadline adjustment all price the
    dp-wide bucket set.  Resources: ``cfg``, ``params``, optional
    ``stage_fns`` / ``mesh``.
    """
    from repro.launch.mesh import make_serving_mesh
    dp, tp = int(args.get("dp", 1)), int(args.get("tp", 1))
    mesh = ctx.resources.get("mesh")
    if mesh is None:
        axes = tuple(args.get("mesh") or ("data", "model"))
        mesh = make_serving_mesh(dp, tp, axes=axes,
                                 require=bool(args.get("require", False)))
    eff_dp = int(mesh.shape[mesh.axis_names[0]])
    eff_tp = int(mesh.shape[mesh.axis_names[1]])
    params = ctx.resources["params"]
    stm = sharded_time_model(
        ctx.time_model, eff_dp, collective=float(args.get("collective", 0.0)))
    sfns = ctx.resources.get("stage_fns")
    if sfns is None:
        sfns = ShardedStageFns(ctx.resources["cfg"], ctx.time_model.buckets,
                               mesh)
    elif tuple(getattr(sfns, "buckets", ())) != stm.buckets:
        # a caller-supplied stage_fns must pad to the dp-scaled global
        # buckets the engine will form — catch the mismatch at build
        # time, not at the first over-bucket dispatch on a warm engine
        raise ValueError(
            f"stage_fns resource buckets "
            f"{tuple(getattr(sfns, 'buckets', ()))} do not match the "
            f"dp-scaled bucket set {stm.buckets} (dp={eff_dp}); build a "
            f"ShardedStageFns for this mesh or omit the resource")
    # everything downstream (StageBatcher, AdmissionController, deadline
    # adjustment, max_batch) prices the dp-wide global buckets
    ctx.time_model = stm
    ex = ShardedDeviceExecutor(sfns, params, stm, mesh,
                               fallback=eff_dp * eff_tp < dp * tp)
    ex.warmup = lambda sample_input: sfns.warmup(ex.params, sample_input)
    return ex
