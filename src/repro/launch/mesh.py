"""Production meshes (assignment-fixed shapes).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run entrypoint (repro.launch.dryrun) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else (smoke tests, benchmarks) sees the real single CPU device.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` where it exists (jax >= 0.6); on older jax
    the ``Mesh`` object itself is the context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def _mesh(shape, axes):
    # jax < 0.6 has no jax.sharding.AxisType (Auto is that era's default);
    # jax < 0.4.35 has no jax.make_mesh at all
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for in-test lowering (8 host devices)."""
    return _mesh((n_data, n_model), ("data", "model"))


def make_serving_mesh(dp: int = 1, tp: int = 1, *,
                      axes=("data", "model"), require: bool = False):
    """A ``(dp, tp)`` serving mesh: data-parallel batch rows over
    ``axes[0]``, tensor-parallel weights within a stage over ``axes[1]``.

    The ``device-sharded`` executor (registered by :mod:`repro.launch.serve`,
    built in :mod:`repro.launch.sharded`) runs its stage fns over this mesh.
    When the host has fewer than ``dp * tp`` devices the mesh **falls back
    to 1x1** so the same ServeSpec runs everywhere (single-device CI
    exercises the full sharded code path as a degenerate mesh); pass
    ``require=True`` to raise instead — a production launcher should fail
    loudly, not silently serve at 1/dp of the provisioned capacity.
    """
    dp, tp = int(dp), int(tp)
    if dp < 1 or tp < 1:
        raise ValueError(f"dp and tp must be >= 1, got dp={dp} tp={tp}")
    n = len(jax.devices())
    if dp * tp > n:
        if require:
            raise ValueError(f"serving mesh needs dp*tp={dp * tp} devices, "
                             f"host has {n}")
        dp = tp = 1
    return _mesh((dp, tp), tuple(axes))


# TPU v5e hardware model (roofline constants, per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (intra-pod)
DCN_BW = 25e9                     # B/s (pod axis)
HBM_BYTES = 16e9                  # v5e HBM capacity
