"""Step functions + ShapeDtypeStruct input specs for every (arch × shape).

input_specs() returns weak-type-correct, shardable stand-ins for every model
input — no device allocation; the dry-run lowers against them.  Modality
frontends are stubs per the assignment carve-out: VLM inputs include
precomputed patch embeddings, audio inputs are EnCodec token streams.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs import InputShape, ModelConfig
from repro.models import decode_step, forward, init_decode_cache, init_params
from repro.models.common import ParallelCtx
from repro.training.loop import make_loss_fn
from repro.training.optimizer import AdamW

SWA_WINDOW = 8192      # ring-buffer window for full-attention archs @500k

SUBQUADRATIC = ("xlstm-1.3b", "jamba-1.5-large-398b", "gemma3-4b")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def uses_swa_variant(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k on a pure full-attention arch -> swa-8192 ring variant."""
    return (shape.name == "long_500k"
            and cfg.name.replace("-reduced", "") not in SUBQUADRATIC)


def model_inputs_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.modality == "features":
        from repro.models.model import FEATURE_DIM
        return {"features": sds((batch, seq, FEATURE_DIM), jnp.float32)}
    if cfg.modality == "vision_stub":
        n_text = max(1, seq - cfg.num_patches)
        return {"tokens": sds((batch, n_text), jnp.int32),
                "patch_embeds": sds((batch, cfg.num_patches, cfg.d_model),
                                    jnp.dtype(cfg.dtype))}
    if cfg.modality == "audio_stub":
        return {"tokens": sds((batch, cfg.num_codebooks, seq), jnp.int32)}
    return {"tokens": sds((batch, seq), jnp.int32)}


def label_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.modality == "features":
        return sds((batch,), jnp.int32)
    if cfg.modality == "audio_stub":
        return sds((batch, cfg.num_codebooks, seq), jnp.int32)
    if cfg.modality == "vision_stub":
        return sds((batch, max(1, seq - cfg.num_patches)), jnp.int32)
    return sds((batch, seq), jnp.int32)


def decode_cache_slots(cfg: ModelConfig, shape: InputShape) -> int:
    return SWA_WINDOW if uses_swa_variant(cfg, shape) else shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for the chosen step kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"inputs": model_inputs_spec(cfg, B, S),
                "labels": label_spec(cfg, B, S)}
    if shape.kind == "prefill":
        return {"inputs": model_inputs_spec(cfg, B, S)}
    # decode: one token against a seq_len cache
    slots = decode_cache_slots(cfg, shape)
    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, B, slots, jnp.dtype(cfg.dtype)))
    tok = (sds((B, cfg.num_codebooks), jnp.int32)
           if cfg.modality == "audio_stub" else sds((B,), jnp.int32))
    return {"cache": cache, "token": tok, "cur_pos": sds((B,), jnp.int32)}


def make_ctx(mesh, shape: InputShape, *, multi_pod: bool,
             moe_impl: str = "gather", remat: bool = True,
             seq_parallel: bool = False) -> ParallelCtx:
    dp = ("pod", "data") if multi_pod else ("data",)
    if shape.kind == "decode":
        if shape.global_batch == 1:        # long_500k: all axes shard the seq
            seq_axes = ("pod", "data", "model") if multi_pod \
                else ("data", "model")
            dp = ()
        else:
            seq_axes = ("model",)
    else:
        seq_axes = ("model",)
    return ParallelCtx(mesh=mesh, dp=dp, tp="model", seq_axes=seq_axes,
                       moe_impl=moe_impl, remat=remat,
                       seq_parallel=seq_parallel)


def pick_microbatches(cfg: ModelConfig, ctx: ParallelCtx, batch: int,
                      seq: int, *, boundary_budget: float = 2 * 2 ** 30):
    """Gradient-accumulation factor: per-device inter-period activation
    boundaries (the part remat cannot remove) must fit `boundary_budget`."""
    from repro.models.model import stage_layouts
    n_bounds = sum(max(1, l.n_scan) + len(l.prefix) + len(l.tail)
                   for l in stage_layouts(cfg))
    dp_size = 1
    for a in ctx.dp:
        dp_size *= ctx.mesh.shape[a]
    n_micro = 1
    while True:
        bm = batch // n_micro
        per_dev = n_bounds * bm * seq * cfg.d_model * 2 / max(1, dp_size)
        if per_dev <= boundary_budget or bm <= max(1, dp_size) \
                or batch % (n_micro * 2) != 0:
            return n_micro
        n_micro *= 2


def make_train_step_fn(cfg: ModelConfig, ctx: ParallelCtx, *,
                       q_chunk: int = 1024, n_micro: int = 1):
    """Full AdamW train step: microbatched gradient accumulation (scanned),
    grad reduction via sharding, AdamW update.

    >300B configs use bf16 moment states and bf16 grad accumulators (fp32
    AdamW for 671B–1T params exceeds pod HBM by arithmetic; bf16 states are
    standard practice at that scale)."""
    from repro.models import count_params_analytic
    big = count_params_analytic(cfg) > 3e11
    acc_dtype = jnp.bfloat16 if big else jnp.float32
    opt = AdamW(learning_rate=1e-4,
                state_dtype="bfloat16" if big else "float32")
    stride = 4 if cfg.vocab_size >= 32768 else 1
    loss_fn = make_loss_fn(cfg, ctx=ctx, q_chunk=q_chunk,
                           aux_exit_stride=stride)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            def micro(acc, mb):
                loss_i, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(acc_dtype), acc, g)
                return acc, loss_i

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            grads, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = losses.mean()
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, updates)
        return params, opt_state, loss

    return train_step, opt


def make_prefill_step_fn(cfg: ModelConfig, ctx: ParallelCtx, *,
                         q_chunk: int = 1024):
    def prefill_step(params, inputs):
        out = forward(cfg, params, inputs, ctx=ctx, mode="prefill",
                      q_chunk=q_chunk, exit_last_only=True)
        # last-position logits of every exit + all layer caches
        last = [lg[:, -1] if lg.ndim >= 3 else lg for lg in out.logits]
        confs = [c[:, -1] if c.ndim == 2 else c for c in out.confidences]
        return last, confs, out.caches

    return prefill_step


def make_serve_step_fn(cfg: ModelConfig, ctx: ParallelCtx):
    def serve_step(params, cache, token, cur_pos):
        out, new_cache = decode_step(cfg, params, cache, token, cur_pos,
                                     ctx=ctx)
        # return (pred, conf) per exit — NOT the (B, V) logits: a vocab-
        # sharded logits output would force a V-sized all-gather per step
        # (§Perf iteration 2)
        preds = [jnp.argmax(lg, axis=-1).astype(jnp.int32)
                 for lg in out.logits]
        return preds, out.confidences, new_cache

    return serve_step


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          sds((2,), jnp.uint32))


def abstract_opt_state(opt: AdamW, params):
    return jax.eval_shape(opt.init, params)
