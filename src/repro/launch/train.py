"""Generic training launcher: any assigned architecture (reduced variant on
CPU; full variant lowers on the production mesh via dryrun.py).

Trains a reduced config of --arch on the synthetic order-2 Markov LM stream
with deep supervision over its exit heads; reports per-exit loss, saves a
checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import (AdamW, checkpoint, lm_token_stream,
                            make_train_step, warmup_cosine)


def make_batch_fn(cfg, batch, seq, seed):
    if cfg.modality == "features":
        raise SystemExit("use examples/train_multiexit.py for the classifier")
    gen = lm_token_stream(min(cfg.vocab_size, 4096), seed=seed)

    def get(step):
        b = gen(batch, seq, step_seed=step)
        toks = b["inputs"]["tokens"]
        labels = b["labels"]
        if cfg.modality == "audio_stub":
            toks = np.repeat(toks[:, None], cfg.num_codebooks, 1)
            labels = np.repeat(labels[:, None], cfg.num_codebooks, 1)
            return {"inputs": {"tokens": toks}, "labels": labels}
        if cfg.modality == "vision_stub":
            patches = np.zeros((batch, cfg.num_patches, cfg.d_model),
                               np.float32)
            return {"inputs": {"tokens": toks, "patch_embeds": patches},
                    "labels": labels}
        return {"inputs": {"tokens": toks}, "labels": labels}

    return get


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--save", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"stages={cfg.stage_boundaries()}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(args.lr, 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    get_batch = make_batch_fn(cfg, args.batch, args.seq, seed=0)

    t0 = time.time()
    for step in range(args.steps):
        batch = get_batch(step)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)")
    if args.save:
        checkpoint.save(args.save, params, {"arch": cfg.name,
                                            "steps": args.steps})
        print("saved", args.save)


if __name__ == "__main__":
    main()
