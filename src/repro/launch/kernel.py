"""``device-kernel``: Pallas-kernel-backed stage fns behind the runtime core.

The ``device-batched`` executor runs one jitted ``stage_forward`` per
(stage, bucket) shape — trunk, exit head, full logits tensor, softmax
confidence, every intermediate materialized.  This module swaps the stage
*bodies* for the repo's Pallas kernels while keeping every layer above the
executor contract unchanged:

* **Fused exit epilogue** — each stage runs
  :func:`repro.models.stage_trunk` and then
  :func:`repro.models.exits.exit_stats_fused` (the
  ``repro.kernels.exit_confidence`` online-softmax kernel): RMSNorm →
  vocab matmul → (max, normalizer, argmax) in ONE dispatch.  The stage
  returns ``(h, pred, conf)`` — the vocab-sized logits row never leaves
  the kernel and confidence never round-trips to host between stages.
  With a single vocab block the online pass folds exactly once, so in
  interpret mode ``conf``/``pred`` are bit-for-bit equal to the unfused
  reference (:func:`repro.models.exits.exit_stats_unfused`).
* **Ragged decode batching** — ``mode="decode"`` dispatches
  :func:`repro.models.stage_decode_step` with
  ``ParallelCtx(decode_attn="kernel")``: attention reads each request's
  KV rows through ``repro.kernels.decode_attention``, whose *per-row*
  ``slot_pos`` masking makes co-batched requests at different positions
  exact (the legacy jnp route shares row 0's slot map across the batch).
  Per-request caches live in the executor's hidden-state cache, sliced
  out of the batched step on commit (:func:`repro.models.
  slice_decode_cache`) and concatenated back in on dispatch.
* **Length buckets** — ragged sequence lengths are padded up to a small
  pre-compiled set (``len_buckets``); the refined
  :class:`~repro.serving.batch.time_model.LengthBucketTimeModel` prices
  ``(stage, batch-bucket, len-bucket)`` WCETs, so the
  :class:`~repro.serving.batch.batcher.StageBatcher` co-batches only
  same-length-bucket runners and admission/§II-B see length-exact costs.
  In decode mode a request's KV slot count IS its length bucket — every
  member of a batch shares it, so cache concat is shape-stable.
* **Deep pipeline** — ``pipeline_depth - 1`` device windows may be
  enqueued at once (``max_inflight`` on the executor); the core stacks
  further windows while the device works, so the device never drains
  between windows waiting for host-side batch formation.

Registered as ``register_executor("device-kernel")`` from
:mod:`repro.launch.serve` — outside the serving package, like
``device-sharded``: the registry extension point at executor scale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ParallelCtx, concat_decode_caches, exit_rows,
                          exit_stats_fused, slice_decode_cache,
                          stage_decode_step, stage_trunk)
from repro.serving.batch.batcher import BatchTimeModel, bucket_for
from repro.serving.batch.stage_fns import BatchedStageFns, pad_batch
from repro.serving.batch.time_model import LengthBucketTimeModel
from repro.serving.runtime.device import DeviceExecutor

#: executor_args keys understood by the ``device-kernel`` factory — the
#: single source of truth ``ServeSpec._validate_kernel_args`` reads to
#: reject anything else (typo guard)
KERNEL_ARGS = ("mode", "interpret", "block_rows", "block_v", "len_buckets",
               "len_marginal")


def length_bucketed_time_model(tm: BatchTimeModel, len_buckets, *,
                               len_marginal: float = 0.25) \
        -> LengthBucketTimeModel:
    """Refine a 2-D ``BatchTimeModel`` with a length-bucket axis.

    The existing ``(stage, bucket)`` table is taken as the *largest*
    length bucket's cost; shorter buckets scale down linearly with a
    ``len_marginal`` floor (cost = base * (lm + (1 - lm) * lb/max_lb)) —
    the analytic analog of :meth:`LengthBucketTimeModel.linear` applied
    to an already-priced model.  Base ``times`` stay exactly ``tm.times``
    (the max over length buckets), so every length-blind consumer prices
    identically before and after refinement.
    """
    if isinstance(tm, LengthBucketTimeModel):
        return tm
    lbs = tuple(sorted(int(b) for b in len_buckets))
    lm = float(len_marginal)
    mats = []
    for lb in lbs:
        frac = lm + (1.0 - lm) * lb / lbs[-1]
        mats.append(tuple(tuple(float(t) * frac for t in row)
                          for row in tm.times))
    return LengthBucketTimeModel(buckets=tm.buckets, times=tm.times,
                                 len_buckets=lbs, times3=tuple(mats))


class KernelStageFns(BatchedStageFns):
    """``BatchedStageFns`` whose jitted stage bodies end in the fused exit
    kernel: ``stage_trunk`` → :func:`exit_stats_fused`, returning
    ``(h, pred, conf)`` with no logits tensor.

    The exit head must be a 2-D shared projection (text/vlm/features);
    the audio codebook head has no fused kernel.
    """

    def __init__(self, cfg, buckets, *, interpret: bool = True,
                 block_rows: int = 8, block_v: int = 512):
        if cfg.modality == "audio_stub":
            raise ValueError("device-kernel: the audio codebook exit head "
                             "has no fused kernel; use device-batched")
        super().__init__(cfg, buckets)
        self.interpret = bool(interpret)
        self.block_rows = int(block_rows)
        self.block_v = int(block_v)

    def fn(self, stage: int):
        if stage not in self._fns:
            def f(params, h, _s=stage):
                h_out = stage_trunk(self.cfg, params, _s, h, mode="train")
                rows = exit_rows(self.cfg, h_out)
                conf, pred, _m, _lse = exit_stats_fused(
                    rows, params["exits"][_s]["ln"],
                    params["exit_shared"]["w_out"],
                    eps=self.cfg.norm_eps, interpret=self.interpret,
                    block_rows=self.block_rows, block_v=self.block_v)
                return h_out, pred, conf
            self._fns[stage] = jax.jit(f)
        return self._fns[stage]

    def run(self, stage: int, params, pytrees):
        """Pad, dispatch one fused stage, return (h, pred, conf, mask)."""
        h, mask = pad_batch(pytrees, bucket_for(len(pytrees), self.buckets),
                            staging=self.staging)
        h_out, pred, conf = self.fn(stage)(params, h)
        return h_out, pred, conf, mask


class KernelDecodeStageFns:
    """Per-stage jitted :func:`stage_decode_step` + fused exit epilogue,
    with attention routed through the Pallas decode kernel.

    ``fn(stage)(params, h, st_cache, cur_pos)`` runs one batched stage of
    a decode step over the stage's (batched) cache and returns
    ``(h, new_st_cache, pred, conf)``.  Shapes are keyed by jit tracing:
    each ``(batch bucket, KV slot count)`` pair compiles once (a request's
    slot count is its length bucket, so the shape set is the pre-compiled
    ``buckets x len_buckets`` grid); :meth:`warmup` pre-compiles the
    sample's slot count across stages and batch buckets.
    """

    def __init__(self, cfg, buckets, ctx: ParallelCtx, *,
                 interpret: bool = True, block_rows: int = 8,
                 block_v: int = 512):
        if cfg.modality == "audio_stub":
            raise ValueError("device-kernel: the audio codebook exit head "
                             "has no fused kernel; use device-batched")
        self.cfg = cfg
        self.buckets = tuple(sorted(buckets))
        self.ctx = ctx
        self.interpret = bool(interpret)
        self.block_rows = int(block_rows)
        self.block_v = int(block_v)
        self._fns = {}

    def fn(self, stage: int):
        if stage not in self._fns:
            def f(params, h, st_cache, cur_pos, _s=stage):
                h, new_cache = stage_decode_step(self.cfg, params, _s,
                                                 st_cache, h, cur_pos,
                                                 ctx=self.ctx)
                conf, pred, _m, _lse = exit_stats_fused(
                    h, params["exits"][_s]["ln"],
                    params["exit_shared"]["w_out"],
                    eps=self.cfg.norm_eps, interpret=self.interpret,
                    block_rows=self.block_rows, block_v=self.block_v)
                return h, new_cache, pred, conf
            self._fns[stage] = jax.jit(f)
        return self._fns[stage]

    def warmup(self, params, sample_state):
        """Compile every (stage, bucket) shape at the sample's slot count
        before the clock starts; other length buckets compile on their
        first dispatch (pre-warm with one sample per length bucket to
        avoid that)."""
        for b in self.buckets:
            h = jnp.concatenate([sample_state["h"]] * b, axis=0)
            cur = jnp.concatenate([sample_state["cur_pos"]] * b, axis=0)
            for s in range(self.cfg.num_stages):
                cache = concat_decode_caches([sample_state["cache"][s]] * b)
                out = self.fn(s)(params, h, cache, cur)
                jax.block_until_ready(out[0])
                h = out[0]


class KernelDeviceExecutor(DeviceExecutor):
    """:class:`DeviceExecutor` over kernel-backed stage fns.

    ``mode="classifier"`` keeps the inherited dispatch (per-request hidden
    pytrees through :class:`KernelStageFns`) and only re-reads ``commit``
    for the fused payload — ``pred`` arrives as an argmax vector, not a
    logits tensor.  ``mode="decode"`` dispatches
    :class:`KernelDecodeStageFns` over per-request decode state
    ``{"h": token/hidden row, "cache": per-stage cache list, "cur_pos"}``
    held in the hidden-state cache: dispatch concatenates the stage's
    cache rows across the batch (padding replicates the last member, whose
    slot count every co-runner shares — same length bucket), commit slices
    each request's row and cache back out, device-resident throughout.
    """

    def __init__(self, stage_fns, params, time_model, *,
                 mode: str = "classifier", max_inflight: int = 1):
        super().__init__(stage_fns, params, time_model,
                         max_inflight=max_inflight)
        self.mode = mode

    def wcet(self, stage: int, n: int = 1) -> float:
        return self.time_model.wcet(stage, n)

    # -- dispatch seams -------------------------------------------------
    def _dispatch_stage(self, stage: int, tasks: list):
        if self.mode != "decode":
            return super()._dispatch_stage(stage, tasks)
        states = [self.states[t.tid][1] for t in tasks]
        b = bucket_for(len(states), self.stage_fns.buckets)
        padded = states + [states[-1]] * (b - len(states))
        h = jnp.concatenate([s["h"] for s in padded], axis=0)
        cache = concat_decode_caches([s["cache"][stage] for s in padded])
        cur = jnp.concatenate([s["cur_pos"] for s in padded], axis=0)
        return self.stage_fns.fn(stage)(self.params, h, cache, cur)

    def _finalize(self, payload):
        if self.mode != "decode":
            h_out, pred, conf = payload
            return h_out, np.asarray(pred), np.asarray(conf)
        h_out, new_cache, pred, conf = payload
        return h_out, new_cache, np.asarray(pred), np.asarray(conf)

    def commit(self, task, k: int) -> float:
        stage, done = self._done
        w0 = time.perf_counter()
        st = self.states[task.tid]
        if self.mode != "decode":
            h_out, pred, conf = done
            st[1] = jax.tree.map(lambda x: x[k:k + 1], h_out)
        else:
            h_out, new_cache, pred, conf = done
            st[1]["h"] = h_out[k:k + 1]
            st[1]["cache"][stage] = slice_decode_cache(new_cache, k)
        c = float(conf[k])
        st[2] = (int(pred[k]), c)
        self.stage_host_time[stage] += time.perf_counter() - w0
        return c


def build_kernel_executor(args: dict, ctx):
    """Factory behind ``register_executor("device-kernel")``.

    ``args`` (all JSON-able; validated by ``ServeSpec.validate()``):

    * ``mode`` — ``"classifier"`` (default: fused-exit ``stage_trunk``
      over hidden pytrees) or ``"decode"`` (ragged decode batching over
      per-request KV caches through the Pallas decode kernel).
    * ``interpret`` — run the Pallas kernels in interpret mode (default
      True: bit-exact on CPU CI; set False on a real TPU backend).
    * ``block_rows`` / ``block_v`` — fused exit kernel tile sizes.
    * ``len_buckets`` — optional ascending lengths; refines
      ``ctx.time_model`` via :func:`length_bucketed_time_model` so the
      batcher/admission/§II-B price ``(stage, batch-bucket, len-bucket)``.
    * ``len_marginal`` — length-scaling floor of that refinement.

    ``max_inflight`` is ``spec.pipeline_depth - 1``: the depth-minus-one
    windows the core may stack on the device.  Resources: ``cfg``,
    ``params``, optional ``stage_fns`` / ``mesh``.
    """
    cfg, params = ctx.resources["cfg"], ctx.resources["params"]
    mode = args.get("mode", "classifier")
    interpret = bool(args.get("interpret", True))
    kw = dict(interpret=interpret, block_rows=int(args.get("block_rows", 8)),
              block_v=int(args.get("block_v", 512)))
    lbs = args.get("len_buckets")
    if lbs:
        # everything downstream (StageBatcher, admission, §II-B) prices
        # the (stage, batch-bucket, len-bucket) table
        ctx.time_model = length_bucketed_time_model(
            ctx.time_model, lbs,
            len_marginal=float(args.get("len_marginal", 0.25)))
    tm = ctx.time_model
    max_inflight = max(1, int(ctx.spec.pipeline_depth) - 1)
    sfns = ctx.resources.get("stage_fns")
    if mode == "decode":
        if sfns is None:
            from repro.launch.mesh import make_serving_mesh
            mesh = ctx.resources.get("mesh") or make_serving_mesh(1, 1)
            pctx = ParallelCtx(mesh=mesh, decode_attn="kernel")
            sfns = KernelDecodeStageFns(cfg, tm.buckets, pctx, **kw)
        ex = KernelDeviceExecutor(sfns, params, tm, mode="decode",
                                  max_inflight=max_inflight)
        ex.warmup = lambda sample_state: sfns.warmup(params, sample_state)
    else:
        if sfns is None:
            sfns = KernelStageFns(cfg, tm.buckets, **kw)
        ex = KernelDeviceExecutor(sfns, params, tm,
                                  max_inflight=max_inflight)
        ex.warmup = lambda sample_input: sfns.warmup(params, sample_input)
    return ex
