import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 host placeholder devices.  Do not
import this module from tests; run it as a subprocess:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh single --out artifacts/dryrun

For every combination it jits the appropriate step (train_step for train_4k,
prefill_step for prefill_32k, serve_step for decode shapes) with explicit
in/out shardings, runs .lower().compile(), and records memory_analysis() +
cost_analysis() + the optimized-HLO collective byte census to a JSON
artifact consumed by benchmarks/roofline.
"""

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _sharding_tree(avals, shardings):
    return jax.tree.map(lambda s: s, shardings)


def run_combo(arch: str, shape_name: str, multi_pod: bool, *,
              moe_impl: str = "gather", attn_impl: str = "grouped",
              seq_parallel: bool = False, collect_hlo: bool = True,
              probes: bool = True, q_chunk: int = 1024):
    from repro.configs import get_config, get_shape
    from repro.launch import steps as S
    from repro.launch.mesh import make_production_mesh, set_mesh
    from repro.launch.shardings import (batch_shardings, cache_shardings,
                                        decode_weight_layout,
                                        expert_templates_for, opt_shardings,
                                        param_shardings)
    from repro.roofline.collectives import collective_bytes_from_hlo

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = S.make_ctx(mesh, shape, multi_pod=multi_pod, moe_impl=moe_impl,
                     seq_parallel=seq_parallel)
    if attn_impl != "grouped":
        import dataclasses as _dc
        ctx = _dc.replace(ctx, attn_impl=attn_impl)
    rec = {"arch": arch, "shape": shape_name, "attn_impl": attn_impl,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "moe_impl": moe_impl, "kind": shape.kind,
           "swa_variant": S.uses_swa_variant(cfg, shape)}
    t0 = time.time()

    params = S.abstract_params(cfg)
    etpl = expert_templates_for(cfg, mesh, ctx.dp, moe_impl)
    layout = decode_weight_layout(cfg, mesh) if shape.kind == "decode" \
        else "2d"
    rec["weight_layout"] = layout
    p_sh = param_shardings(mesh, params, etpl, layout=layout)
    specs = S.input_specs(cfg, shape)

    with set_mesh(mesh):
        if shape.kind == "train":
            n_micro = S.pick_microbatches(cfg, ctx, shape.global_batch,
                                          shape.seq_len)
            rec["n_micro"] = n_micro
            step, opt = S.make_train_step_fn(cfg, ctx, q_chunk=q_chunk,
                                             n_micro=n_micro)
            opt_state = S.abstract_opt_state(opt, params)
            o_sh = opt_shardings(mesh, opt_state, etpl)
            b_sh = {"inputs": batch_shardings(mesh, specs["inputs"], ctx.dp),
                    "labels": batch_shardings(mesh, {"l": specs["labels"]},
                                              ctx.dp)["l"]}
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt_state,
                               {"inputs": specs["inputs"],
                                "labels": specs["labels"]})
        elif shape.kind == "prefill":
            step = S.make_prefill_step_fn(cfg, ctx, q_chunk=q_chunk)
            b_sh = batch_shardings(mesh, specs["inputs"], ctx.dp)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(params, specs["inputs"])
        else:
            step = S.make_serve_step_fn(cfg, ctx)
            c_sh = cache_shardings(mesh, specs["cache"], ctx.dp,
                                   ctx.seq_axes)
            bdp = tuple(a for a in ctx.dp if a not in ctx.seq_axes) or None
            tok_sh = NamedSharding(mesh, P(bdp, *([None] * (specs["token"].ndim - 1))))
            pos_sh = NamedSharding(mesh, P(bdp))
            fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params, specs["cache"], specs["token"],
                               specs["cur_pos"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):     # jax < 0.6: one dict per program
            ca = ca[0] if ca else {}
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed")}
        if collect_hlo:
            txt = compiled.as_text()
            rec["collectives_fullhlo"] = collective_bytes_from_hlo(txt)
    if probes:
        from repro.roofline.probes import probe_combo
        rec["probe"] = probe_combo(cfg, shape, mesh, ctx, q_chunk=q_chunk)
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--moe-impl", default="gather",
                    choices=["gather", "alltoall"])
    ap.add_argument("--attn-impl", default="grouped",
                    choices=["grouped", "flat"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    from repro.configs import SHAPES, all_arch_ids
    archs = list(all_arch_ids()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.moe_impl != "gather":
                    name += f"__{args.moe_impl}"
                if args.tag:
                    name += f"__{args.tag}"
                path = os.path.join(args.out, name + ".json")
                print(f"=== {name}", flush=True)
                try:
                    rec = run_combo(arch, shape, mp, moe_impl=args.moe_impl,
                                    attn_impl=args.attn_impl,
                                    seq_parallel=args.seq_parallel,
                                    probes=not args.no_probes,
                                    q_chunk=args.q_chunk)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    mem = rec["memory"]
                    per_dev = (mem["argument_bytes"] + mem["temp_bytes"] +
                               mem["output_bytes"]) / 512e9 if mp else \
                        (mem["argument_bytes"] + mem["temp_bytes"] +
                         mem["output_bytes"]) / 256e9
                    print(f"    ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"args={mem['argument_bytes']/2**30:.1f}GiB "
                          f"temp={mem['temp_bytes']/2**30:.1f}GiB "
                          f"flops={rec['cost'].get('flops', 0):.3e}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"    FAIL {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
    print(f"done, {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
