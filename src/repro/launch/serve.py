"""Anytime-decoding serving launcher: imprecise computation per TOKEN.

The paper's stage shedding applied to autoregressive decode: each token runs
stage 1 (mandatory); deeper stages execute only while the exit confidence is
below a target — a deadline-free confidence-driven variant of RTDeepIoT's
depth assignment (with --deadline-ms the FPTAS scheduler governs depth across
the batch exactly as in serving).

``--pipeline`` applies the serving runtime's async-dispatch idea at token
granularity: the next-deeper decode step is dispatched (XLA async) *before*
blocking on the current depth's confidence readback, so the host's
read-and-decide overlaps device compute; a speculatively dispatched depth
is simply discarded when the confidence target was already met.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_decode_cache, init_params
from repro.training import checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--conf-target", type=float, default=0.7)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--pipeline", action="store_true",
                    help="speculatively dispatch the next-deeper step "
                         "before reading the current confidence (async "
                         "host/device overlap)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    if cfg.modality == "features":
        raise SystemExit("classifier serving lives in examples/serve_anytime.py")
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        params, _ = checkpoint.load(args.ckpt, params)
    B = args.batch
    n_stages = len(cfg.stage_boundaries())
    cache = init_decode_cache(cfg, B, slots=args.tokens + 1)

    # jit one step per depth (the per-stage dispatch units of the engine)
    steps = [jax.jit(lambda p, c, t, pos, _d=d: decode_step(
        cfg, p, c, t, pos, upto_stage=_d)) for d in range(1, n_stages + 1)]

    tok = (jnp.zeros((B, cfg.num_codebooks), jnp.int32)
           if cfg.modality == "audio_stub" else jnp.zeros((B,), jnp.int32))
    depth_hist = np.zeros(n_stages, np.int64)
    speculated = 0
    t0 = time.time()
    for t in range(args.tokens):
        pos = jnp.full((B,), t, jnp.int32)
        if args.pipeline:
            # async deepening: dispatch depth d+1 (XLA returns immediately)
            # BEFORE blocking on depth d's confidence readback, so the
            # host's read-and-decide hides behind device compute; the
            # speculative step is discarded when the target was already met
            outs = [steps[0](params, cache, tok, pos)]
            for d in range(1, n_stages + 1):
                if d < n_stages:
                    outs.append(steps[d](params, cache, tok, pos))
                conf = float(jnp.mean(outs[d - 1][0].confidences[-1]))
                if conf >= args.conf_target or d == n_stages:
                    out, new_cache = outs[d - 1]
                    speculated += int(d < n_stages)
                    break
        else:
            # anytime decode: run deeper only while mean confidence < target
            for d in range(1, n_stages + 1):
                out, new_cache = steps[d - 1](params, cache, tok, pos)
                conf = float(jnp.mean(out.confidences[-1]))
                if conf >= args.conf_target or d == n_stages:
                    break
        depth_hist[d - 1] += 1
        cache = new_cache
        nxt = jnp.argmax(out.logits[-1], -1).astype(jnp.int32)
        tok = nxt if cfg.modality != "audio_stub" else \
            jnp.broadcast_to(nxt[..., :1] if nxt.ndim > 1 else nxt[:, None],
                             (B, cfg.num_codebooks))
        print(f"token {t:3d}: depth={d} conf={conf:.3f}")
    dt = time.time() - t0
    if args.pipeline:
        print(f"pipelined decode: {speculated} speculative deeper steps "
              f"dispatched and discarded")
    print(f"\n{args.tokens} tokens in {dt:.1f}s; depth histogram "
          f"{depth_hist.tolist()} (mean {np.average(np.arange(1, n_stages+1), weights=depth_hist):.2f} "
          f"of {n_stages}) — stages shed: "
          f"{1 - depth_hist @ np.arange(1, n_stages+1) / (args.tokens * n_stages):.0%} compute saved")


if __name__ == "__main__":
    main()
