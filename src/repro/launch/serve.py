"""Anytime-decoding serving launcher: imprecise computation per TOKEN.

The paper's stage shedding applied to autoregressive decode: each token
runs stage 1 (mandatory); deeper stages execute only while the exit
confidence is below a target — a deadline-free confidence-driven variant
of RTDeepIoT's depth assignment.

The decode loop runs through the public serving API: each *token* is one
imprecise-computation request served by ``repro.serving.Service`` from a
declarative ``ServeSpec``, with four launch-registered components proving
the registry's extension points (no core module touched):

* policy ``conf-target`` — assign full depth, stop deepening the moment
  the measured exit confidence reaches the target;
* executor ``decode`` — jitted per-depth decode steps; with
  ``speculate=True`` (``--pipeline``) the next-deeper step is dispatched
  (XLA async) before the current depth's confidence readback, so the
  host's read-and-decide overlaps device compute — a speculatively
  dispatched depth is discarded when the target was already met;
* source ``token-loop`` — a closed loop of one token at a time: retiring
  token *t* commits the chosen depth's cache, samples token *t+1* and
  issues it as the next request;
* executor ``device-sharded`` (:mod:`repro.launch.sharded`) — the batched
  classifier engine with its stage fns sharded over a ``(dp, tp)`` mesh
  from :func:`repro.launch.mesh.make_serving_mesh`; falls back to a 1x1
  mesh on single-device hosts so the same ServeSpec runs everywhere;
* executor ``device-kernel`` (:mod:`repro.launch.kernel`) — Pallas-backed
  stage fns: fused exit-confidence epilogue (no logits round-trip) and
  ragged decode batching over per-request KV caches through the decode
  kernel, with ``(stage, batch-bucket, len-bucket)`` WCET pricing.

``--dry-run`` validates the spec against the registry and prints it as
JSON without touching the model (the CI examples-smoke job).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 24
"""
from __future__ import annotations

import argparse
import math
import time

from repro.serving.registry import (register_executor, register_policy,
                                    register_source)
from repro.serving.service import ServeSpec, Service


# ---------------------------------------------------------------------------
# launch-registered serving components (registry extension points in action)
# ---------------------------------------------------------------------------

@register_policy("conf-target")
def _make_conf_target(args, ctx):
    """Deadline-free depth governor: run deeper only while the measured
    exit confidence is below ``target`` (BatchPolicy imported lazily so
    the registration itself stays import-light)."""
    from repro.serving.batch.policy import BatchPolicy

    class _ConfTarget(BatchPolicy):
        name = "conf-target"

        def __init__(self, target):
            super().__init__()
            self.target = target

        def on_arrival(self, active, task, now):
            task.assigned_depth = task.clamp_depth(task.num_stages)

        def on_stage_done(self, active, task, now):
            c = task.last_confidence
            if c is not None and c >= self.target:
                task.assigned_depth = task.executed      # stop deepening

        def next_batch(self, active, now):
            r = self._runnable(active, now)
            if not r:
                return None
            t = min(r, key=lambda x: x.tid)
            return t.executed, [t]

    return _ConfTarget(float(args.get("target", 0.7)))


class DecodeExecutor:
    """Jitted per-depth decode steps behind the runtime Executor contract.

    Depth *d*'s "stage" recomputes the token at depth d+1 from the current
    cache (exactly the bespoke loop this launcher used to hand-roll).
    With ``speculate`` the next-deeper step is dispatched asynchronously
    before the current depth's confidence readback blocks.
    """

    def __init__(self, steps, params, cache, tok, *, speculate=False):
        # jax only enters the process on the non-dry-run path, which has
        # already imported it to jit `steps` — bind it once here instead of
        # re-importing in the per-token hot methods
        import jax
        import jax.numpy as jnp
        self._jax, self._jnp = jax, jnp
        self.steps = steps
        self.params = params
        self.cache = cache
        self.tok = tok
        self.speculate = speculate
        self.total_busy = 0.0
        self.speculated = 0          # deeper steps dispatched speculatively
        self.spec_hits = 0           # ... that the schedule then consumed
        self._running = None
        self._spec = None            # (token, stage, out, new_cache)
        self._done = None
        self.chosen = None           # (out, new_cache) of the last commit

    # -- Executor contract ---------------------------------------------
    @property
    def busy(self):
        return self._running is not None

    def wcet(self, stage, n):
        return 0.0

    def submit(self, stage, tasks, now):
        jnp = self._jnp
        task = tasks[0]
        pos = jnp.full((self.tok.shape[0],), task.sample, jnp.int32)
        if self._spec is not None and self._spec[:2] == (task.sample, stage):
            out, new_cache = self._spec[2:]
            self.spec_hits += 1
        else:
            out, new_cache = self.steps[stage](self.params, self.cache,
                                               self.tok, pos)
        self._spec = None
        if self.speculate and stage + 1 < len(self.steps):
            o2, c2 = self.steps[stage + 1](self.params, self.cache, self.tok,
                                           pos)
            self._spec = (task.sample, stage + 1, o2, c2)
            self.speculated += 1
        self._running = (stage, tasks, out, new_cache, now)

    def finish_time(self):
        return None if self.busy else math.inf

    def complete(self, clock):
        stage, tasks, out, new_cache, t0 = self._running
        self._running = None
        self._jax.block_until_ready(out.logits[-1])
        self.total_busy += clock.now() - t0
        self._done = (out, new_cache)
        return stage, tasks

    def commit(self, task, k):
        self.chosen = self._done
        return float(self._jnp.mean(self._done[0].confidences[-1]))

    def running_tasks(self):
        return list(self._running[1]) if self._running is not None else []


@register_executor("decode")
def _make_decode(args, ctx):
    r = ctx.resources
    return DecodeExecutor(r["steps"], r["params"], r["cache"], r["tok"],
                          speculate=bool(args.get("speculate", False)))


@register_executor("device-sharded")
def _make_device_sharded(args, ctx):
    """``device-batched`` across a ``(dp, tp)`` mesh: batch rows sharded
    over ``dp``, stage weights over ``tp``, per-request hidden state cached
    on device between stage dispatches.  args:
    ``{"dp": ..., "tp": ..., "mesh": [dp_axis, tp_axis], "require": ...,
    "collective": ...}`` (see :func:`repro.launch.sharded.
    build_sharded_executor`); resources: ``cfg``, ``params``, optionally
    ``stage_fns`` / ``mesh``."""
    from repro.launch.sharded import build_sharded_executor
    return build_sharded_executor(args, ctx)


@register_executor("zoo-device")
def _make_zoo_device(args, ctx):
    """Multi-model ``device-batched``: one accelerator, per-model batched
    stage fns, windows routed on the batch's model id (the
    :class:`repro.serving.zoo.device.ZooDeviceExecutor`).  resources:
    ``zoo_models`` = ``{model: {"cfg": ..., "params": ...,
    "stage_fns": optional}}``; spec: ``ServeSpec.models``."""
    from repro.serving.zoo.device import build_zoo_device_executor
    return build_zoo_device_executor(args, ctx)


@register_executor("device-kernel")
def _make_device_kernel(args, ctx):
    """``device-batched`` with Pallas-kernel stage bodies: fused
    exit-confidence epilogue (``mode="classifier"``) or ragged decode
    batching over the per-request KV caches (``mode="decode"``), with
    optional ``(stage, batch-bucket, len-bucket)`` WCET refinement.  args:
    ``{"mode": ..., "interpret": ..., "block_rows": ..., "block_v": ...,
    "len_buckets": [...], "len_marginal": ...}`` (see :func:`repro.launch.
    kernel.build_kernel_executor`); resources: ``cfg``, ``params``,
    optionally ``stage_fns`` / ``mesh``."""
    from repro.launch.kernel import build_kernel_executor
    return build_kernel_executor(args, ctx)


class TokenLoopSource:
    """Closed loop of one token request at a time: retiring token *t*
    commits the chosen depth's cache, lets the ``advance`` callback sample
    token *t+1*, and issues it as the next request."""

    def __init__(self, n_tokens, n_stages, executor, advance):
        self.n_tokens = n_tokens
        self.n_stages = n_stages
        self.executor = executor
        self.advance = advance
        self._next = 0
        self._ready = n_tokens > 0
        self._issue_time = 0.0

    def has_pending(self):
        return self._ready

    def next_time(self):
        return self._issue_time if self._ready else math.inf

    def pop(self, now):
        from repro.core.task import Task
        self._ready = False
        return Task(arrival=now, deadline=math.inf,
                    stage_times=(0.0,) * self.n_stages, mandatory=1,
                    sample=self._next)

    def on_retire(self, task, now):
        out, new_cache = self.executor.chosen
        self.executor.cache = new_cache
        self.executor.tok = self.advance(task, out)
        self._next += 1
        if self._next < self.n_tokens:
            self._ready = True
            self._issue_time = now


@register_source("token-loop")
def _make_token_loop(args, ctx):
    return TokenLoopSource(int(args["n_tokens"]), int(args["n_stages"]),
                           ctx.executor, ctx.resources["advance"])


# ---------------------------------------------------------------------------
# launcher
# ---------------------------------------------------------------------------

def build_spec(args, n_stages: int) -> ServeSpec:
    """The launcher's serving configuration, declared once."""
    return ServeSpec(
        policy="conf-target", policy_args={"target": args.conf_target},
        executor="decode", executor_args={"speculate": bool(args.pipeline)},
        clock="wall", source="token-loop",
        source_args={"n_tokens": args.tokens, "n_stages": n_stages},
        batching={"mode": "none", "stage_times": [0.0] * n_stages})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--conf-target", type=float, default=0.7)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--pipeline", action="store_true",
                    help="speculatively dispatch the next-deeper step "
                         "before reading the current confidence (async "
                         "host/device overlap)")
    ap.add_argument("--dry-run", action="store_true",
                    help="validate + print the ServeSpec (registry check) "
                         "without touching the model")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    cfg = get_config(args.arch).reduced()
    if cfg.modality == "features":
        raise SystemExit("classifier serving lives in examples/serve_anytime.py")
    n_stages = len(cfg.stage_boundaries())
    spec = build_spec(args, n_stages)
    if args.dry_run:
        spec.validate()
        print(spec.to_json(indent=1))
        print(f"DRY RUN OK: {args.arch} ({n_stages} stages, "
              f"{args.tokens} tokens) resolves through the registry")
        return spec

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import decode_step, init_decode_cache, init_params
    from repro.training import checkpoint

    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        params, _ = checkpoint.load(args.ckpt, params)
    B = args.batch
    cache = init_decode_cache(cfg, B, slots=args.tokens + 1)

    # jit one step per depth (the per-stage dispatch units of the engine)
    steps = [jax.jit(lambda p, c, t, pos, _d=d: decode_step(
        cfg, p, c, t, pos, upto_stage=_d)) for d in range(1, n_stages + 1)]

    tok = (jnp.zeros((B, cfg.num_codebooks), jnp.int32)
           if cfg.modality == "audio_stub" else jnp.zeros((B,), jnp.int32))
    depth_hist = np.zeros(n_stages, np.int64)

    def advance(task, out):
        """Token transition: record depth, print, sample the next token."""
        d = task.executed
        depth_hist[d - 1] += 1
        print(f"token {task.sample:3d}: depth={d} "
              f"conf={task.last_confidence:.3f}")
        nxt = jnp.argmax(out.logits[-1], -1).astype(jnp.int32)
        if cfg.modality != "audio_stub":
            return nxt
        return jnp.broadcast_to(nxt[..., :1] if nxt.ndim > 1 else nxt[:, None],
                                (B, cfg.num_codebooks))

    svc = Service.from_spec(spec, steps=steps, params=params, cache=cache,
                            tok=tok, advance=advance)
    t0 = time.time()
    met = svc.run()
    dt = time.time() - t0
    svc.close()
    ex = svc.executor
    if args.pipeline:
        print(f"pipelined decode: {ex.speculated - ex.spec_hits} speculative "
              f"deeper steps dispatched and discarded "
              f"({ex.spec_hits} consumed)")
    print(f"\n{args.tokens} tokens in {dt:.1f}s; depth histogram "
          f"{depth_hist.tolist()} (mean {met.mean_depth:.2f} "
          f"of {n_stages}) — stages shed: "
          f"{1 - depth_hist @ np.arange(1, n_stages+1) / (args.tokens * n_stages):.0%} compute saved")
    return met


if __name__ == "__main__":
    main()
