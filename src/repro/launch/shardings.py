"""Sharding rules: param/opt/batch/cache pytrees -> NamedSharding.

2-D weight sharding (Megatron TP x FSDP-style DP), required to fit the
123B–1T configs on 256/512 x 16GB chips:

  column-parallel weights (output dim on TP):   (d_in, d_out) -> (DP, TP)
  row-parallel weights (input dim on TP):       (d_in, d_out) -> (TP, DP)
  experts:  E over (DP+TP) when divisible (256-way expert parallelism),
            else E over TP and d_ff_expert over DP
  embeddings / exit projections: vocab dim over as many axes as divide it

DP is ('data',) single-pod or ('pod','data') multi-pod; TP is 'model'.
Every axis assignment is divisibility-checked with graceful fallback to a
subset of axes (or replication) so any architecture lowers.  Optimizer state
mirrors parameters (ZeRO comes for free from the 2-D layout).  Decode KV
caches are sequence-sharded (see repro.models.flash_decode).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# symbolic axis groups resolved against the mesh
DP, TP, DPTP, FREE = "##dp", "##tp", "##dptp", "##free"

_COL = ("wq", "wk", "wv", "w_up", "w_gate", "in_proj", "W", "wq_a", "wq_b",
        "wkv_a", "wkv_b", "w_if", "dt_proj", "proj")
_ROW = ("wo", "w_down", "out_proj", "x_proj")
_EXPERT = ("we_gate", "we_up", "we_down")


def _rule_for(name: str, layout: str = "2d"):
    """Trailing-dims spec template for a parameter name.

    layout "2d": TP x FSDP-DP (training/prefill — weight gathers amortize
    over many tokens).  layout "tp": TP-only (decode — per-step weight
    gathers would dominate single-token activations; §Perf iteration 3)."""
    dpx = DP if layout == "2d" else None
    vx = DPTP if layout == "2d" else TP
    if name in _EXPERT:
        return (DPTP, FREE, FREE)
    if name in _COL:
        return (dpx, TP)
    if name in _ROW:
        return (TP, dpx)
    if name == "conv_w":
        return (None, TP)
    if name == "A_log":
        return (TP, None)
    if name == "tok":                      # embedding (V, d)
        return (vx, None)
    if name == "w_out":                    # exit projection (d, V)
        return (None, vx)
    return None


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape.keys())


def _subsets(axes, mesh):
    """Non-empty subsets of `axes`, largest total parallelism first."""
    out = []
    n = len(axes)
    for mask in range(1, 2 ** n):
        sub = tuple(a for i, a in enumerate(axes) if mask >> i & 1)
        size = 1
        for a in sub:
            size *= mesh.shape[a]
        out.append((size, sub))
    out.sort(key=lambda t: -t[0])
    return [s for _, s in out] + [()]


def _candidates(group, mesh):
    """Axis tuples to try for a symbolic group, most-parallel first."""
    dp = _dp_axes(mesh)
    if group == DP:
        return _subsets(dp, mesh)
    if group == TP:
        return [("model",), ()]
    if group == DPTP:
        return _subsets(dp + ("model",), mesh)
    if group == FREE:
        return _subsets(dp + ("model",), mesh)
    return [(group,) if isinstance(group, str) else tuple(group), ()]


def _assign(shape, template, mesh):
    """Resolve a trailing-dims template against concrete dims with
    divisibility fallback.  Earlier (stacking) dims stay replicated."""
    nd = len(shape)
    template = template[-nd:] if len(template) > nd else template
    pad = (None,) * (nd - len(template))
    spec = []
    used: set = set()
    for dim, group in zip(shape, pad + tuple(template)):
        if group is None:
            spec.append(None)
            continue
        chosen = None
        for cand in _candidates(group, mesh):
            cand = tuple(a for a in cand if a not in used)
            if not cand:
                continue
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                chosen = cand
                break
        if chosen:
            used.update(chosen)
            spec.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            spec.append(None)
    return P(*spec)


def _leaf_name(path):
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return None


def expert_templates_for(cfg, mesh, dp, moe_impl: str):
    """Expert sharding templates matching the chosen MoE implementation.

    alltoall: EP over a dp-axis suffix on E, expert-FFN width over TP —
    exactly the shard_map layout, so no per-layer resharding happens.
    gather (default): maximally-sharded 2-D layout (DPTP/FREE)."""
    if moe_impl != "alltoall" or cfg is None or cfg.moe is None:
        return None
    from repro.models.moe import alltoall_ep_axes
    ep = alltoall_ep_axes(cfg, mesh, dp)
    if not ep:
        return None
    fe = cfg.moe.d_ff_expert
    tp = "model" if fe % mesh.shape["model"] == 0 else None
    ep_s = ep if len(ep) > 1 else ep[0]
    return {"we_gate": (ep_s, None, tp), "we_up": (ep_s, None, tp),
            "we_down": (ep_s, tp, None)}


def param_shardings(mesh, params, expert_templates=None, layout="2d"):
    def one(path, leaf):
        name = _leaf_name(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if expert_templates and name in expert_templates:
            t = expert_templates[name]
            nd = leaf.ndim
            spec = (None,) * (nd - len(t)) + tuple(t)
            return NamedSharding(mesh, P(*spec))
        rule = _rule_for(name, layout) if name else None
        if rule is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _assign(leaf.shape, rule, mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def decode_weight_layout(cfg, mesh):
    """Pick the decode weight layout: TP-only when the dense (non-expert)
    params fit per-device under ~4GiB, else keep the 2-D layout."""
    import numpy as np
    from repro.launch.steps import abstract_params
    params = abstract_params(cfg)
    dense = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if _leaf_name(path) in _EXPERT:
            continue
        dense += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return "tp" if dense / mesh.shape["model"] <= 4 * 2 ** 30 else "2d"


def opt_shardings(mesh, opt_state, expert_templates=None):
    """AdamW state: mu/nu mirror params; step replicated."""
    from repro.training.optimizer import AdamWState
    return AdamWState(step=NamedSharding(mesh, P()),
                      mu=param_shardings(mesh, opt_state.mu, expert_templates),
                      nu=param_shardings(mesh, opt_state.nu, expert_templates))


def batch_shardings(mesh, batch, dp):
    """Shard the leading (batch) axis of every leaf over dp axes."""
    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        bsz = leaf.shape[0]
        total = 1
        for a in dp:
            total *= mesh.shape[a]
        ax = dp if total > 1 and bsz % total == 0 else None
        return NamedSharding(mesh, P(ax, *([None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(mesh, cache, dp, seq_axes):
    """Decode-cache sharding: KV/latent caches sequence-sharded over
    seq_axes (dim 1 after batch), recurrent states model-sharded."""
    bdp = tuple(a for a in dp if a not in seq_axes) or None

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        spec = [None] * len(shape)

        def setdim(i, ax):
            if ax is None or i < 0 or i >= len(shape):
                return
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size > 1 and shape[i] % size == 0:
                spec[i] = ax if isinstance(ax, tuple) and len(ax) > 1 \
                    else axes[0]

        if name in ("k", "v"):            # (..., B, S, KV, hd)
            setdim(len(shape) - 4, bdp)
            setdim(len(shape) - 3, seq_axes)
        elif name in ("latent", "k_rope"):  # (..., B, S, dim)
            setdim(len(shape) - 3, bdp)
            setdim(len(shape) - 2, seq_axes)
        elif name == "slot_pos":          # (..., B, S)
            setdim(len(shape) - 2, bdp)
            setdim(len(shape) - 1, seq_axes)
        elif name == "ssm_state":         # (..., B, di, ds)
            setdim(len(shape) - 3, bdp)
            setdim(len(shape) - 2, "model")
        elif name == "conv_state":        # (..., B, dc-1, di)
            setdim(len(shape) - 3, bdp)
            setdim(len(shape) - 1, "model")
        elif name == "C":                 # (..., B, H, dk, dv)
            setdim(len(shape) - 4, bdp)
            setdim(len(shape) - 1, "model")
        elif name in ("n", "m"):          # (..., B, H[, dk])
            setdim(len(shape) - (3 if name == "n" else 2), bdp)
        else:
            # slstm tuple state (B, d) and misc small leaves
            if len(shape) >= 1:
                setdim(0, bdp if len(shape) <= 2 else None)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)
