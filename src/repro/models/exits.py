"""Early-exit heads — the imprecise-computation interface of every model.

Each stage ends in a thin classifier (paper Fig. 1): RMSNorm → linear to the
output vocabulary → softmax.  Its (prediction, confidence) tuple is what the
RTDeepIoT scheduler consumes; confidence = (optionally temperature-calibrated)
max-softmax probability [21].

The TPU-target fused version of `confidence_from_logits` (online softmax over
vocab blocks, never materializing the probability vector) lives in
repro.kernels.exit_confidence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init, param_dtype, rms_norm, shard


def init_exit(cfg, key, dtype=None, shared=False):
    """Per-stage exit params.  The (large, vocab-sized) output projection is
    *shared* across stages (paper: exits are "thin" classifiers; sharing the
    unembedding is the standard anytime-LM construction) — each stage owns
    only its norm scale.  `shared=True` initializes the shared projection."""
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d, V = cfg.d_model, cfg.vocab_size
    if shared:
        if cfg.modality == "audio_stub":
            return {"w_out": dense_init(kg(), (cfg.num_codebooks, d, V), dt)}
        return {"w_out": dense_init(kg(), (d, V), dt)}
    return {"ln": jnp.zeros((d,), dt)}


def apply_exit(cfg, params, h, *, ctx=None):
    """h: (B, S, d) -> logits.

    text/vlm:   (B, S, V)     next-token logits
    audio_stub: (B, S, ncb, V)
    features:   (B, V)        mean-pooled classification logits
    """
    hn = rms_norm(h, params["ln"], cfg.norm_eps)
    if cfg.modality == "features":
        # classification readout = cell 0 (the anchor position); mean-pool
        # dilutes position-routed information
        hn = hn[:, 0]
        return hn @ params["w_out"]
    if cfg.modality == "audio_stub":
        logits = jnp.einsum("bsd,cdv->bscv", hn, params["w_out"])
    else:
        logits = hn @ params["w_out"]
    if ctx is not None:
        lead = (ctx.dp,) + (None,) * (logits.ndim - 2)
        logits = shard(logits, ctx, *lead, ctx.tp)
    return logits


def exit_rows(cfg, h):
    """The rows the exit head actually reads: (B, d).

    features: the anchor cell (position 0); decode callers pass the
    current-token hidden state directly.  RMSNorm is per-position, so
    norming the selected rows equals selecting from the normed tensor —
    this is what lets the fused kernel skip the rest of the sequence."""
    if h.ndim == 2:
        return h
    return h[:, 0] if cfg.modality == "features" else h[:, -1]


def exit_stats_unfused(h_rows, scale, w_out, *, eps: float = 1e-6,
                       temperature: float = 1.0):
    """Unfused reference for the fused exit kernel — materializes the full
    (N, V) logits row, then reduces with the *same* finisher arithmetic as
    the kernel (running max m, normalizer l = sum exp(logits - m),
    conf = 1/l, lse = m + log l).  With a single vocab block the kernel's
    online pass folds exactly once, so in interpret mode the fused path is
    bit-for-bit equal to this function — the equality the kernel-serving
    figure asserts.

    h_rows: (N, d); scale: (d,); w_out: (d, V).
    Returns (conf (N,), pred (N,) int32, max_logit (N,), lse (N,)).
    """
    h = h_rows.astype(jnp.float32)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    logits = jax.lax.dot_general(hn, w_out.astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = logits / temperature
    m = jnp.max(logits, axis=1)
    l = jnp.maximum(jnp.sum(jnp.exp(logits - m[:, None]), axis=1), 1e-30)
    conf = 1.0 / l
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    return conf, pred, m, m + jnp.log(l)


def exit_stats_fused(h_rows, scale, w_out, *, eps: float = 1e-6,
                     temperature: float = 1.0, block_rows: int = 8,
                     block_v: int = 512, interpret: bool = True):
    """Fused exit epilogue: RMSNorm -> matmul -> online (max, lse, argmax)
    in one Pallas dispatch (repro.kernels.exit_confidence) — the V-sized
    logits row never leaves the kernel.  Same signature/returns as
    :func:`exit_stats_unfused`."""
    from repro.kernels.exit_confidence.kernel import exit_confidence
    return exit_confidence(h_rows, scale, w_out, eps=eps,
                           temperature=temperature, block_rows=block_rows,
                           block_v=block_v, interpret=interpret)


def confidence_from_logits(logits, temperature: float = 1.0):
    """Max-softmax confidence over the trailing class axis (fp32).

    Pure-jnp oracle for the fused Pallas kernel; audio codebook confidences
    are averaged.
    """
    lg = logits.astype(jnp.float32) / temperature
    conf = jnp.exp(jnp.max(lg, -1) - jax.nn.logsumexp(lg, -1))
    # average any remaining non-batch axes (codebooks / positions handled by
    # callers; this reduces exactly the codebook axis for audio)
    return conf


def exit_prediction(cfg, logits):
    """argmax class / token id at the last position (serving path)."""
    return jnp.argmax(logits, axis=-1)
