"""Early-exit heads — the imprecise-computation interface of every model.

Each stage ends in a thin classifier (paper Fig. 1): RMSNorm → linear to the
output vocabulary → softmax.  Its (prediction, confidence) tuple is what the
RTDeepIoT scheduler consumes; confidence = (optionally temperature-calibrated)
max-softmax probability [21].

The TPU-target fused version of `confidence_from_logits` (online softmax over
vocab blocks, never materializing the probability vector) lives in
repro.kernels.exit_confidence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init, param_dtype, rms_norm, shard


def init_exit(cfg, key, dtype=None, shared=False):
    """Per-stage exit params.  The (large, vocab-sized) output projection is
    *shared* across stages (paper: exits are "thin" classifiers; sharing the
    unembedding is the standard anytime-LM construction) — each stage owns
    only its norm scale.  `shared=True` initializes the shared projection."""
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d, V = cfg.d_model, cfg.vocab_size
    if shared:
        if cfg.modality == "audio_stub":
            return {"w_out": dense_init(kg(), (cfg.num_codebooks, d, V), dt)}
        return {"w_out": dense_init(kg(), (d, V), dt)}
    return {"ln": jnp.zeros((d,), dt)}


def apply_exit(cfg, params, h, *, ctx=None):
    """h: (B, S, d) -> logits.

    text/vlm:   (B, S, V)     next-token logits
    audio_stub: (B, S, ncb, V)
    features:   (B, V)        mean-pooled classification logits
    """
    hn = rms_norm(h, params["ln"], cfg.norm_eps)
    if cfg.modality == "features":
        # classification readout = cell 0 (the anchor position); mean-pool
        # dilutes position-routed information
        hn = hn[:, 0]
        return hn @ params["w_out"]
    if cfg.modality == "audio_stub":
        logits = jnp.einsum("bsd,cdv->bscv", hn, params["w_out"])
    else:
        logits = hn @ params["w_out"]
    if ctx is not None:
        lead = (ctx.dp,) + (None,) * (logits.ndim - 2)
        logits = shard(logits, ctx, *lead, ctx.tp)
    return logits


def confidence_from_logits(logits, temperature: float = 1.0):
    """Max-softmax confidence over the trailing class axis (fp32).

    Pure-jnp oracle for the fused Pallas kernel; audio codebook confidences
    are averaged.
    """
    lg = logits.astype(jnp.float32) / temperature
    conf = jnp.exp(jnp.max(lg, -1) - jax.nn.logsumexp(lg, -1))
    # average any remaining non-batch axes (codebooks / positions handled by
    # callers; this reduces exactly the codebook axis for audio)
    return conf


def exit_prediction(cfg, logits):
    """argmax class / token id at the last position (serving path)."""
    return jnp.argmax(logits, axis=-1)
