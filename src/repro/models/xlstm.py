"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential).  [arXiv:2405.04517]

TPU adaptation: the paper's CUDA mLSTM kernel is replaced by a *chunkwise
parallel* formulation — an outer `lax.scan` carries the stabilized
(C, n, m) state across chunks; within a chunk the recurrence is evaluated in
closed form with masked L×L score matrices (flash-attention-shaped work that
maps onto the MXU).  The sLSTM hidden-to-hidden nonlinearity is inherently
sequential; its input projections are hoisted out of the scan so the per-step
body is only the block-diagonal recurrent matmul.

Stabilization follows the paper: running log-scale m with
m_t = max(logsigmoid(f̃_t) + m_{t-1}, ĩ_t).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import shard_residual, KeyGen, dense_init, param_dtype, rms_norm, shard

MLSTM_CHUNK = 128


def _group_norm(x, scale, n_heads, eps=1e-6):
    """Per-head group norm over trailing dim split into heads."""
    *lead, d = x.shape
    xh = x.reshape(*lead, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(*lead, d) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_d_inner(cfg):
    return 2 * cfg.d_model


def init_mlstm(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d, H = cfg.d_model, cfg.num_heads
    di = mlstm_d_inner(cfg)
    down_scale = 0.02 / max(1, cfg.num_layers) ** 0.5
    return {
        "ln": jnp.zeros((d,), dt),
        "w_up": dense_init(kg(), (d, 2 * di), dt),
        "conv_w": dense_init(kg(), (4, di), dt, scale=0.2),
        "conv_b": jnp.zeros((di,), dt),
        # block-diagonal (per-head) q/k/v projections, per the xLSTM paper
        "wq_head": dense_init(kg(), (H, di // H, di // H), dt),
        "wk_head": dense_init(kg(), (H, di // H, di // H), dt),
        "wv_head": dense_init(kg(), (H, di // H, di // H), dt),
        "w_if": dense_init(kg(), (di, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                 3.0 * jnp.ones((H,), jnp.float32)]),
        "gn": jnp.zeros((di,), dt),
        "w_down": dense_init(kg(), (di, d), dt, scale=down_scale),
    }


def _mlstm_qkvif(cfg, params, x_m):
    """x_m: (B,S,di) conv/silu already applied where needed."""
    from repro.models.ssm import _causal_conv
    H = cfg.num_heads
    di = x_m.shape[-1]
    dh = di // H
    x_c = jax.nn.silu(_causal_conv(x_m, params["conv_w"], params["conv_b"]))
    B, S, _ = x_m.shape
    xch = x_c.reshape(B, S, H, dh)
    xmh = x_m.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xch, params["wq_head"])
    k = jnp.einsum("bshd,hde->bshe", xch, params["wk_head"]) * dh ** -0.5
    v = jnp.einsum("bshd,hde->bshe", xmh, params["wv_head"])
    if_pre = (x_c.astype(jnp.float32) @ params["w_if"] + params["b_if"])
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)           # (B,S,H)
    return q, k, v, i_pre, f_pre


def mlstm_chunked(q, k, v, i_pre, f_pre, state0):
    """Chunk-parallel stabilized mLSTM.

    q,k,v: (B,S,H,dh); i_pre,f_pre: (B,S,H) fp32.
    state0: dict(C=(B,H,dh,dh), n=(B,H,dh), m=(B,H)) — stabilized storage
    (C and n are already divided by exp(m)).
    Returns (h (B,S,H,dh), final_state).
    """
    B, S, H, dh = q.shape
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0, (S, L)
    n_chunks = S // L

    def rs(t):  # (B,S,...) -> (n_chunks, B, L, ...)
        return t.reshape(B, n_chunks, L, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    qs, ks, vs = rs(q), rs(k), rs(v)
    is_, fs = rs(i_pre), rs(f_pre)

    def body(state, inp):
        C0, n0, m0 = state["C"], state["n"], state["m"]   # stabilized
        qc, kc, vc, ic, fc = inp                          # (B,L,H,*)
        lf = jax.nn.log_sigmoid(fc)                       # (B,L,H)
        b = jnp.cumsum(lf, axis=1)                        # inclusive
        u = ic - b                                        # (B,L,H)
        g = jnp.maximum(m0[:, None], jax.lax.cummax(u, axis=1))
        m = b + g                                         # (B,L,H) running max
        # decay matrices
        scores = jnp.einsum("blhd,bshd->bhls", qc, kc).astype(jnp.float32)
        causal = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.exp(u.transpose(0, 2, 1)[:, :, None, :]
                       - g.transpose(0, 2, 1)[:, :, :, None])   # (B,H,l,s)
        dmat = jnp.where(causal[None, None], dmat, 0.0)
        w = scores * dmat                                  # weighted scores
        inter_scale = jnp.exp(m0[:, None] - g)             # (B,L,H)
        h_intra = jnp.einsum("bhls,bshd->blhd", w.astype(vc.dtype), vc)
        h_inter = jnp.einsum("blhd,bhde->blhe", qc, C0.astype(qc.dtype))
        h_num = h_intra.astype(jnp.float32) + \
            inter_scale[..., None] * h_inter.astype(jnp.float32)
        nq_intra = jnp.sum(w, axis=-1).transpose(0, 2, 1)  # (B,L,H)
        nq_inter = jnp.einsum("blhd,bhd->blh", qc.astype(jnp.float32),
                              n0)
        nq = nq_intra + inter_scale * nq_inter
        denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m))
        h = (h_num / denom[..., None]).astype(qc.dtype)
        # state update to end of chunk
        gL, bL = g[:, -1], b[:, -1]                        # (B,H)
        wS = jnp.exp(u - gL[:, None])                      # (B,L,H)
        C1 = jnp.exp(m0 - gL)[..., None, None] * C0 + \
            jnp.einsum("blh,blhd,blhe->bhde", wS, kc.astype(jnp.float32),
                       vc.astype(jnp.float32))
        n1 = jnp.exp(m0 - gL)[..., None] * n0 + \
            jnp.einsum("blh,blhd->bhd", wS, kc.astype(jnp.float32))
        m1 = bL + gL
        return {"C": C1, "n": n1, "m": m1}, h

    final, hs = jax.lax.scan(body, state0, (qs, ks, vs, is_, fs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return h, final


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """Single-token recurrence. q,k,v: (B,H,dh); i/f_pre: (B,H)."""
    C0, n0, m0 = state["C"], state["n"], state["m"]
    lf = jax.nn.log_sigmoid(f_pre)
    m1 = jnp.maximum(lf + m0, i_pre)
    fp = jnp.exp(lf + m0 - m1)
    ip = jnp.exp(i_pre - m1)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C1 = fp[..., None, None] * C0 + ip[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kf, vf)
    n1 = fp[..., None] * n0 + ip[..., None] * kf
    h_num = jnp.einsum("bhd,bhde->bhe", qf, C1)
    nq = jnp.einsum("bhd,bhd->bh", qf, n1)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m1))
    h = (h_num / denom[..., None]).astype(q.dtype)
    return h, {"C": C1, "n": n1, "m": m1}


def init_mlstm_state(cfg, batch):
    H = cfg.num_heads
    dh = mlstm_d_inner(cfg) // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def apply_mlstm_full(cfg, params, x, *, ctx=None, **_):
    B, S, d = x.shape
    H = cfg.num_heads
    h_in = rms_norm(x, params["ln"], cfg.norm_eps)
    up = h_in @ params["w_up"]
    if ctx is not None:
        up = shard(up, ctx, ctx.dp, None, ctx.tp)
    x_m, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, params, x_m)
    state0 = init_mlstm_state(cfg, B)
    hs, final = mlstm_chunked(q, k, v, i_pre, f_pre, state0)
    di = x_m.shape[-1]
    hs = _group_norm(hs.reshape(B, S, di), params["gn"], H)
    y = (hs * jax.nn.silu(z)) @ params["w_down"]
    y = shard_residual(y, ctx)
    # conv ring for decode
    cache = {"mlstm": final,
             "conv_state": (h_in[:, -3:] @ params["w_up"][:, :di])}
    return x + y, cache


def apply_mlstm_step(cfg, params, x, *, cache, ctx=None, **_):
    from repro.models.ssm import d_inner_of  # noqa: F401 (parity import)
    B, d = x.shape
    H = cfg.num_heads
    di = mlstm_d_inner(cfg)
    dh = di // H
    h_in = rms_norm(x, params["ln"], cfg.norm_eps)
    up = h_in @ params["w_up"]
    x_m, z = jnp.split(up, 2, axis=-1)
    hist = jnp.concatenate([cache["conv_state"], x_m[:, None]], 1)  # (B,4,di)
    x_c = jnp.einsum("bcd,cd->bd", hist[:, -4:], params["conv_w"]) + params["conv_b"]
    x_c = jax.nn.silu(x_c)
    xch = x_c.reshape(B, H, dh)
    xmh = x_m.reshape(B, H, dh)
    q = jnp.einsum("bhd,hde->bhe", xch, params["wq_head"])
    k = jnp.einsum("bhd,hde->bhe", xch, params["wk_head"]) * dh ** -0.5
    v = jnp.einsum("bhd,hde->bhe", xmh, params["wv_head"])
    if_pre = x_c.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)
    hstep, new_state = mlstm_step(q, k, v, i_pre, f_pre, cache["mlstm"])
    hs = _group_norm(hstep.reshape(B, di), params["gn"], H)
    y = (hs * jax.nn.silu(z)) @ params["w_down"]
    new_cache = dict(cache, mlstm=new_state, conv_state=hist[:, 1:])
    return x + y, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    f_up = -(-4 * d // 3 // 64) * 64                 # 4/3 GeGLU factor
    down_scale = 0.02 / max(1, cfg.num_layers) ** 0.5
    return {
        "ln": jnp.zeros((d,), dt),
        "W": dense_init(kg(), (d, 4 * d), dt),
        "R": dense_init(kg(), (H, dh, 4 * dh), dt, scale=dh ** -0.5),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              3.0 * jnp.ones((d,), jnp.float32),   # fgate bias
                              jnp.zeros((d,), jnp.float32)]),
        "gn": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "w_up": dense_init(kg(), (d, 2 * f_up), dt),
        "w_down": dense_init(kg(), (f_up, d), dt, scale=down_scale),
    }


def slstm_step_core(cfg, params, wx_t, state):
    """One sLSTM step. wx_t: (B, 4d) precomputed input projection."""
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    h0, c0, n0, m0 = state
    B = wx_t.shape[0]
    rh = jnp.einsum("bhd,hde->bhe", h0.reshape(B, H, dh), params["R"])
    pre = (wx_t.reshape(B, H, 4 * dh) + rh).reshape(B, 4 * d).astype(jnp.float32)
    pre = pre + params["b"]
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    lf = jax.nn.log_sigmoid(f_p)
    m1 = jnp.maximum(lf + m0, i_p)
    fp = jnp.exp(lf + m0 - m1)
    ip = jnp.exp(i_p - m1)
    c1 = fp * c0 + ip * z
    n1 = fp * n0 + ip
    h1 = o * c1 / jnp.maximum(n1, 1e-6)       # fp32 recurrent state
    return (h1, c1, n1, m1)


def init_slstm_state(cfg, batch):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_mlp(cfg, params, y):
    h = rms_norm(y, params["ln2"], cfg.norm_eps)
    a, b = jnp.split(h @ params["w_up"], 2, axis=-1)
    return y + (jax.nn.gelu(a) * b) @ params["w_down"]


def apply_slstm_full(cfg, params, x, *, ctx=None, **_):
    B, S, d = x.shape
    h_in = rms_norm(x, params["ln"], cfg.norm_eps)
    wx = h_in @ params["W"]                           # hoisted input proj
    state0 = init_slstm_state(cfg, B)

    def body(state, wx_t):
        s1 = slstm_step_core(cfg, params, wx_t, state)
        return s1, s1[0]

    final, hs = jax.lax.scan(body, state0, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)                        # (B,S,d)
    hs = _group_norm(hs, params["gn"], cfg.num_heads).astype(x.dtype)
    y = x + hs
    y = _slstm_mlp(cfg, params, y)
    cache = {"slstm": final}
    return y, cache


def apply_slstm_step(cfg, params, x, *, cache, ctx=None, **_):
    h_in = rms_norm(x, params["ln"], cfg.norm_eps)
    wx = h_in @ params["W"]
    s1 = slstm_step_core(cfg, params, wx, cache["slstm"])
    hs = _group_norm(s1[0], params["gn"], cfg.num_heads).astype(x.dtype)
    y = x + hs
    y = _slstm_mlp(cfg, params, y)
    return y, dict(cache, slstm=s1)
