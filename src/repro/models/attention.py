"""Attention blocks: GQA (full / sliding-window local) and MLA (DeepSeek).

Three execution paths:
  * mode="full"  — training / prefill over a whole sequence.  Full attention
    uses a q-chunk scanned online-softmax (flash pattern, O(S) memory);
    sliding-window layers use a block-local path (each chunk attends to
    itself + the previous chunk) that never touches far context.
  * mode="step"  — decode: one new token against a KV cache.  Distributed
    decode uses the shard_map flash-decode in repro.models.flash_decode
    (sequence-sharded cache, (m, l) logsumexp combine).
  * Pallas kernels in repro.kernels are the TPU-target versions of the same
    math, validated against these pure-jnp paths.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (KeyGen, apply_rope, dense_init,
                                 param_dtype, rms_norm, rms_norm_head, shard,
                                 shard_residual)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core softmax-attention primitives (pure jnp)
# ---------------------------------------------------------------------------

def _grouped_scores(q, k):
    """q: (B,Sq,KV,G,hd)  k: (B,Sk,KV,hd)  -> (B,KV,G,Sq,Sk)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _grouped_out(p, v):
    """p: (B,KV,G,Sq,Sk)  v: (B,Sk,KV,hd) -> (B,Sq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v)


def attend_dense(q, k, v, *, causal: bool, q_pos, k_pos,
                 window: Optional[int] = None, softmax_scale: float):
    """Unchunked reference attention with GQA grouping.

    q: (B, Sq, KV, G, hd); k, v: (B, Sk, KV, hd); *_pos: int32 positions.
    """
    scores = _grouped_scores(q, k).astype(jnp.float32) * softmax_scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _grouped_out(p, v)


def attend_chunked(q, k, v, *, q_pos, k_pos, window: Optional[int],
                   softmax_scale: float, q_chunk: int = 1024,
                   causal: bool = True):
    """Causal online-softmax attention, scanned over query chunks.

    Memory is O(q_chunk * Sk) instead of O(Sq * Sk).  Each chunk's scores are
    computed against the full key range with causal (+ optional window)
    masking — FLOPs match the dense path, memory does not.
    """
    B, Sq, KV, G, hd = q.shape
    if Sq <= q_chunk:
        return attend_dense(q, k, v, causal=causal, q_pos=q_pos, k_pos=k_pos,
                            window=window, softmax_scale=softmax_scale)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(n, q_chunk)

    def body(_, x):
        qc, qpc = x
        out = attend_dense(qc, k, v, causal=causal, q_pos=qpc, k_pos=k_pos,
                           window=window, softmax_scale=softmax_scale)
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, qp))
    hd_v = v.shape[-1]                     # MLA: v head dim != q head dim
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, hd_v)


def attend_local(q, k, v, *, q_pos, k_pos, window: int, softmax_scale: float):
    """Block-local sliding-window attention (window <= block).

    Chunks the sequence into `window`-sized blocks; each block attends to
    itself and its predecessor with exact causal+window masking.  FLOPs are
    O(S * 2*window) — this is the sub-quadratic path used by local layers.
    """
    B, S, KV, G, hd = q.shape
    if S <= window:
        return attend_dense(q, k, v, causal=True, q_pos=q_pos, k_pos=k_pos,
                            window=window, softmax_scale=softmax_scale)
    assert S % window == 0, (S, window)
    n = S // window
    qb = q.reshape(B, n, window, KV, G, hd)
    kb = k.reshape(B, n, window, KV, hd)
    vb = v.reshape(B, n, window, KV, hd)
    # previous block (zero-padded at the front)
    pad = lambda x: jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], 1)
    k2 = jnp.concatenate([pad(kb), kb], axis=2)         # (B,n,2w,KV,hd)
    v2 = jnp.concatenate([pad(vb), vb], axis=2)
    qpb = q_pos.reshape(n, window)
    kpb = k_pos.reshape(n, window)
    kp2 = jnp.concatenate(
        [jnp.concatenate([jnp.full((1, window), -10**9, k_pos.dtype),
                          kpb[:-1]], 0), kpb], axis=1)  # (n, 2w)

    scores = jnp.einsum("bnqkgh,bnskh->bnkgqs", qb, k2).astype(jnp.float32)
    scores = scores * softmax_scale
    mask = (qpb[:, :, None] >= kp2[:, None, :]) & \
           (qpb[:, :, None] - kp2[:, None, :] < window)
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", p, v2)
    return out.reshape(B, S, KV, G, hd)


def decode_attend(q, k_cache, v_cache, k_pos, cur_pos, *, window, softmax_scale):
    """Single-token decode attention against a cache (single-shard path).

    q: (B, KV, G, hd); caches: (B, S, KV, hd); k_pos: (S,) positions stored at
    each cache slot (ring buffers store non-monotonic positions); cur_pos: (B,)
    """
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k_cache).astype(jnp.float32)
    scores = scores * softmax_scale
    valid = (k_pos[None] <= cur_pos[:, None]) & (k_pos[None] >= 0)
    if window is not None:
        valid &= cur_pos[:, None] - k_pos[None] < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgs,bskh->bkgh", p, v_cache)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    p = {
        "ln": jnp.zeros((d,), dt),
        "wq": dense_init(kg(), (d, H * hd), dt),
        "wk": dense_init(kg(), (d, KV * hd), dt),
        "wv": dense_init(kg(), (d, KV * hd), dt),
        "wo": dense_init(kg(), (H * hd, d), dt, scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _project_qkv(cfg, params, x, positions, ctx, allow_flat=True):
    B = x.shape[0]
    S = x.shape[1] if x.ndim == 3 else 1
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    x2 = x.reshape(B, S, -1)
    q = (x2 @ params["wq"]).reshape(B, S, KV, H // KV, hd)
    k = (x2 @ params["wk"]).reshape(B, S, KV, hd)
    v = (x2 @ params["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm_head(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm_head(k, params["k_norm"], cfg.norm_eps)
    pos = positions if positions.ndim == 2 else positions[None].repeat(B, 0)
    q = apply_rope(q.reshape(B, S, KV * (H // KV), hd), pos, cfg.rope_theta)
    q = q.reshape(B, S, KV, H // KV, hd)
    k = apply_rope(k, pos, cfg.rope_theta)
    if ctx is not None and allow_flat and ctx.attn_impl == "flat" \
            and H % ctx.tp_size == 0:
        # §Perf iteration 1 (flat-head attention): repeat KV heads to H and
        # treat as MHA so every attention operand shards exactly H/tp-way —
        # no (KV=8 vs tp=16) mismatch, no involuntary full remats.  The
        # repeated K/V shard over heads, so per-device bytes are H/tp*hd
        # (<= the replicated KV heads of the grouped layout).
        G = H // KV
        k = jnp.repeat(k, G, axis=2)               # (B,S,H,hd)
        v = jnp.repeat(v, G, axis=2)
        q = q.reshape(B, S, H, 1, hd)
        k = shard(k, ctx, ctx.dp, None, ctx.tp, None)
        v = shard(v, ctx, ctx.dp, None, ctx.tp, None)
        q = shard(q, ctx, ctx.dp, None, ctx.tp, None, None)
        return q, k, v
    if ctx is not None:
        if KV % ctx.tp_size == 0:
            q = shard(q, ctx, ctx.dp, None, ctx.tp, None, None)
        # else: leave placement to GSPMD propagation (baseline behaviour)
    return q, k, v


def apply_gqa_full(cfg, params, x, *, positions, local: bool, ctx,
                   q_chunk: int = 1024):
    """Training/prefill attention over the full sequence.

    Returns (y, cache) where cache = (k, v) over the whole sequence.
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, params, h, positions, ctx)
    scale = hd ** -0.5
    window = cfg.sliding_window if local else None
    kp = positions if positions.ndim == 1 else positions[0]
    if local and window is not None and S > window:
        out = attend_local(q, k, v, q_pos=kp, k_pos=kp, window=window,
                           softmax_scale=scale)
    else:
        out = attend_chunked(q, k, v, q_pos=kp, k_pos=kp, window=window,
                             softmax_scale=scale, q_chunk=q_chunk,
                             causal=cfg.causal)
    out = out.reshape(B, S, cfg.num_heads * hd)
    y = out @ params["wo"]
    y = shard_residual(y, ctx)
    return x + y, (k, v)


def apply_gqa_step(cfg, params, x, *, cache, cur_pos, local: bool, ctx):
    """Decode one token.  cache: dict(k=(B,S,KV,hd), v=..., slot_pos=(B,S)).

    The cache layout is owned by repro.serving.kv_cache: a *full* cache has
    as many slots as max positions (write slot = position); a *ring* cache
    (sliding-window layers / swa-8192 long-context variant) has `window`
    slots and wraps — `slot_pos` records which position each slot holds so
    masking stays exact either way.
    """
    from repro.models import flash_decode

    B, d = x.shape[0], x.shape[-1]
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    # decode writes KV heads into the cache: the flat-head repeat is a
    # full-mode (train/prefill) optimization only
    q, k, v = _project_qkv(cfg, params, h, cur_pos[:, None], ctx,
                           allow_flat=False)
    q = q[:, 0]                      # (B,KV,G,hd)
    k_new, v_new = k[:, 0], v[:, 0]  # (B,KV,hd)

    n_slots = cache["k"].shape[1]
    write_idx = cur_pos % n_slots    # (B,) slot to overwrite (ring-aware)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, write_idx].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, write_idx].set(v_new.astype(cache["v"].dtype))
    slot_pos = cache["slot_pos"].at[bidx, write_idx].set(cur_pos)

    window = cfg.sliding_window if local else None
    scale = hd ** -0.5
    if ctx is not None and ctx.decode_attn == "kernel":
        # Pallas decode kernel over the (ring) cache: per-row slot_pos
        # masking makes ragged co-batched requests exact.  Head axis is
        # KV-major ((B, KV*G, hd)), matching the kernel's head->KV map
        from repro.kernels.decode_attention.kernel import decode_attention
        out = decode_attention(q.reshape(B, H, hd),
                               k_cache.transpose(0, 2, 1, 3),
                               v_cache.transpose(0, 2, 1, 3),
                               slot_pos, cur_pos,
                               window=window, softmax_scale=scale)
    elif ctx is not None and ctx.decode_attn == "flash_decode":
        out = flash_decode.flash_decode(q, k_cache, v_cache, slot_pos, cur_pos,
                                        window=window, softmax_scale=scale,
                                        ctx=ctx)
    else:
        out = decode_attend(q, k_cache, v_cache,
                            slot_pos[0] if slot_pos.ndim == 2 else slot_pos,
                            cur_pos, window=window, softmax_scale=scale)
    out = out.reshape(B, H * hd)
    y = out @ params["wo"]
    new_cache = dict(cache, k=k_cache, v=v_cache, slot_pos=slot_pos)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "ln": jnp.zeros((d,), dt),
        "wq_a": dense_init(kg(), (d, m.q_lora_rank), dt),
        "q_norm": jnp.zeros((m.q_lora_rank,), dt),
        "wq_b": dense_init(kg(), (m.q_lora_rank, H * qd), dt),
        "wkv_a": dense_init(kg(), (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
        "wkv_b": dense_init(kg(), (m.kv_lora_rank,
                                   H * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": dense_init(kg(), (H * m.v_head_dim, d), dt,
                         scale=0.02 / max(1, cfg.num_layers) ** 0.5),
    }


def _mla_qkv_full(cfg, params, h, positions):
    m = cfg.mla
    B, S, _ = h.shape
    H = cfg.num_heads
    q = rms_norm(h @ params["wq_a"], params["q_norm"], cfg.norm_eps) @ params["wq_b"]
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    pos = positions if positions.ndim == 2 else positions[None].repeat(B, 0)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv = h @ params["wkv_a"]
    latent, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    latent = rms_norm(latent, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    kvb = (latent @ params["wkv_b"]).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    return q_nope, q_rope, k_nope, k_rope, v, latent


def apply_mla_full(cfg, params, x, *, positions, ctx, q_chunk: int = 1024,
                   **_):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    q_nope, q_rope, k_nope, k_rope, v, latent = _mla_qkv_full(
        cfg, params, h, positions)
    # assemble per-head q/k with shared rope part; treat as KV=H GQA (G=1)
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # (B,S,H,1,qd)
    q = q.transpose(0, 1, 2, 3, 4)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))], -1)
    q = q.reshape(B, S, H, 1, -1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    kp = positions if positions.ndim == 1 else positions[0]
    out = attend_chunked(q, k, v, q_pos=kp, k_pos=kp, window=None,
                         softmax_scale=scale, q_chunk=q_chunk)
    out = out.reshape(B, S, H * m.v_head_dim)
    y = out @ params["wo"]
    y = shard_residual(y, ctx)
    # MLA cache = compressed latent + shared rope key (what makes MLA special)
    return x + y, (latent, k_rope)


def apply_mla_step(cfg, params, x, *, cache, cur_pos, ctx, **_):
    """Decode with the latent cache in the *absorbed* form.

    Production MLA decode never re-expands per-token K/V for the whole cache:
    the per-head nope-query is absorbed through wkv_b's key half
    (q_lat[h] = q_nope[h] @ W_bk[h]^T) so attention runs directly in the
    (kv_lora + rope) latent space against the compressed cache — structurally
    MQA with a single shared 576-dim "kv head".  The attention output (a
    weighted sum of latents) is then expanded once per head through wkv_b's
    value half.
    """
    from repro.models import flash_decode

    m = cfg.mla
    B, d = x.shape[0], x.shape[-1]
    H = cfg.num_heads
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    h3 = h[:, None, :]
    q_nope, q_rope, _kn, k_rope_new, _v, latent_new = _mla_qkv_full(
        cfg, params, h3, cur_pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]              # (B,H,*)

    n_slots = cache["latent"].shape[1]
    write_idx = cur_pos % n_slots
    bidx = jnp.arange(B)
    latent_c = cache["latent"].at[bidx, write_idx].set(
        latent_new[:, 0].astype(cache["latent"].dtype))
    krope_c = cache["k_rope"].at[bidx, write_idx].set(
        k_rope_new[:, 0].astype(cache["k_rope"].dtype))
    slot_pos = cache["slot_pos"].at[bidx, write_idx].set(cur_pos)

    # absorb q through the key half of wkv_b: (B,H,nope) -> (B,H,kv_lora)
    wkv_b = params["wkv_b"].reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_bk = wkv_b[:, :, :m.qk_nope_head_dim]                  # (lora,H,nope)
    w_bv = wkv_b[:, :, m.qk_nope_head_dim:]                  # (lora,H,v)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope, w_bk)         # (B,H,lora)

    # MQA over the latent cache: KV=1, G=H, hd = lora + rope
    q_cat = jnp.concatenate([q_lat, q_rope], -1)[:, None, :, :]  # (B,1,H,hd)
    k_cat = jnp.concatenate([latent_c, krope_c], -1)[:, :, None, :]
    v_lat = latent_c[:, :, None, :]                          # (B,S,1,lora)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if ctx is not None and ctx.decode_attn == "flash_decode":
        out = flash_decode.flash_decode(q_cat, k_cat, v_lat, slot_pos, cur_pos,
                                        window=None, softmax_scale=scale,
                                        ctx=ctx, shard_kv_heads=False)
    else:
        out = decode_attend(q_cat, k_cat, v_lat,
                            slot_pos[0] if slot_pos.ndim == 2 else slot_pos,
                            cur_pos, window=None, softmax_scale=scale)
    out_lat = out.reshape(B, H, m.kv_lora_rank)
    out = jnp.einsum("bhl,lhv->bhv", out_lat, w_bv)          # expand to v-space
    y = out.reshape(B, H * m.v_head_dim) @ params["wo"]
    new_cache = dict(cache, latent=latent_c, k_rope=krope_c, slot_pos=slot_pos)
    return x + y, new_cache
