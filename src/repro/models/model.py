"""AnytimeModel: stage-structured (imprecise-computation) model orchestration.

Every architecture is a stack of blocks partitioned into `cfg.num_stages`
*stages* — the paper's schedulable unit.  Each stage ends in an exit head
(repro.models.exits).  Within a stage, layers are grouped into scanned
periods (bounding HLO size / compile time for the 61–96-layer configs) plus
explicit prefix/tail layers where the block pattern breaks periodicity
(e.g. DeepSeek's leading dense layers, Gemma-3's 34 = 5×6+4 remainder).

Public API
----------
init_params(cfg, key)                  -> params pytree
forward(cfg, params, inputs, ...)      -> ExitsOut (train / prefill)
decode_step(cfg, params, cache, ...)   -> (exits, new_cache)
init_decode_cache(cfg, batch, slots)   -> cache pytree
stage_forward / stage_decode_step      -> the scheduler's dispatch unit
count_params_analytic(cfg)             -> N (for roofline MODEL_FLOPS)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, exits, ffn, moe, ssm, xlstm
from repro.models.common import KeyGen, dense_init, param_dtype, shard

FEATURE_DIM = 32  # input feature width for the "features" modality


# ---------------------------------------------------------------------------
# layer layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sig:
    kind: str      # attn | attn_local | mamba | mlstm | slstm
    is_moe: bool


@dataclasses.dataclass(frozen=True)
class StageLayout:
    start: int
    end: int
    prefix: tuple            # absolute layer indices
    scan_start: int
    n_scan: int              # number of scanned periods (0 = no scan group)
    scan_sigs: tuple         # Sig per slot of one period
    tail: tuple              # absolute layer indices


def layer_sig(cfg, idx: int) -> Sig:
    kinds = cfg.layer_kinds()
    return Sig(kinds[idx], cfg.is_moe_layer(idx))


def _effective_period(cfg) -> int:
    p = len(cfg.period)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.moe_every)
    return p


def stage_layouts(cfg):
    bounds = cfg.stage_boundaries()
    out = []
    start = 0
    E = _effective_period(cfg)
    fd = cfg.moe.first_dense_layers if cfg.moe else 0
    for end in bounds:
        g0 = max(start, fd)
        n_scan = max(0, (end - g0) // E)
        if n_scan < 2:                       # not worth a scan group
            out.append(StageLayout(start, end, tuple(range(start, end)),
                                   end, 0, (), ()))
        else:
            sigs = tuple(layer_sig(cfg, g0 + j) for j in range(E))
            tail_start = g0 + n_scan * E
            out.append(StageLayout(start, end, tuple(range(start, g0)),
                                   g0, n_scan, sigs,
                                   tuple(range(tail_start, end))))
        start = end
    return tuple(out)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _init_mixer(cfg, sig: Sig, key):
    if sig.kind in ("attn", "attn_local"):
        if cfg.attention == "mla":
            return attention.init_mla(cfg, key)
        return attention.init_gqa(cfg, key)
    if sig.kind == "mamba":
        return ssm.init_mamba(cfg, key)
    if sig.kind == "mlstm":
        return xlstm.init_mlstm(cfg, key)
    if sig.kind == "slstm":
        return xlstm.init_slstm(cfg, key)
    raise ValueError(sig.kind)


def init_layer(cfg, sig: Sig, key):
    kg = KeyGen(key)
    p = {"mixer": _init_mixer(cfg, sig, kg())}
    if sig.is_moe:
        p["ffn"] = moe.init_moe(cfg, kg())
    elif cfg.ffn_type != "none" and cfg.d_ff > 0 and sig.kind in ("attn", "attn_local", "mamba"):
        p["ffn"] = ffn.init_ffn(cfg, kg())
    return p


def apply_layer(cfg, sig: Sig, params, h, *, mode, cache=None,
                positions=None, cur_pos=None, ctx=None, q_chunk=1024):
    """Returns (h, cache_out, aux)."""
    aux = jnp.zeros((), jnp.float32)
    local = sig.kind == "attn_local"
    if sig.kind in ("attn", "attn_local"):
        if mode == "step":
            if cfg.attention == "mla":
                h, c = attention.apply_mla_step(cfg, params["mixer"], h,
                                                cache=cache, cur_pos=cur_pos,
                                                ctx=ctx)
            else:
                h, c = attention.apply_gqa_step(cfg, params["mixer"], h,
                                                cache=cache, cur_pos=cur_pos,
                                                local=local, ctx=ctx)
        else:
            if cfg.attention == "mla":
                h, c = attention.apply_mla_full(cfg, params["mixer"], h,
                                                positions=positions, ctx=ctx,
                                                q_chunk=q_chunk)
            else:
                h, c = attention.apply_gqa_full(cfg, params["mixer"], h,
                                                positions=positions,
                                                local=local, ctx=ctx,
                                                q_chunk=q_chunk)
    elif sig.kind == "mamba":
        fn = ssm.apply_mamba_step if mode == "step" else ssm.apply_mamba_full
        h, c = fn(cfg, params["mixer"], h, cache=cache, ctx=ctx)
    elif sig.kind == "mlstm":
        fn = xlstm.apply_mlstm_step if mode == "step" else xlstm.apply_mlstm_full
        h, c = fn(cfg, params["mixer"], h, cache=cache, ctx=ctx)
    elif sig.kind == "slstm":
        fn = xlstm.apply_slstm_step if mode == "step" else xlstm.apply_slstm_full
        h, c = fn(cfg, params["mixer"], h, cache=cache, ctx=ctx)
    else:
        raise ValueError(sig.kind)

    if "ffn" in params:
        if sig.is_moe:
            h, aux = moe.apply_moe(cfg, params["ffn"], h, ctx=ctx)
        else:
            h = ffn.apply_ffn(cfg, params["ffn"], h, ctx=ctx)
    return h, c, aux


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(cfg, key):
    kg = KeyGen(key)
    dt = param_dtype(cfg)
    d, V = cfg.d_model, cfg.vocab_size
    if cfg.modality == "features":
        return {"w_in": dense_init(kg(), (FEATURE_DIM, d), dt, scale=0.1)}
    if cfg.modality == "audio_stub":
        return {"tok": dense_init(kg(), (cfg.num_codebooks, V, d), dt)}
    return {"tok": dense_init(kg(), (V, d), dt)}


def apply_embed(cfg, params, inputs, ctx=None):
    """Returns (h (B,S,d), positions (S,))."""
    if cfg.modality == "features":
        h = inputs["features"] @ params["w_in"]
    elif cfg.modality == "audio_stub":
        toks = inputs["tokens"]                  # (B, ncb, S)
        h = jnp.zeros((*toks.shape[::2], cfg.d_model), params["tok"].dtype)
        parts = [jnp.take(params["tok"][c], toks[:, c], axis=0)
                 for c in range(cfg.num_codebooks)]
        h = sum(parts)
    elif cfg.modality == "vision_stub":
        tok_emb = jnp.take(params["tok"], inputs["tokens"], axis=0)
        h = jnp.concatenate(
            [inputs["patch_embeds"].astype(tok_emb.dtype), tok_emb], axis=1)
    else:
        h = jnp.take(params["tok"], inputs["tokens"], axis=0)
    S = h.shape[1]
    if ctx is not None:
        h = shard(h, ctx, ctx.dp, None, None)
    return h, jnp.arange(S, dtype=jnp.int32)


def embed_one(cfg, params_embed, token, cur_pos):
    """Decode-time embedding of a single token. token: (B,) or (B,ncb);
    features modality: a (B, FEATURE_DIM) frame (or {"features": ...})."""
    if cfg.modality == "features":
        feats = token["features"] if isinstance(token, dict) else token
        return feats @ params_embed["w_in"]
    if cfg.modality == "audio_stub":
        parts = [jnp.take(params_embed["tok"][c], token[:, c], axis=0)
                 for c in range(cfg.num_codebooks)]
        return sum(parts)
    return jnp.take(params_embed["tok"], token, axis=0)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    kg = KeyGen(key)
    layouts = stage_layouts(cfg)
    stages = []
    for lay in layouts:
        sp: dict = {"prefix": [init_layer(cfg, layer_sig(cfg, i), kg())
                               for i in lay.prefix]}
        if lay.n_scan:
            periods = []
            for _ in range(lay.n_scan):
                periods.append(tuple(init_layer(cfg, s, kg())
                                     for s in lay.scan_sigs))
            sp["scan"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
        sp["tail"] = [init_layer(cfg, layer_sig(cfg, i), kg())
                      for i in lay.tail]
        stages.append(sp)
    params = {
        "embed": init_embed(cfg, kg()),
        "stages": stages,
        "exits": [exits.init_exit(cfg, kg()) for _ in layouts],
        "exit_shared": exits.init_exit(cfg, kg(), shared=True),
    }
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(kg(), (2 * cfg.d_model, cfg.d_model),
                               param_dtype(cfg)),
            "block": init_layer(cfg, Sig("attn", False), kg()),
            "exit": exits.init_exit(cfg, kg()),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExitsOut:
    logits: list               # per stage
    confidences: list          # per stage, (B,) or (B,S)
    aux: Any                   # router aux loss (scalar)
    h_final: Any
    caches: Optional[list]     # per stage: layer caches (prefill only)


jax.tree_util.register_dataclass(
    ExitsOut,
    data_fields=["logits", "confidences", "aux", "h_final", "caches"],
    meta_fields=[])


def _stage_apply_full(cfg, stage_params, lay: StageLayout, h, *, mode,
                      positions, ctx, collect_cache, q_chunk):
    aux_total = jnp.zeros((), jnp.float32)
    caches: dict = {"prefix": [], "scan": None, "tail": []}

    def one(idx, p, h):
        return apply_layer(cfg, layer_sig(cfg, idx), p, h, mode=mode,
                           positions=positions, ctx=ctx, q_chunk=q_chunk)

    for i, p in zip(lay.prefix, stage_params["prefix"]):
        h, c, aux = one(i, p, h)
        aux_total += aux
        caches["prefix"].append(c if collect_cache else None)

    if lay.n_scan:
        sigs = lay.scan_sigs

        def period_body(h, period_params):
            aux_p = jnp.zeros((), jnp.float32)
            cs = []
            hh = h
            for sig, p in zip(sigs, period_params):
                hh, c, aux = apply_layer(cfg, sig, p, hh, mode=mode,
                                         positions=positions, ctx=ctx,
                                         q_chunk=q_chunk)
                aux_p += aux
                cs.append(c if collect_cache else 0)
            return hh, (aux_p, tuple(cs))

        body = period_body
        if ctx is not None and ctx.remat and mode == "train":
            body = jax.checkpoint(period_body)

        def scan_body(carry, period_params):
            h, aux_acc = carry
            h, (aux_p, cs) = body(h, period_params)
            return (h, aux_acc + aux_p), cs

        (h, aux_total), scan_caches = jax.lax.scan(
            scan_body, (h, aux_total), stage_params["scan"])
        caches["scan"] = scan_caches if collect_cache else None

    for i, p in zip(lay.tail, stage_params["tail"]):
        h, c, aux = one(i, p, h)
        aux_total += aux
        caches["tail"].append(c if collect_cache else None)

    return h, aux_total, (caches if collect_cache else None)


def forward(cfg, params, inputs, *, ctx=None, mode="train", upto_stage=None,
            collect_cache=None, q_chunk=1024, conf_temperature=1.0,
            exit_last_only=False, aux_exit_stride=1):
    """Full-sequence forward through (up to) `upto_stage` stages.

    exit_last_only: compute exit heads on the final position only (prefill
    serving path — avoids materializing (B, S, V) logits per exit).
    aux_exit_stride: evaluate non-final exits every k-th position only
    (training FLOPs; see make_loss_fn)."""
    if collect_cache is None:
        collect_cache = mode == "prefill"
    layouts = stage_layouts(cfg)
    n_stages = len(layouts) if upto_stage is None else upto_stage
    h, positions = apply_embed(cfg, params["embed"], inputs, ctx)
    aux_total = jnp.zeros((), jnp.float32)
    logits_list, conf_list, cache_list = [], [], []
    for s in range(n_stages):
        h, aux, caches = _stage_apply_full(
            cfg, params["stages"][s], layouts[s], h, mode=mode,
            positions=positions, ctx=ctx, collect_cache=collect_cache,
            q_chunk=q_chunk)
        aux_total += aux
        h_exit = h
        if h.ndim == 3 and cfg.modality != "features":
            if exit_last_only:
                h_exit = h[:, -1:]
            elif (aux_exit_stride > 1 and s < n_stages - 1
                  and h.shape[1] % aux_exit_stride == 0):
                h_exit = h[:, ::aux_exit_stride]
        lg = exits.apply_exit(
            cfg, {**params["exits"][s], **params["exit_shared"]}, h_exit,
            ctx=ctx)
        logits_list.append(lg)
        conf = exits.confidence_from_logits(lg, conf_temperature)
        if conf.ndim > 1:   # reduce codebook axis for audio; keep (B,) / (B,S)
            while conf.ndim > 2:
                conf = conf.mean(-1)
        conf_list.append(conf)
        cache_list.append(caches)
    return ExitsOut(logits_list, conf_list, aux_total, h,
                    cache_list if collect_cache else None)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _layer_cache_struct(cfg, sig: Sig, batch, slots, dtype):
    hd = cfg.resolved_head_dim
    if sig.kind in ("attn", "attn_local"):
        if sig.kind == "attn_local" and cfg.sliding_window:
            slots_l = min(slots, cfg.sliding_window)
        else:
            slots_l = slots
        if cfg.attention == "mla":
            m = cfg.mla
            return {"latent": jnp.zeros((batch, slots_l, m.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, slots_l, m.qk_rope_head_dim), dtype),
                    "slot_pos": jnp.full((batch, slots_l), -1, jnp.int32)}
        return {"k": jnp.zeros((batch, slots_l, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, slots_l, cfg.num_kv_heads, hd), dtype),
                "slot_pos": jnp.full((batch, slots_l), -1, jnp.int32)}
    if sig.kind == "mamba":
        di = ssm.d_inner_of(cfg)
        return {"ssm_state": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
                "conv_state": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype)}
    if sig.kind == "mlstm":
        di = xlstm.mlstm_d_inner(cfg)
        return {"mlstm": xlstm.init_mlstm_state(cfg, batch),
                "conv_state": jnp.zeros((batch, 3, di), dtype)}
    if sig.kind == "slstm":
        return {"slstm": xlstm.init_slstm_state(cfg, batch)}
    raise ValueError(sig.kind)


def init_decode_cache(cfg, batch, slots, dtype=None):
    """Zero-initialized decode cache mirroring the stage/scan structure.

    `slots` = number of KV slots for full-attention layers; sliding-window
    layers allocate min(slots, window); the swa-8192 long-context variant
    passes slots=8192 for every full-attention layer.
    """
    dtype = dtype or param_dtype(cfg)
    layouts = stage_layouts(cfg)
    out = []
    for lay in layouts:
        st = {"prefix": [_layer_cache_struct(cfg, layer_sig(cfg, i), batch,
                                             slots, dtype)
                         for i in lay.prefix],
              "scan": None}
        if lay.n_scan:
            one_period = tuple(_layer_cache_struct(cfg, s, batch, slots, dtype)
                               for s in lay.scan_sigs)
            st["scan"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (lay.n_scan, *x.shape)).copy()
                if isinstance(x, jnp.ndarray) else x, one_period)
        st["tail"] = [_layer_cache_struct(cfg, layer_sig(cfg, i), batch,
                                          slots, dtype)
                      for i in lay.tail]
        out.append(st)
    return out


def slice_decode_cache(st_cache, i: int, j: int = None):
    """Rows [i:j) of one stage's decode cache (default the single row i).

    Structure-aware: prefix/tail layer caches carry batch on axis 0, scan
    caches are stacked over periods so batch sits on axis 1.  This is how
    a serving executor keeps per-request cache state while batching
    co-runners: slice rows out of a batched step, concat them back in
    (:func:`concat_decode_caches`) for the next dispatch.
    """
    j = i + 1 if j is None else j
    out = {"prefix": [jax.tree.map(lambda x: x[i:j], c)
                      for c in st_cache["prefix"]],
           "scan": None,
           "tail": [jax.tree.map(lambda x: x[i:j], c)
                    for c in st_cache["tail"]]}
    if st_cache["scan"] is not None:
        out["scan"] = jax.tree.map(lambda x: x[:, i:j], st_cache["scan"])
    return out


def concat_decode_caches(st_caches):
    """Concatenate same-stage decode caches along the batch axis (the
    inverse of :func:`slice_decode_cache`).  All members must share the
    same slot count — in serving terms, the same length bucket."""
    first = st_caches[0]
    cat = lambda axis: (lambda *xs: jnp.concatenate(xs, axis=axis))
    out = {"prefix": [jax.tree.map(cat(0), *[c["prefix"][k]
                                             for c in st_caches])
                      for k in range(len(first["prefix"]))],
           "scan": None,
           "tail": [jax.tree.map(cat(0), *[c["tail"][k]
                                           for c in st_caches])
                    for k in range(len(first["tail"]))]}
    if first["scan"] is not None:
        out["scan"] = jax.tree.map(cat(1), *[c["scan"] for c in st_caches])
    return out


def decode_step(cfg, params, cache, token, cur_pos, *, ctx=None,
                upto_stage=None, conf_temperature=1.0):
    """One decode step through (up to) `upto_stage` stages.

    token: (B,) int32 (or (B,ncb) audio); cur_pos: (B,) int32 positions.
    Returns (ExitsOut with last-position logits per stage, new_cache).
    """
    layouts = stage_layouts(cfg)
    n_stages = len(layouts) if upto_stage is None else upto_stage
    h = embed_one(cfg, params["embed"], token, cur_pos)      # (B, d)
    if ctx is not None:
        h = shard(h, ctx, ctx.dp, None)
    logits_list, conf_list = [], []
    new_cache = [None] * len(layouts)
    for s in range(n_stages):
        h, st_cache = _stage_decode(cfg, params["stages"][s], layouts[s],
                                    cache[s], h, cur_pos, ctx)
        new_cache[s] = st_cache
        lg = exits.apply_exit(
            cfg, {**params["exits"][s], **params["exit_shared"]},
            h[:, None], ctx=ctx)
        lg = lg[:, 0]                                        # (B, V) / (B,ncb,V)
        logits_list.append(lg)
        conf = exits.confidence_from_logits(lg, conf_temperature)
        while conf.ndim > 1:
            conf = conf.mean(-1)
        conf_list.append(conf)
    for s in range(n_stages, len(layouts)):
        new_cache[s] = cache[s]
    return ExitsOut(logits_list, conf_list, jnp.zeros((), jnp.float32),
                    h, None), new_cache


def _stage_decode(cfg, stage_params, lay: StageLayout, st_cache, h, cur_pos,
                  ctx):
    def one(idx, p, c, h):
        h, c_new, _ = apply_layer(cfg, layer_sig(cfg, idx), p, h, mode="step",
                                  cache=c, cur_pos=cur_pos, ctx=ctx)
        return h, c_new

    new_cache: dict = {"prefix": [], "scan": None, "tail": []}
    for i, p, c in zip(lay.prefix, stage_params["prefix"], st_cache["prefix"]):
        h, c_new = one(i, p, c, h)
        new_cache["prefix"].append(c_new)

    if lay.n_scan:
        sigs = lay.scan_sigs

        def scan_body(h, pc):
            period_params, period_cache = pc
            cs = []
            for sig, p, c in zip(sigs, period_params, period_cache):
                h, c_new, _ = apply_layer(cfg, sig, p, h, mode="step",
                                          cache=c, cur_pos=cur_pos, ctx=ctx)
                cs.append(c_new)
            return h, tuple(cs)

        h, scan_cache = jax.lax.scan(
            scan_body, h, (stage_params["scan"], st_cache["scan"]))
        new_cache["scan"] = scan_cache

    for i, p, c in zip(lay.tail, stage_params["tail"], st_cache["tail"]):
        h, c_new = one(i, p, c, h)
        new_cache["tail"].append(c_new)
    return h, new_cache


# ---------------------------------------------------------------------------
# stage-granular API (the scheduler's dispatch unit)
# ---------------------------------------------------------------------------

def stage_trunk(cfg, params, stage_idx: int, h_or_inputs, *, ctx=None,
                q_chunk=1024, mode="prefill"):
    """ONE stage's trunk (embed + blocks), *without* the exit head.

    stage 0 takes raw inputs (embeds them); later stages take hidden
    state.  Returns the stage-out hidden state (B, S, d).  This is the
    seam the kernel-backed stage fns build on: run the trunk here, then a
    fused exit epilogue (repro.models.exits.exit_stats_fused) instead of
    materializing the full logits tensor.
    """
    layouts = stage_layouts(cfg)
    lay = layouts[stage_idx]
    if stage_idx == 0:
        h, positions = apply_embed(cfg, params["embed"], h_or_inputs, ctx)
    else:
        h = h_or_inputs
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    h, _aux, _ = _stage_apply_full(cfg, params["stages"][stage_idx], lay, h,
                                   mode=mode, positions=positions, ctx=ctx,
                                   collect_cache=False, q_chunk=q_chunk)
    return h


def stage_forward(cfg, params, stage_idx: int, h_or_inputs, *, ctx=None,
                  q_chunk=1024, conf_temperature=1.0, mode="prefill"):
    """Run ONE stage (paper's non-preemptive unit) and its exit head.

    stage 0 takes raw inputs (embeds them); later stages take hidden state.
    Returns (h, logits, confidence).
    """
    h = stage_trunk(cfg, params, stage_idx, h_or_inputs, ctx=ctx,
                    q_chunk=q_chunk, mode=mode)
    lg = exits.apply_exit(
        cfg, {**params["exits"][stage_idx], **params["exit_shared"]}, h,
        ctx=ctx)
    conf = exits.confidence_from_logits(lg, conf_temperature)
    while conf.ndim > 1:
        conf = conf.mean(-1)
    return h, lg, conf


def stage_decode_step(cfg, params, stage_idx: int, st_cache, h, cur_pos, *,
                      ctx=None):
    """ONE stage of a decode step over its per-stage cache (the decode-mode
    dispatch unit: the serving engine holds per-request caches device-side
    and batches co-runners at the same stage through this function).

    stage 0 takes the raw token(s) (embeds them); later stages take hidden
    state.  ``st_cache`` is ``init_decode_cache(...)[stage_idx]``.  Routing
    ``ctx.decode_attn == "kernel"`` runs attention through the Pallas
    decode kernel, whose per-row slot_pos masking keeps ragged co-batched
    requests exact.  Returns (h, new_st_cache).
    """
    lay = stage_layouts(cfg)[stage_idx]
    if stage_idx == 0:
        h = embed_one(cfg, params["embed"], h, cur_pos)      # (B, d)
        if ctx is not None:
            h = shard(h, ctx, ctx.dp, None)
    return _stage_decode(cfg, params["stages"][stage_idx], lay, st_cache, h,
                         cur_pos, ctx)


# ---------------------------------------------------------------------------
# analytic parameter counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(int(math.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        if cfg.ffn_type == "relu2":
            per_expert = 2 * cfg.d_model * m.d_ff_expert
        total -= n_moe * (m.num_experts - m.top_k) * per_expert
    return total
