"""Mamba (S6 selective-scan) block, TPU-adapted.

The CUDA reference is a fused shared-memory scan kernel; the TPU-native
adaptation processes the sequence in chunks: an outer `lax.scan` carries the
(d_inner, d_state) SSM state across chunk boundaries while each chunk is
solved in parallel with an associative scan — bounding live memory to
O(chunk * d_inner * d_state) instead of O(S * d_inner * d_state).

Decode is the O(1) single-step recurrence on the carried state plus a ring of
the last (d_conv-1) inputs for the causal conv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import shard_residual, KeyGen, dense_init, param_dtype, rms_norm, shard

CHUNK = 256


def d_inner_of(cfg):
    return cfg.ssm_expand * cfg.d_model


def dt_rank_of(cfg):
    return max(1, -(-cfg.d_model // 16))


def init_mamba(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d = cfg.d_model
    di, ds, dc = d_inner_of(cfg), cfg.ssm_d_state, cfg.ssm_d_conv
    dtr = dt_rank_of(cfg)
    down_scale = 0.02 / max(1, cfg.num_layers) ** 0.5
    return {
        "ln": jnp.zeros((d,), dt),
        "in_proj": dense_init(kg(), (d, 2 * di), dt),
        "conv_w": dense_init(kg(), (dc, di), dt, scale=0.2),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(kg(), (di, dtr + 2 * ds), dt),
        "dt_proj": dense_init(kg(), (dtr, di), dt, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(kg(), (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(kg(), (di, d), dt, scale=down_scale),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: (B,S,di); w: (dc,di)."""
    dc = w.shape[0]
    out = x * w[-1]
    for j in range(1, dc):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j or None][:, :x.shape[1]]
        out = out + shifted * w[-1 - j]
    return out + b


def _ssm_inputs(cfg, params, xz):
    """Shared by full/step paths. xz: (..., 2*di) pre-activation of in_proj."""
    di, ds = d_inner_of(cfg), cfg.ssm_d_state
    dtr = dt_rank_of(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z


def _dt_B_C(cfg, params, x):
    ds = cfg.ssm_d_state
    dtr = dt_rank_of(cfg)
    dbc = x @ params["x_proj"]
    dt_low, B, C = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low.astype(jnp.float32) @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def _chunk_scan(a, bx, state0):
    """Linear recurrence s_t = a_t * s_{t-1} + bx_t over a chunk (parallel).

    a, bx: (L, B, di, ds) fp32; state0: (B, di, ds).  Returns (states, last).
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_all, b_all = jax.lax.associative_scan(combine, (a, bx), axis=0)
    states = a_all * state0[None] + b_all
    return states, states[-1]


def mamba_scan_full(cfg, x, dt, B, C, A, state0):
    """x: (Bb,S,di); dt: (Bb,S,di); B,C: (Bb,S,ds); A: (di,ds) (negative).

    Chunked: outer scan over S/CHUNK chunks, inner associative scan.
    Returns (y (Bb,S,di), final_state (Bb,di,ds)).
    """
    Bb, S, di = x.shape
    ds = B.shape[-1]
    chunk = min(CHUNK, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    def rs(t):  # (Bb,S,...) -> (n, chunk, Bb, ...)
        return t.reshape(Bb, n, chunk, *t.shape[2:]).transpose(1, 2, 0, *range(3, t.ndim + 1))

    xc, dtc, Bc, Cc = rs(x.astype(jnp.float32)), rs(dt), rs(B), rs(C)

    def body(state, inp):
        xk, dtk, Bk, Ck = inp                       # (chunk,Bb,...)
        a = jnp.exp(dtk[..., None] * A)             # (chunk,Bb,di,ds)
        bx = (dtk * xk)[..., None] * Bk[..., None, :]
        states, last = _chunk_scan(a, bx, state)
        yk = jnp.einsum("lbds,lbs->lbd", states, Ck)
        return last, yk

    final, ys = jax.lax.scan(body, state0, (xc, dtc, Bc, Cc))
    y = ys.transpose(2, 0, 1, 3).reshape(Bb, S, di)
    return y, final


def apply_mamba_full(cfg, params, x, *, ctx=None, **_):
    Bb, S, d = x.shape
    di, ds = d_inner_of(cfg), cfg.ssm_d_state
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    xz = h @ params["in_proj"]
    if ctx is not None:
        xz = shard(xz, ctx, ctx.dp, None, ctx.tp)
    xi, z = _ssm_inputs(cfg, params, xz)
    xi = _causal_conv(xi, params["conv_w"], params["conv_b"])
    xi = jax.nn.silu(xi)
    dt, B, C = _dt_B_C(cfg, params, xi)
    A = -jnp.exp(params["A_log"])
    state0 = jnp.zeros((Bb, di, ds), jnp.float32)
    y, final = mamba_scan_full(cfg, xi, dt, B, C, A, state0)
    y = (y + params["D"] * xi.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    out = shard_residual(out, ctx)
    # cache for subsequent decode: ssm state + conv tail
    conv_tail = xz[:, S - (cfg.ssm_d_conv - 1):, :di] if S >= cfg.ssm_d_conv - 1 else None
    cache = {"ssm_state": final,
             "conv_state": jax.lax.stop_gradient(
                 h[:, -(cfg.ssm_d_conv - 1):] @ params["in_proj"][:, :di])}
    return x + out, cache


def apply_mamba_step(cfg, params, x, *, cache, ctx=None, **_):
    """x: (Bb, d). cache: ssm_state (Bb,di,ds), conv_state (Bb,dc-1,di)."""
    Bb, d = x.shape
    di, ds, dc = d_inner_of(cfg), cfg.ssm_d_state, cfg.ssm_d_conv
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    xz = h @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    # causal conv over [conv_state ; xi]
    hist = jnp.concatenate([cache["conv_state"], xi[:, None]], 1)  # (Bb,dc,di)
    xi_c = jnp.einsum("bcd,cd->bd", hist[:, -dc:], params["conv_w"]) + params["conv_b"]
    xi_c = jax.nn.silu(xi_c)
    dt, B, C = _dt_B_C(cfg, params, xi_c)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A)                  # (Bb,di,ds)
    bx = (dt * xi_c.astype(jnp.float32))[..., None] * B[:, None, :]
    state = a * cache["ssm_state"] + bx
    y = jnp.einsum("bds,bs->bd", state, C)
    y = (y + params["D"] * xi_c.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = dict(cache, ssm_state=state, conv_state=hist[:, 1:])
    return x + out, new_cache
