"""Dense FFN sublayers: SwiGLU and squared-ReLU (Nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import shard_residual, KeyGen, dense_init, param_dtype, rms_norm, shard


def init_ffn(cfg, key, d_ff=None, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    down_scale = 0.02 / max(1, cfg.num_layers) ** 0.5
    p = {"ln": jnp.zeros((d,), dt),
         "w_up": dense_init(kg(), (d, f), dt),
         "w_down": dense_init(kg(), (f, d), dt, scale=down_scale)}
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = dense_init(kg(), (d, f), dt)
    return p


def ffn_core(cfg, params, h, ctx=None):
    """The projection stack without norm/residual (shared with MoE experts)."""
    if cfg.ffn_type == "swiglu":
        a = jax.nn.silu(h @ params["w_gate"]) * (h @ params["w_up"])
    elif cfg.ffn_type == "relu2":
        a = jnp.square(jax.nn.relu(h @ params["w_up"]))
    else:
        raise ValueError(cfg.ffn_type)
    if ctx is not None:
        lead = (ctx.dp,) + (None,) * (a.ndim - 2)
        a = shard(a, ctx, *lead, ctx.tp)
    return a @ params["w_down"]


def apply_ffn(cfg, params, x, *, ctx=None):
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    y = ffn_core(cfg, params, h, ctx)
    if y.ndim == 3:
        y = shard_residual(y, ctx)
    return x + y
