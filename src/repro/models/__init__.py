from repro.models.common import ParallelCtx
from repro.models.exits import exit_rows, exit_stats_fused, exit_stats_unfused
from repro.models.model import (
    ExitsOut,
    concat_decode_caches,
    count_params_analytic,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    slice_decode_cache,
    stage_decode_step,
    stage_forward,
    stage_layouts,
    stage_trunk,
)

__all__ = [
    "ParallelCtx", "ExitsOut", "concat_decode_caches",
    "count_params_analytic", "decode_step",
    "exit_rows", "exit_stats_fused", "exit_stats_unfused",
    "forward", "init_decode_cache", "init_params", "slice_decode_cache",
    "stage_decode_step", "stage_forward", "stage_layouts", "stage_trunk",
]
