from repro.models.common import ParallelCtx
from repro.models.model import (
    ExitsOut,
    count_params_analytic,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    stage_forward,
    stage_layouts,
)

__all__ = [
    "ParallelCtx", "ExitsOut", "count_params_analytic", "decode_step",
    "forward", "init_decode_cache", "init_params", "stage_forward",
    "stage_layouts",
]
