"""Distributed flash-decode: one-token attention over a sequence-sharded cache.

The KV cache for decode shapes is sharded along its *sequence* dimension over
``ctx.seq_axes`` (``('model',)`` for decode_32k; ``('data','model')`` for
long_500k where batch=1 cannot use the data axis).  Each shard computes a
partial attention (unnormalized accumulator + running max m + normalizer l)
over its local slots, then shards combine with the standard flash logsumexp
merge via pmax/psum — no shard ever materializes the full cache.

This is what makes a half-megatoken cache fit per device; GSPMD's automatic
alternative is an all-gather of the whole cache (measured in §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import shard_map

NEG_INF = -1e30


def _partial_attend(q, k, v, slot_pos, cur_pos, window, softmax_scale):
    """Local partial attention.

    q: (B,KV,G,hd); k,v: (B,S_loc,KV,hd); slot_pos: (B,S_loc); cur_pos: (B,).
    Returns (acc, m, l): acc (B,KV,G,hd) unnormalized, m/l (B,KV,G).
    """
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k).astype(jnp.float32)
    scores = scores * softmax_scale
    valid = (slot_pos <= cur_pos[:, None]) & (slot_pos >= 0)
    if window is not None:
        valid &= cur_pos[:, None] - slot_pos < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                           # (B,KV,G)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v).astype(jnp.float32)
    return acc, m, l


def flash_decode(q, k_cache, v_cache, slot_pos, cur_pos, *, window,
                 softmax_scale, ctx, shard_kv_heads: bool = True,
                 use_kernel: bool = False):
    """q: (B,KV,G,hd); caches: (B,S,KV,hd); slot_pos: (B,S); cur_pos: (B,).

    ``use_kernel`` routes the unsharded (ctx is None) case through the
    Pallas decode kernel (repro.kernels.decode_attention) — the kernel is
    exactly this function's intra-shard partial, so the two paths agree up
    to reduction order."""
    del shard_kv_heads  # KV heads stay replicated in this scheme
    if ctx is None:
        if use_kernel:
            from repro.kernels.decode_attention.kernel import decode_attention
            B, KV, G, hd = q.shape
            out = decode_attention(q.reshape(B, KV * G, hd),
                                   k_cache.transpose(0, 2, 1, 3),
                                   v_cache.transpose(0, 2, 1, 3),
                                   slot_pos, cur_pos, window=window,
                                   softmax_scale=softmax_scale)
            return out.reshape(B, KV, G, hd)
        acc, m, l = _partial_attend(q, k_cache, v_cache, slot_pos, cur_pos,
                                    window, softmax_scale)
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    seq = ctx.seq_axes
    dp = tuple(a for a in ctx.dp if a not in seq)
    bspec = dp if dp else None

    def body(q_, k_, v_, sp_, cp_):
        acc, m, l = _partial_attend(q_, k_, v_, sp_, cp_, window, softmax_scale)
        m_g = jax.lax.pmax(m, seq)
        scale = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * scale, seq)
        acc_g = jax.lax.psum(acc * scale[..., None], seq)
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q_.dtype)

    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, seq, None, None),
                  P(bspec, seq, None, None),
                  P(bspec, seq),
                  P(bspec)),
        out_specs=P(bspec, None, None, None),
    )(q, k_cache, v_cache, slot_pos, cur_pos)
