"""Mixture-of-Experts sublayer with expert parallelism.

Baseline impl ("gather"): capacity-bounded sort-based dispatch under GSPMD —
tokens are ranked within their expert via an argsort (no T×E×C one-hot
einsums), gathered into an (E, C, d) buffer, pushed through the stacked expert
FFNs (experts sharded over the 'model' axis = expert parallelism), and
scatter-added back weighted by their gates.

Optimized impl ("alltoall"): shard_map version where each data shard routes
locally and exchanges expert buffers with an explicit all_to_all over the
expert-parallel axis (see EXPERIMENTS.md §Perf).

Routing: softmax router, top-k, renormalized gates, Switch-style load-balance
auxiliary loss.  Over-capacity tokens are dropped (capacity_factor bounds the
buffer, as in GShard/Switch).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import shard_residual, KeyGen, dense_init, param_dtype, rms_norm, shard, shard_map
from repro.models.ffn import ffn_core, init_ffn


def init_moe(cfg, key, dtype=None):
    kg = KeyGen(key)
    dt = dtype or param_dtype(cfg)
    m = cfg.moe
    d, fe, E = cfg.d_model, m.d_ff_expert, m.num_experts
    down_scale = 0.02 / max(1, cfg.num_layers) ** 0.5
    p = {
        "ln": jnp.zeros((d,), dt),
        "router": dense_init(kg(), (d, E), jnp.float32),
        "we_gate": dense_init(kg(), (E, d, fe), dt),
        "we_up": dense_init(kg(), (E, d, fe), dt),
        "we_down": dense_init(kg(), (E, fe, d), dt, scale=down_scale),
    }
    if m.num_shared_experts:
        p["shared"] = init_ffn(cfg, kg(), d_ff=fe * m.num_shared_experts,
                               dtype=dt)
        p["shared"].pop("ln")  # shares the MoE layernorm
    return p


def _route(cfg, logits):
    """top-k routing. logits: (T, E) fp32 -> gates (T,k), idx (T,k), aux."""
    m = cfg.moe
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e f_e * p_e
    T = logits.shape[0]
    f = jnp.zeros((m.num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / (T * m.top_k)
    pbar = probs.mean(0)
    aux = m.num_experts * jnp.sum(f * pbar)
    return gates, idx, aux


def _capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    c = -(-int(n_tokens * m.top_k * m.capacity_factor) // m.num_experts)
    c = max(1, c)
    if c > 8:
        c = -(-c // 4) * 4             # align larger buffers
    # never more slots than assignments exist
    return min(c, n_tokens * m.top_k)


def _dispatch_tables(cfg, idx, n_tokens: int, capacity: int):
    """Sort-based rank-in-expert; returns (dispatch_idx (E,C), slot_gatepos).

    dispatch_idx[e, c] = flat token index filling slot c of expert e (or
    n_tokens = sentinel padding row).  slot_assign[e, c] = index into the
    flattened (T*k) assignment list (or -1) used to fetch gates.
    """
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    TK = n_tokens * k
    a = idx.reshape(TK)                                   # expert of each assignment
    order = jnp.argsort(a)                                # stable
    a_sorted = a[order]
    start = jnp.searchsorted(a_sorted, jnp.arange(E))     # first pos of each expert
    rank_sorted = jnp.arange(TK) - start[a_sorted]        # rank within expert
    keep = rank_sorted < capacity
    # scatter into (E, C) tables
    flat_slot = a_sorted * capacity + rank_sorted
    flat_slot = jnp.where(keep, flat_slot, E * capacity)  # dropped -> overflow row
    token_of_assign = order // k
    dispatch = jnp.full((E * capacity + 1,), n_tokens, jnp.int32)
    dispatch = dispatch.at[flat_slot].set(token_of_assign.astype(jnp.int32),
                                          mode="drop")
    assign_of_slot = jnp.full((E * capacity + 1,), -1, jnp.int32)
    assign_of_slot = assign_of_slot.at[flat_slot].set(order.astype(jnp.int32),
                                                      mode="drop")
    return (dispatch[:-1].reshape(E, capacity),
            assign_of_slot[:-1].reshape(E, capacity))


def _expert_ffn(cfg, params, xd):
    """xd: (E, C, d) -> (E, C, d) through stacked expert FFNs."""
    if cfg.ffn_type == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xd, params["we_up"])))
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xd, params["we_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xd, params["we_up"])
    return jnp.einsum("ecf,efd->ecd", h, params["we_down"])


def moe_gather(cfg, params, h2, ctx):
    """GSPMD-auto dispatch. h2: (T, d) -> (y (T, d), aux)."""
    T, d = h2.shape
    cap = _capacity(cfg, T)
    logits = h2.astype(jnp.float32) @ params["router"]
    gates, idx, aux = _route(cfg, logits)
    dispatch, assign_of_slot = _dispatch_tables(cfg, idx, T, cap)

    h_pad = jnp.concatenate([h2, jnp.zeros((1, d), h2.dtype)], 0)
    xd = h_pad[dispatch]                                  # (E, C, d)
    if ctx is not None:
        xd = shard(xd, ctx, ctx.tp, None, None)
    yd = _expert_ffn(cfg, params, xd)                     # (E, C, d)

    gate_flat = gates.reshape(-1)
    slot_gate = jnp.where(assign_of_slot >= 0,
                          gate_flat[jnp.clip(assign_of_slot, 0)], 0.0)
    y = jnp.zeros((T + 1, d), jnp.float32)
    y = y.at[dispatch.reshape(-1)].add(
        (yd * slot_gate[..., None].astype(yd.dtype)).reshape(-1, d)
        .astype(jnp.float32))
    return y[:-1].astype(h2.dtype), aux


def alltoall_ep_axes(cfg, mesh, dp):
    """Data axes carrying expert parallelism for the all_to_all MoE: the
    largest suffix of dp whose product divides num_experts."""
    E = cfg.moe.num_experts
    for start in range(len(dp)):
        axes = dp[start:]
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and E % size == 0:
            return axes
    return ()


def moe_alltoall(cfg, params, h2, ctx):
    """shard_map expert-parallel MoE: EP over the data axes, TP over the
    model axis, explicit all_to_all dispatch/combine (DeepSpeed-MoE-style
    EP x TP hybrid — the production layout).

    Tokens are sharded over dp (replicated over tp).  Experts live E-major
    on the EP axes with their FFN width sharded over tp.  Each data shard
    routes its local tokens, all_to_all's the (E, C_loc, d) dispatch buffer
    over the EP axes so every shard receives exactly its own experts' slots,
    runs the row/column-parallel expert FFN (psum over tp), and reverses the
    exchange.  Per-device collective volume is O(T_loc * k * cf * d) —
    independent of the global token count — versus the GSPMD gather
    baseline's full-token-buffer rematerializations (see EXPERIMENTS.md
    §Perf).
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = ctx.mesh
    tp, dp = ctx.tp, ctx.dp
    E = m.num_experts
    ep = alltoall_ep_axes(cfg, mesh, dp)
    if not ep:                                # no divisible EP axis: fall back
        return moe_gather(cfg, params, h2, ctx)
    ep_size = 1
    for a in ep:
        ep_size *= mesh.shape[a]
    E_loc = E // ep_size
    T, d = h2.shape
    fe = m.d_ff_expert
    tp_size = mesh.shape[tp]
    fe_tp = tp if fe % tp_size == 0 else None

    router = params["router"]
    we = {k_: params[k_] for k_ in ("we_gate", "we_up", "we_down")
          if k_ in params}

    def body(h_loc, router_, we_loc):
        Tl = h_loc.shape[0]
        cap = _capacity(cfg, Tl)
        logits = h_loc.astype(jnp.float32) @ router_
        gates, idx, aux = _route(cfg, logits)
        dispatch, assign_of_slot = _dispatch_tables(cfg, idx, Tl, cap)
        h_pad = jnp.concatenate([h_loc, jnp.zeros((1, d), h_loc.dtype)], 0)
        xd = h_pad[dispatch]                      # (E, cap, d), E-major by EP
        # dispatch: shard i keeps experts [i*E_loc, (i+1)*E_loc); receives
        # the matching slice from every peer along its slot axis
        xd = xd.astype(h_loc.dtype)               # keep exchanges in bf16
        xr = jax.lax.all_to_all(xd, ep, split_axis=0, concat_axis=1,
                                tiled=True)       # (E_loc, ep*cap, d)
        yr = _expert_ffn(cfg, we_loc, xr).astype(h_loc.dtype)
        if fe_tp is not None:
            yr = jax.lax.psum(yr, tp)             # row-parallel down-proj
        yd = jax.lax.all_to_all(yr, ep, split_axis=1, concat_axis=0,
                                tiled=True)       # (E, cap, d)
        gate_flat = gates.reshape(-1)
        slot_gate = jnp.where(assign_of_slot >= 0,
                              gate_flat[jnp.clip(assign_of_slot, 0)], 0.0)
        y = jnp.zeros((Tl + 1, d), jnp.float32)
        y = y.at[dispatch.reshape(-1)].add(
            (yd * slot_gate[..., None].astype(yd.dtype))
            .reshape(-1, d).astype(jnp.float32))
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y[:-1].astype(h_loc.dtype), aux

    gate_spec = P(ep, None, fe_tp)                # we_gate/we_up (E, d, fe)
    down_spec = P(ep, fe_tp, None)                # we_down (E, fe, d)
    we_specs = {k_: (down_spec if k_ == "we_down" else gate_spec)
                for k_ in we}
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp if dp else None, None), P(None, None), we_specs),
        out_specs=(P(dp if dp else None, None), P()),
    )(h2, router, we)
    return y, aux


def apply_moe(cfg, params, x, *, ctx=None):
    """x: (B, S, d) or (T, d). Returns (y, aux_loss)."""
    m = cfg.moe
    orig_shape = x.shape
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    h2 = h.reshape(-1, orig_shape[-1])
    if ctx is not None and ctx.moe_impl == "alltoall":
        y2, aux = moe_alltoall(cfg, params, h2, ctx)
    else:
        y2, aux = moe_gather(cfg, params, h2, ctx)
    if m.num_shared_experts:
        y2 = y2 + ffn_core(cfg, dict(params["shared"]), h2, ctx)
    y = y2.reshape(orig_shape)
    if y.ndim == 3:
        y = shard_residual(y, ctx)
    return x + y, aux * m.router_aux_weight
