"""Shared model utilities: norms, RoPE, init, parallel context, sharding."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax < 0.6 compat: shard_map graduated from jax.experimental
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                              # pragma: no cover
    from jax.experimental.shard_map import shard_map


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Distribution context threaded through model apply functions.

    dp: mesh axis name(s) carrying the batch (tuple — ('pod','data') multi-pod).
    tp: mesh axis name carrying tensor/expert/head parallelism.
    seq_axes: axes over which decode KV caches are sequence-sharded.
    """
    mesh: object
    dp: tuple = ("data",)
    tp: str = "model"
    seq_axes: tuple = ("model",)
    # feature toggles (hillclimbing knobs; see EXPERIMENTS.md §Perf)
    moe_impl: str = "gather"          # gather | alltoall
    decode_attn: str = "flash_decode"  # flash_decode | kernel | naive
    attn_impl: str = "grouped"        # grouped | flat (§Perf iteration 1:
                                      # flat repeats KV->H so the head axis
                                      # shards evenly over tp, killing GSPMD
                                      # involuntary full remats when KV < tp)
    seq_parallel: bool = False        # §Perf iteration 2: residual stream
                                      # sequence-sharded over tp between
                                      # blocks -> row-parallel psums become
                                      # reduce-scatters (Megatron-SP)
    remat: bool = True

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp]


def shard(x, ctx: Optional[ParallelCtx], *spec):
    """Apply a sharding constraint if running distributed."""
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(*spec)))


def shard_residual(x, ctx: Optional[ParallelCtx], name: Optional[str] = None):
    """Constraint for the residual stream (B, S, d) between blocks:
    sequence-sharded over tp when seq_parallel (full mode only).

    §Perf iteration 4: (a) an optimization barrier pins the bf16 dtype at
    the block output so XLA cannot hoist the fp32 convert of the next
    norm above the row-parallel all-reduce (halves its volume); (b) a
    checkpoint_name makes the psum'd output saveable across remat so the
    backward does not re-execute the all-reduce.
    """
    if ctx is None:
        return x
    if name is not None:
        from jax.ad_checkpoint import checkpoint_name
        x = jax.lax.optimization_barrier(x)
        x = checkpoint_name(x, name)
    if x.ndim == 3 and ctx.seq_parallel and x.shape[1] % ctx.tp_size == 0:
        return shard(x, ctx, ctx.dp, ctx.tp, None)
    if x.ndim == 3:
        return shard(x, ctx, ctx.dp, None, None)
    return shard(x, ctx, ctx.dp, None)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rms_norm_head(x, scale, eps: float = 1e-6):
    """Per-head qk-norm: normalize over the trailing head_dim."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


class KeyGen:
    """Split keys on demand (keeps init code linear)."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def param_dtype(cfg):
    return jnp.dtype(cfg.dtype)
