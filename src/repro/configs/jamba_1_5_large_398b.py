"""Jamba-1.5-Large (398B hybrid: Mamba + attention 7:1, MoE 16e top-2 every 2).
[arXiv:2403.19887]"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="[arXiv:2403.19887]",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,          # GQA on the attention layers
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    # 8-layer Jamba period: attention at position 4, Mamba elsewhere (1:7)
    period=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    ffn_type="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  moe_every=2, moe_offset=1),
    ssm_d_state=16,
    ssm_expand=2,
    ssm_d_conv=4,
    rope_theta=1e4,
))
