"""xLSTM-1.3B (sLSTM + mLSTM blocks, 7:1 mLSTM:sLSTM). [arXiv:2405.04517]

No FFN sublayer: xLSTM blocks carry their own up/down projections
(pre-up-projection mLSTM, post-up-projection sLSTM per the paper).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="[arXiv:2405.04517]",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,                  # blocks carry their own projections
    vocab_size=50304,
    period=("mlstm",) * 7 + ("slstm",),
    ffn_type="none",
))
