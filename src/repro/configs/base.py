"""Model configuration system.

Every assigned architecture is a `ModelConfig` constructed in its own module
under `repro.configs`, registered by id.  `reduced()` derives the CPU-smoke
variant of the same family (>=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Optional, Sequence


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # layers whose index % moe_every == moe_offset are MoE layers
    moe_every: int = 1
    moe_offset: int = 0
    first_dense_layers: int = 0  # deepseek: first k layers stay dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    source: str               # citation bracket from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads

    # --- block pattern -------------------------------------------------
    # one period of block kinds; tiled to cover num_layers (remainder kept
    # as an explicit tail).  kinds: "attn", "attn_local", "mamba", "mlstm",
    # "slstm".
    period: Sequence[str] = ("attn",)

    # --- attention ------------------------------------------------------
    attention: str = "gqa"                  # gqa | mla
    qk_norm: bool = False
    causal: bool = True                     # False: bidirectional (classifier)
    sliding_window: Optional[int] = None    # window for "attn_local" blocks
    rope_theta: float = 1e4
    mla: Optional[MLAConfig] = None

    # --- ffn --------------------------------------------------------------
    ffn_type: str = "swiglu"                # swiglu | relu2 | none
    moe: Optional[MoEConfig] = None

    # --- ssm / xlstm --------------------------------------------------------
    ssm_d_state: int = 16
    ssm_expand: int = 2
    ssm_d_conv: int = 4

    # --- anytime / imprecise-computation structure (the paper) -----------
    num_stages: int = 3
    mandatory_stages: int = 1
    # optional explicit stage ends (layer idx, exclusive); default: uniform
    stage_ends: Optional[tuple] = None

    # --- modality stubs ---------------------------------------------------
    modality: str = "text"                  # text | vision_stub | audio_stub
    num_codebooks: int = 1                  # musicgen: 4 EnCodec codebooks
    num_patches: int = 0                    # vlm: patch-embedding prefix len
    mtp: bool = False                       # deepseek multi-token prediction

    # --- numerics / misc ---------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"                 # compute/param dtype for big runs

    # ----------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def layer_kinds(self) -> tuple:
        """Expand the period over num_layers."""
        p = tuple(self.period)
        reps = self.num_layers // len(p)
        tail = self.num_layers - reps * len(p)
        return p * reps + p[:tail]

    def is_moe_layer(self, idx: int) -> bool:
        m = self.moe
        if m is None:
            return False
        if idx < m.first_dense_layers:
            return False
        return idx % m.moe_every == m.moe_offset

    def stage_boundaries(self) -> tuple:
        """Layer index (exclusive) ending each stage, rounded to period size."""
        if self.stage_ends is not None:
            return tuple(self.stage_ends)
        p = len(self.period)
        per = max(1, round(self.num_layers / self.num_stages / p)) * p
        bounds = []
        for s in range(1, self.num_stages):
            bounds.append(min(s * per, self.num_layers))
        bounds.append(self.num_layers)
        # dedupe while preserving order (tiny configs)
        out, seen = [], set()
        for b in bounds:
            if b not in seen and b > 0:
                out.append(b); seen.add(b)
        return tuple(out)

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant of the same family."""
        p = tuple(dict.fromkeys(self.period))  # one of each distinct kind
        n_layers = max(2, len(p)) * 2 if len(p) > 1 else 2
        d_model = min(self.d_model, 256)
        heads = 4
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else heads
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64,
            d_ff=256 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            period=p,
            moe=moe,
            mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                          qk_rope_head_dim=16, v_head_dim=32) if self.mla else None,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            num_patches=min(self.num_patches, 16) if self.num_patches else 0,
            num_stages=min(self.num_stages, 2) if n_layers < 3 else self.num_stages,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


ARCH_IDS = (
    "mistral-large-123b",
    "deepseek-v3-671b",
    "nemotron-4-340b",
    "pixtral-12b",
    "qwen3-4b",
    "xlstm-1.3b",
    "gemma3-4b",
    "musicgen-medium",
    "jamba-1.5-large-398b",
    "kimi-k2-1t-a32b",
)

_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "nemotron-4-340b": "nemotron_4_340b",
    "pixtral-12b": "pixtral_12b",
    "qwen3-4b": "qwen3_4b",
    "xlstm-1.3b": "xlstm_1_3b",
    "gemma3-4b": "gemma3_4b",
    "musicgen-medium": "musicgen_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "anytime-classifier": "anytime_classifier",
}


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = _MODULES.get(name)
        if mod is None:
            raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_arch_ids() -> tuple:
    return ARCH_IDS
