"""Qwen3-4B (dense, GQA, qk-norm). [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b",
    arch_type="dense",
    source="[hf:Qwen/Qwen3-8B]",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    period=("attn",),
    ffn_type="swiglu",
    qk_norm=True,            # per-head RMSNorm on q and k
    rope_theta=1e6,
))
