"""The paper's own workload analog: a small anytime classifier.

The paper trains a 3-stage ResNet on CIFAR-10/ImageNet with an exit head per
stage.  Offline-container analog: a compact transformer classifier over
synthetic difficulty-varying feature sequences (repro.training.data), with the
identical 3-stage + exit-head + confidence structure.  vocab_size = number of
classes; modality "features" feeds continuous feature vectors through a linear
embed.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="anytime-classifier",
    arch_type="dense",
    source="[paper:RTDeepIoT §III-A analog]",
    num_layers=6,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=10,           # classes
    period=("attn",),
    ffn_type="swiglu",
    modality="features",
    causal=False,           # bidirectional encoder for classification
    num_stages=3,
    mandatory_stages=1,
    # anytime stages of 1/2/3 layers: pointer-chase reach doubles per layer,
    # so stage depth maps to solvable chain length (the paper's "complex
    # images need more layers" premise, made structural)
    stage_ends=(1, 3, 6),
    dtype="float32",
))
