"""MusicGen-medium (decoder-only over EnCodec tokens, 4 codebooks).
[arXiv:2306.05284]

The EnCodec conv codec is a STUB per the assignment carve-out: inputs are
codebook token ids (4 parallel streams, delay pattern applied upstream);
embeddings of the 4 codebooks are summed; each exit head carries 4 parallel
classifier heads (one per codebook) and confidence is their mean.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="[arXiv:2306.05284]",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,         # MHA (kv == q heads)
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,         # per-codebook EnCodec vocabulary
    period=("attn",),
    ffn_type="swiglu",
    rope_theta=1e4,
    modality="audio_stub",
    num_codebooks=4,
))
