"""Nemotron-4 340B (dense, GQA, squared-ReLU FFN). [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    source="[arXiv:2402.16819]",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,          # GQA
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    period=("attn",),
    ffn_type="relu2",        # squared-ReLU per the Nemotron-4 report
    rope_theta=1e4,
))
