"""Pixtral-12B (VLM: pixtral-ViT frontend STUB + mistral-nemo decoder).
[hf:mistralai/Pixtral-12B-2409]

Per the assignment carve-out, the vision encoder is a stub: input_specs()
provides precomputed patch embeddings of shape (batch, num_patches, d_model);
this config is the language decoder that consumes them.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    source="[hf:mistralai/Pixtral-12B-2409]",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    period=("attn",),
    ffn_type="swiglu",
    rope_theta=1e6,
    modality="vision_stub",
    num_patches=1024,        # patch-embedding prefix provided by the stub
))
