from repro.configs.base import (
    ARCH_IDS,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    all_arch_ids,
    get_config,
    register,
)
from repro.configs.shapes import SHAPES, InputShape, get_shape

__all__ = [
    "ARCH_IDS", "MLAConfig", "MoEConfig", "ModelConfig", "all_arch_ids",
    "get_config", "register", "SHAPES", "InputShape", "get_shape",
]
