"""Mistral Large 2 (123B dense). [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    arch_type="dense",
    source="[hf:mistralai/Mistral-Large-Instruct-2407]",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,          # GQA
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    period=("attn",),
    ffn_type="swiglu",
    rope_theta=1e6,
))
