"""DeepSeek-V3 (671B MoE: MLA, 1 shared + 256 routed top-8, MTP).
[arXiv:2412.19437]

Assignment lists d_ff=2048 = the *routed expert* intermediate size, honored in
MoEConfig.d_ff_expert.  The first 3 layers are dense with the model's dense
intermediate size 18432 (paper Table 1).
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="[arXiv:2412.19437]",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: latent KV, heads materialized per-query
    head_dim=128,
    d_ff=18432,              # dense layers (first 3)
    vocab_size=129280,
    period=("attn",),
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    ffn_type="swiglu",
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, moe_every=1, moe_offset=0,
                  first_dense_layers=3),
    mtp=True,                # one-depth multi-token-prediction head
    rope_theta=1e4,
))
