"""Kimi K2 (1T total / 32B active MoE, 384 experts top-8, GQA).
[arXiv:2501.kimi2]

Assignment lists d_ff=2048 = routed-expert intermediate size (MoEConfig);
the single leading dense layer uses the dense intermediate 18432.
"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="[arXiv:2501.kimi2]",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,          # GQA (Kimi K2 reduces heads vs DeepSeek-V3)
    head_dim=128,
    d_ff=18432,              # dense layer(s)
    vocab_size=163840,
    period=("attn",),
    ffn_type="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, moe_every=1, moe_offset=0,
                  first_dense_layers=1),
    rope_theta=5e4,
))
