"""Gemma-3 4B (dense, 5 local(sliding-window 1024) : 1 global, 128k ctx).
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    source="[hf:google/gemma-3-1b-pt]",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,          # GQA
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    period=("attn_local",) * 5 + ("attn",),   # 5:1 local:global
    sliding_window=1024,
    ffn_type="swiglu",
    qk_norm=True,
    rope_theta=1e6,
))
