"""Jitted public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention_op(q, k_cache, v_cache, slot_pos, cur_pos, *,
                        window=None, block_k=256, interpret=True):
    return decode_attention(q, k_cache, v_cache, slot_pos, cur_pos,
                            window=window, block_k=block_k,
                            interpret=interpret)
