"""Pure-jnp oracle for the decode-attention kernel (mirrors
repro.models.attention.decode_attend semantics with per-batch slot_pos)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, slot_pos, cur_pos, *,
                         window=None, softmax_scale=None):
    """q: (B,H,dh); caches: (B,KV,S,dh); slot_pos: (B,S); cur_pos: (B,)."""
    B, H, dh = q.shape
    KV = k_cache.shape[1]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window is not None:
        valid &= cur_pos[:, None] - slot_pos < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, dh).astype(q.dtype)
