"""Pallas TPU flash-decode kernel: one query token vs. a (ring) KV cache.

Grid: (batch * q_heads, n_kv_blocks) with the kv axis innermost; running
(m, l, acc) scratch implements the online softmax.  Slot validity uses the
cache's slot_pos array (ring caches store non-monotonic positions), matching
repro.models.flash_decode's per-shard partial — this kernel is the
*intra-shard* compute of the distributed flash-decode: on a real pod each
model-parallel shard runs this kernel over its local cache slice and the
(m, l) combine crosses shards via psum/pmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, sp_ref, cp_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, softmax_scale, window,
                   block_k, n_kv_blocks):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                # (1, dh)
    k = k_ref[0].astype(jnp.float32)                # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * softmax_scale                           # (1, bk)
    slot_pos = sp_ref[0]                            # (bk,)
    cur = cp_ref[0]
    valid = (slot_pos >= 0) & (slot_pos <= cur)
    if window is not None:
        valid &= cur - slot_pos < window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, slot_pos, cur_pos, *, window=None,
                     softmax_scale=None, block_k: int = 256,
                     interpret: bool = True):
    """q: (B, H, dh); caches: (B, KV, S, dh); slot_pos: (B, S); cur_pos: (B,).

    Returns (B, H, dh).
    """
    B, H, dh = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    block_k = min(block_k, S)
    Sp = -(-S // block_k) * block_k
    if Sp != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, Sp - S)),
                           constant_values=-1)
    nk = Sp // block_k

    kernel = functools.partial(_decode_kernel, softmax_scale=scale,
                               window=window, block_k=block_k,
                               n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, ik, G=G, KV=KV, H=H:
                         ((bh // H) * KV + (bh % H) // G, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, ik, G=G, KV=KV, H=H:
                         ((bh // H) * KV + (bh % H) // G, ik, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ik, H=H: (bh // H, ik)),
            pl.BlockSpec((1,), lambda bh, ik, H=H: (bh // H,)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * H, 1, dh),
      k_cache.reshape(B * KV, Sp, dh),
      v_cache.reshape(B * KV, Sp, dh),
      slot_pos, cur_pos)
    return out.reshape(B, H, dh)
