"""Pallas TPU flash-attention (prefill) kernel.

Blocked online-softmax attention with GQA head mapping done in the BlockSpec
index maps (query head h reads KV head h // group_size — no repeated KV in
HBM).  Causal and sliding-window masking; fp32 accumulation in VMEM scratch.

Grid: (batch * q_heads, n_q_blocks, n_kv_blocks), kv dimension innermost
("arbitrary") so the (m, l, acc) running state lives in scratch across kv
steps.  Fully-masked kv blocks are skipped via @pl.when — causal prefill
does ~half the work, sliding-window layers touch only blocks inside the
window (the TPU analog of the paper's GPU-side layer-size tuning: block
shapes are chosen so q/k tiles and the fp32 accumulator fit VMEM with
128-aligned MXU dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 softmax_scale, block_q, block_k, seq_len, causal, window,
                 n_kv_blocks):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # static block-level skip: block fully above the diagonal / out of window
    def live_block():
        q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * softmax_scale
        mask = k_pos < seq_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dh)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window is not None:
        first_q = iq * block_q
        last_q = first_q + block_q - 1
        first_k = ik * block_k
        cond = jnp.asarray(True)
        if causal:
            cond &= first_k <= last_q
        if window is not None:
            last_k = first_k + block_k - 1
            cond &= first_q - last_k < window
        pl.when(cond)(live_block)
    else:
        live_block()

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softmax_scale=None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, H, S, dh); k, v: (B, KV, S, dh).  Returns (B, H, S, dh).

    H must be a multiple of KV (GQA).  S is padded internally to block size.
    """
    B, H, S, dh = q.shape
    KV = k.shape[1]
    assert H % KV == 0
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(S, 8))
    Sp = -(-S // max(block_q, block_k)) * max(block_q, block_k)
    if Sp != S:
        pad = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq = Sp // block_q
    nk = Sp // block_k

    kernel = functools.partial(
        _attn_kernel, softmax_scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, causal=causal, window=window, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh),
                         lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, G=G, KV=KV:
                         ((bh // (G * KV)) * KV + (bh % (G * KV)) // G,
                          ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, G=G, KV=KV:
                         ((bh // (G * KV)) * KV + (bh % (G * KV)) // G,
                          ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),           # running max m
            pltpu.VMEM((block_q,), jnp.float32),           # normalizer l
            pltpu.VMEM((block_q, dh), jnp.float32),        # fp32 accumulator
        ],
        interpret=interpret,
    )(q.reshape(B * H, Sp, dh),
      k.reshape(B * KV, Sp, dh),
      v.reshape(B * KV, Sp, dh))
    return out.reshape(B, H, Sp, dh)[:, :, :S]
