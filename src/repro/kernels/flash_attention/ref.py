"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        softmax_scale=None):
    """q: (B, H, S, dh); k, v: (B, KV, S, dh) -> (B, H, S, dh)."""
    B, H, S, dh = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    qg = q.reshape(B, KV, G, S, dh)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, dh).astype(q.dtype)
