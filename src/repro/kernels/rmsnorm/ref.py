"""Pure-jnp oracle for the RMSNorm kernel (= repro.models.common.rms_norm)."""
from repro.models.common import rms_norm


def rmsnorm_ref(x, scale, *, eps=1e-6):
    return rms_norm(x, scale, eps)
