"""Jitted public wrapper for the RMSNorm kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_op(x, scale, *, eps=1e-6, block_rows=256, interpret=True):
    return rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                   interpret=interpret)
