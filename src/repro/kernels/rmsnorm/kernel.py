"""Pallas TPU RMSNorm kernel (row-blocked).

Simple but ubiquitous: every block and every exit head begins with an
RMSNorm; on TPU it is memory-bound, so the kernel keeps the row resident in
VMEM and does the reduce + scale in one pass (fp32 accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x: (N, d); scale: (d,) -> (N, d)."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    Np = -(-N // block_rows) * block_rows
    xp = jnp.pad(x, ((0, Np - N), (0, 0))) if Np != N else x
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Np // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, d), x.dtype),
        interpret=interpret,
    )(xp, scale)
    return out[:N]
