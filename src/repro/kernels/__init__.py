"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jitted wrapper), and ref.py (pure-jnp oracle used by the
per-kernel shape/dtype-sweep allclose tests).  Kernels are validated in
interpret mode on CPU; on real TPU hardware they are enabled via
ParallelCtx/use flags (this container has no TPU).
"""
