"""Jitted public wrapper for the mLSTM chunk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlstm_chunk_op(q, k, v, i_pre, f_pre, C0, n0, m0, *, interpret=True):
    return mlstm_chunk(q, k, v, i_pre, f_pre, C0, n0, m0,
                       interpret=interpret)
