"""Pallas TPU kernel for one chunk of the chunk-parallel mLSTM.

The xLSTM paper ships a CUDA kernel for the mLSTM recurrence; the TPU-native
formulation (repro.models.xlstm.mlstm_chunked) turns each chunk into masked
MXU matmuls with per-(t,s) exponential decay weights.  This kernel fuses the
whole intra-chunk computation for one (batch, head) tile:

    scores   = q @ k^T                      (MXU)
    decay    = exp(u_s - g_t) causal mask   (VPU)
    h_num    = (scores*decay) @ v + exp(m0-g_t) * (q @ C0)
    nq       = rowsum(scores*decay) + exp(m0-g_t) * (q @ n0)
    h        = h_num / max(|nq|, exp(-m_t))
    C1,n1,m1 = decayed state + sum_s exp(u_s-g_L) k_s v_s^T

keeping q/k/v tiles, the L×L decay matrix, and the (dh, dh) state resident
in VMEM.  Grid: (batch*heads,) — one program per head-chunk; the outer scan
over chunks stays in XLA (the carry is the (C, n, m) state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mlstm_chunk_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref,
                        m0_ref, h_ref, c1_ref, n1_ref, m1_ref, *, L, dh):
    q = q_ref[0].astype(jnp.float32)                  # (L, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    i_pre = i_ref[0].astype(jnp.float32)              # (L,)
    f_pre = f_ref[0].astype(jnp.float32)
    C0 = c0_ref[0].astype(jnp.float32)                # (dh, dh)
    n0 = n0_ref[0].astype(jnp.float32)                # (dh,)
    m0 = m0_ref[0]                                    # (1,) fp32

    lf = jax.nn.log_sigmoid(f_pre)
    b = jnp.cumsum(lf)                                # (L,)
    u = i_pre - b
    g = jnp.maximum(m0[0], jax.lax.cummax(u, axis=0))
    m = b + g

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dmat = jnp.where(tpos >= spos, jnp.exp(u[None, :] - g[:, None]), 0.0)
    w = scores * dmat
    inter = jnp.exp(m0[0] - g)                        # (L,)
    h_num = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_num += inter[:, None] * jax.lax.dot_general(
        q, C0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    nq = jnp.sum(w, axis=1) + inter * (q @ n0)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m))
    h_ref[0, ...] = (h_num / denom[:, None]).astype(h_ref.dtype)

    gL, bL = g[L - 1], b[L - 1]
    wS = jnp.exp(u - gL)                              # (L,)
    C1 = jnp.exp(m0[0] - gL) * C0 + jax.lax.dot_general(
        k * wS[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n1 = jnp.exp(m0[0] - gL) * n0 + jnp.sum(k * wS[:, None], axis=0)
    c1_ref[0, ...] = C1
    n1_ref[0, ...] = n1
    m1_ref[0, ...] = jnp.array([bL + gL], jnp.float32)


def mlstm_chunk(q, k, v, i_pre, f_pre, C0, n0, m0, *, interpret: bool = True):
    """One chunk for all (batch, head) tiles.

    q,k,v: (B,H,L,dh); i_pre,f_pre: (B,H,L); C0: (B,H,dh,dh);
    n0: (B,H,dh); m0: (B,H).  Returns (h (B,H,L,dh), C1, n1, m1).
    """
    B, H, L, dh = q.shape
    BH = B * H
    kernel = functools.partial(_mlstm_chunk_kernel, L=L, dh=dh)
    out_shapes = (
        jax.ShapeDtypeStruct((BH, L, dh), q.dtype),
        jax.ShapeDtypeStruct((BH, dh, dh), jnp.float32),
        jax.ShapeDtypeStruct((BH, dh), jnp.float32),
        jax.ShapeDtypeStruct((BH, 1), jnp.float32),
    )
    specs3 = pl.BlockSpec((1, L, dh), lambda i: (i, 0, 0))
    specs2 = pl.BlockSpec((1, L), lambda i: (i, 0))
    h, C1, n1, m1 = pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[specs3, specs3, specs3, specs2, specs2,
                  pl.BlockSpec((1, dh, dh), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, dh), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=(specs3,
                   pl.BlockSpec((1, dh, dh), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, dh), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=out_shapes,
        interpret=interpret,
    )(q.reshape(BH, L, dh), k.reshape(BH, L, dh), v.reshape(BH, L, dh),
      i_pre.reshape(BH, L), f_pre.reshape(BH, L),
      C0.astype(jnp.float32).reshape(BH, dh, dh),
      n0.astype(jnp.float32).reshape(BH, dh),
      m0.astype(jnp.float32).reshape(BH, 1))
    return (h.reshape(B, H, L, dh), C1.reshape(B, H, dh, dh),
            n1.reshape(B, H, dh), m1.reshape(B, H))
