from repro.kernels.mlstm_chunk.kernel import mlstm_chunk
from repro.kernels.mlstm_chunk.ops import mlstm_chunk_op
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref

__all__ = ["mlstm_chunk", "mlstm_chunk_op", "mlstm_chunk_ref"]
