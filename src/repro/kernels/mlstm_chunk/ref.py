"""Pure-jnp oracle: delegates to the model's chunk-parallel formulation."""
from __future__ import annotations

import jax.numpy as jnp



def mlstm_chunk_ref(q, k, v, i_pre, f_pre, C0, n0, m0):
    """Single chunk via repro.models.xlstm.mlstm_chunked (chunk = L).

    Inputs are (B,H,L,*) — transposed to the model's (B,S,H,*) layout.
    """
    from repro.models import xlstm as X
    B, H, L, dh = q.shape
    t = lambda x: x.transpose(0, 2, 1, *range(3, x.ndim))
    state = {"C": C0.astype(jnp.float32), "n": n0.astype(jnp.float32),
             "m": m0.astype(jnp.float32)}
    old = X.MLSTM_CHUNK
    X.MLSTM_CHUNK = L
    try:
        h, final = X.mlstm_chunked(t(q), t(k), t(v),
                                   i_pre.transpose(0, 2, 1),
                                   f_pre.transpose(0, 2, 1), state)
    finally:
        X.MLSTM_CHUNK = old
    return (h.transpose(0, 2, 1, 3), final["C"], final["n"], final["m"])
