"""Jitted public wrapper for the fused exit-confidence kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.exit_confidence.kernel import exit_confidence


@functools.partial(jax.jit, static_argnames=("eps", "temperature",
                                             "block_rows", "block_v",
                                             "interpret"))
def exit_confidence_op(h, scale, w_out, *, eps=1e-6, temperature=1.0,
                       block_rows=8, block_v=512, interpret=True):
    return exit_confidence(h, scale, w_out, eps=eps, temperature=temperature,
                           block_rows=block_rows, block_v=block_v,
                           interpret=interpret)
