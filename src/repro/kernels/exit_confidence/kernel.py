"""Pallas TPU fused exit-head kernel — the paper's per-stage hotspot.

At the end of every stage RTDeepIoT evaluates a thin classifier and needs
only (argmax class, max-softmax confidence) back on the host — not the full
probability vector over up to 262k classes.  This kernel fuses:

    RMSNorm(h) @ W_out  ->  online (max, logsumexp, argmax) over vocab blocks

so the V-sized logits row is never materialized in HBM: each grid step loads
one (d, block_v) weight tile into VMEM, computes a (rows, block_v) logit
tile on the MXU, and folds it into running (m, lse-accumulator, argmax)
scratch.  Output per row: [confidence, argmax, max_logit, lse].

Grid: (n_row_blocks, n_vocab_blocks), vocab innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _exit_conf_kernel(h_ref, scale_ref, w_ref, o_ref, m_ref, l_ref, a_ref,
                      *, eps, block_v, vocab, temperature, n_v_blocks):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    h = h_ref[...].astype(jnp.float32)                   # (rows, d)
    # fused RMSNorm (recomputed per vocab block; O(rows*d) — negligible next
    # to the rows*d*block_v matmul)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + eps) * (1.0 + scale_ref[...].astype(jnp.float32))
    w = w_ref[...].astype(jnp.float32)                   # (d, bv)
    logits = jax.lax.dot_general(hn, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = logits / temperature
    vpos = iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(vpos < vocab, logits, NEG_INF)

    blk_max = jnp.max(logits, axis=1)
    blk_arg = iv * block_v + jnp.argmax(logits, axis=1).astype(jnp.int32)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, blk_max)
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(jnp.exp(logits - m_new[:, None]), axis=1)
    a_ref[...] = jnp.where(blk_max > m_prev, blk_arg, a_ref[...])
    m_ref[...] = m_new

    @pl.when(iv == n_v_blocks - 1)
    def _finish():
        m = m_ref[...]
        l = jnp.maximum(l_ref[...], 1e-30)
        conf = 1.0 / l                                  # exp(m - (m + log l))
        o_ref[...] = jnp.stack(
            [conf, a_ref[...].astype(jnp.float32), m, m + jnp.log(l)],
            axis=1).astype(o_ref.dtype)


def exit_confidence(h, scale, w_out, *, eps: float = 1e-6,
                    temperature: float = 1.0, block_rows: int = 8,
                    block_v: int = 512, interpret: bool = True):
    """h: (N, d) hidden rows; scale: (d,) RMSNorm scale; w_out: (d, V).

    Returns (conf (N,), pred (N,) int32, max_logit (N,), lse (N,)).
    """
    N, d = h.shape
    V = w_out.shape[1]
    block_rows = min(block_rows, N)
    block_v = min(block_v, V)
    Np = -(-N // block_rows) * block_rows
    Vp = -(-V // block_v) * block_v
    if Np != N:
        h = jnp.pad(h, ((0, Np - N), (0, 0)))
    if Vp != V:
        w_out = jnp.pad(w_out, ((0, 0), (0, Vp - V)))
    nr, nv = Np // block_rows, Vp // block_v

    kernel = functools.partial(_exit_conf_kernel, eps=eps, block_v=block_v,
                               vocab=V, temperature=temperature,
                               n_v_blocks=nv)
    out = pl.pallas_call(
        kernel,
        grid=(nr, nv),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda ir, iv: (ir, 0)),
            pl.BlockSpec((d,), lambda ir, iv: (0,)),
            pl.BlockSpec((d, block_v), lambda ir, iv: (0, iv)),
        ],
        out_specs=pl.BlockSpec((block_rows, 4), lambda ir, iv: (ir, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 4), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),   # running max
            pltpu.VMEM((block_rows,), jnp.float32),   # sum exp(l - m)
            pltpu.VMEM((block_rows,), jnp.int32),     # running argmax
        ],
        interpret=interpret,
    )(h, scale, w_out)
    out = out[:N]
    return out[:, 0], out[:, 1].astype(jnp.int32), out[:, 2], out[:, 3]
