"""Pure-jnp oracle for the fused exit-confidence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exit_confidence_ref(h, scale, w_out, *, eps=1e-6, temperature=1.0):
    h = h.astype(jnp.float32)
    hn = h * jax.lax.rsqrt(jnp.mean(jnp.square(h), -1, keepdims=True) + eps)
    hn = hn * (1.0 + scale.astype(jnp.float32))
    logits = (hn @ w_out.astype(jnp.float32)) / temperature
    m = jnp.max(logits, -1)
    lse = jax.nn.logsumexp(logits, -1)
    conf = jnp.exp(m - lse)
    pred = jnp.argmax(logits, -1).astype(jnp.int32)
    return conf, pred, m, lse
