from repro.kernels.exit_confidence.kernel import exit_confidence
from repro.kernels.exit_confidence.ops import exit_confidence_op
from repro.kernels.exit_confidence.ref import exit_confidence_ref

__all__ = ["exit_confidence", "exit_confidence_op", "exit_confidence_ref"]
