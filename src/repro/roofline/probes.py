"""Scan-aware cost probes.

`cost_analysis()` counts a `lax.scan` body once (verified empirically), so
full-model compiles undercount scanned layers, chunked recurrences, and
q-chunked attention.  Instead of trusting one number, we compile *per
layer-kind probes* at scan-free sizes and extrapolate with the kind's known
scaling law, then compose:

  total(S) = Σ_kind count_kind × cost_kind(S) + head(S)

  attn / attn+moe        cost(S) = a·S + b·S²   (fit from two scan-free
                                                 probe points; the chunked
                                                 production path computes the
                                                 same masked S² work)
  attn_local (window w)  cost(S) = a + b·S      (block-local path, probed at
                                                 2w and 4w)
  mamba / mlstm          cost(S) ∝ S            (single-chunk probe × S/chunk
                                                 — chunked recurrences do
                                                 fixed work per chunk)
  slstm                  cost(S) ∝ S            (python-loop probe over 32
                                                 steps × S/32)
  decode (any kind)      exact single compile   (no scans; real cache size)
  head (embed+exits+loss) exact single compile  (no scans)

Each probe lowers with the production shardings on the production mesh, so
collective bytes parsed from its optimized HLO scale identically.
"""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import set_mesh
from repro.roofline.collectives import collective_bytes_from_hlo

METRICS = ("flops", "bytes", "coll")


def _compile_cost(fn, args, shardings=None):
    jitted = jax.jit(fn, in_shardings=shardings)
    compiled = jitted.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def _fit_linear(c1, s1, c2, s2):
    """cost = a + b*S from two points."""
    out = {}
    for m in METRICS:
        b = (c2[m] - c1[m]) / (s2 - s1)
        a = c1[m] - b * s1
        out[m] = (a, b)
    return out


def _fit_quad(c1, s1, c2, s2):
    """cost = a*S + b*S^2 from two points."""
    out = {}
    for m in METRICS:
        # solve a*s1 + b*s1^2 = c1 ; a*s2 + b*s2^2 = c2
        det = s1 * s2 * s2 - s2 * s1 * s1
        b = (c2[m] * s1 - c1[m] * s2) / det
        a = (c1[m] - b * s1 * s1) / s1
        out[m] = (a, b)
    return out


def _eval_linear(fit, S):
    return {m: max(0.0, fit[m][0] + fit[m][1] * S) for m in METRICS}


def _eval_quad(fit, S):
    return {m: max(0.0, fit[m][0] * S + fit[m][1] * S * S) for m in METRICS}


def _layer_fn(cfg, sig, ctx, mode, q_chunk, cur_slots=None):
    from repro.models.model import apply_layer

    def fwd(layer_params, h, *extra):
        # NOTE: reduce in the model dtype so backward cotangents are bf16,
        # matching the real CE-loss backward (an f32 probe loss doubles the
        # measured collective/memory traffic — §Perf iteration 3 finding)
        if mode == "step":
            cache, cur_pos = extra
            h2, _, aux = apply_layer(cfg, sig, layer_params, h, mode="step",
                                     cache=cache, cur_pos=cur_pos, ctx=ctx)
            return jnp.sum(h2).astype(jnp.float32) + aux
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        h2, _, aux = apply_layer(cfg, sig, layer_params, h, mode="full",
                                 positions=positions, ctx=ctx,
                                 q_chunk=q_chunk)
        return jnp.sum(h2).astype(jnp.float32) + aux

    return fwd


def _probe_layer(cfg, sig, ctx, mesh, *, batch, seq, mode, train,
                 cache_slots=None):
    """Compile one layer (+grad when train) at (batch, seq)."""
    from repro.launch.shardings import cache_shardings, param_shardings
    from repro.models.model import _layer_cache_struct, init_layer

    params = jax.eval_shape(
        lambda k: init_layer(cfg, sig, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    from repro.launch.shardings import (decode_weight_layout,
                                        expert_templates_for)
    etpl = expert_templates_for(cfg, mesh, ctx.dp, ctx.moe_impl)
    layout = decode_weight_layout(cfg, mesh) if mode == "step" else "2d"
    p_sh = param_shardings(mesh, params, etpl, layout=layout)
    dt = jnp.dtype(cfg.dtype)
    if mode == "step":
        h = jax.ShapeDtypeStruct((batch, cfg.d_model), dt)
        cache = jax.eval_shape(lambda: _layer_cache_struct(
            cfg, sig, batch, cache_slots, dt))
        c_sh = cache_shardings(mesh, cache, ctx.dp, ctx.seq_axes)
        bdp = tuple(a for a in ctx.dp if a not in ctx.seq_axes) or None
        h_sh = NamedSharding(mesh, P(bdp, None))
        pos_sh = NamedSharding(mesh, P(bdp))
        fn = _layer_fn(cfg, sig, ctx, "step", 0)
        args = (params, h, cache,
                jax.ShapeDtypeStruct((batch,), jnp.int32))
        shardings = (p_sh, h_sh, c_sh, pos_sh)
    else:
        h = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt)
        h_sh = NamedSharding(mesh, P(ctx.dp, None, None))
        fn = _layer_fn(cfg, sig, ctx, "full", q_chunk=seq)
        args = (params, h)
        shardings = (p_sh, h_sh)
    if train:
        base = fn
        fn = lambda *a: jax.value_and_grad(base)(*a)  # noqa: E731
    with set_mesh(mesh):
        return _compile_cost(fn, args, shardings)


def _probe_slstm(cfg, ctx, mesh, *, batch, seq_probe, train):
    """Python-loop sLSTM probe (scan-free) over seq_probe steps."""
    from repro.launch.shardings import param_shardings
    from repro.models import xlstm as xl

    params = jax.eval_shape(
        lambda k: xl.init_slstm(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_sh = param_shardings(mesh, params)
    dt = jnp.dtype(cfg.dtype)

    def fwd(p, x):
        from repro.models.common import rms_norm
        h_in = rms_norm(x, p["ln"], cfg.norm_eps)
        wx = h_in @ p["W"]
        state = xl.init_slstm_state(cfg, x.shape[0])
        hs = []
        for t in range(seq_probe):
            state = xl.slstm_step_core(cfg, p, wx[:, t], state)
            hs.append(state[0])
        h = jnp.stack(hs, 1)
        h = xl._group_norm(h, p["gn"], cfg.num_heads)
        y = x + h
        y = xl._slstm_mlp(cfg, p, y)
        return jnp.sum(y).astype(jnp.float32)

    if train:
        base = fwd
        fwd = lambda *a: jax.value_and_grad(base)(*a)  # noqa: E731
    x = jax.ShapeDtypeStruct((batch, seq_probe, cfg.d_model), dt)
    h_sh = NamedSharding(mesh, P(ctx.dp, None, None))
    with set_mesh(mesh):
        return _compile_cost(fwd, (params, x), (p_sh, h_sh))


def probe_head(cfg, ctx, mesh, *, batch, seq, train):
    from repro.launch.shardings import batch_shardings, param_shardings
    from repro.launch.steps import label_spec, model_inputs_spec
    from repro.models import exits as ex
    from repro.models.model import apply_embed, init_embed
    from repro.training.loop import _exit_loss

    def init_sub(k):
        from repro.models.common import KeyGen
        kg = KeyGen(k)
        return {"embed": init_embed(cfg, kg()),
                "exits": [ex.init_exit(cfg, kg())
                          for _ in range(cfg.num_stages)],
                "exit_shared": ex.init_exit(cfg, kg(), shared=True)}

    params = jax.eval_shape(init_sub, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_sh = param_shardings(mesh, params)
    inputs = model_inputs_spec(cfg, batch, seq)
    in_sh = batch_shardings(mesh, inputs, ctx.dp)

    stride = 4 if (train and cfg.vocab_size >= 32768) else 1

    def fwd(p, inputs, labels=None):
        h, _ = apply_embed(cfg, p["embed"], inputs, ctx)
        total = jnp.zeros((), jnp.float32)
        for s in range(cfg.num_stages):
            hs = h
            lb = labels
            if (stride > 1 and s < cfg.num_stages - 1 and h.ndim == 3
                    and cfg.modality in ("text", "vision_stub")
                    and h.shape[1] % stride == 0):
                hs = h[:, ::stride]
                lb = labels[:, ::stride] if labels is not None else None
            lg = ex.apply_exit(cfg, {**p["exits"][s], **p["exit_shared"]},
                               hs, ctx=ctx)
            if lb is not None:
                total += _exit_loss(cfg, lg, lb)
            else:
                total += jnp.sum(
                    ex.confidence_from_logits(lg).astype(jnp.float32))
        return total

    if train:
        labels = label_spec(cfg, batch, seq)
        l_sh = batch_shardings(mesh, {"l": labels}, ctx.dp)["l"]
        fn = lambda p, i, l: jax.value_and_grad(fwd)(p, i, l)  # noqa: E731
        args = (params, inputs, labels)
        shardings = (p_sh, in_sh, l_sh)
    else:
        fn = fwd
        args = (params, inputs)
        shardings = (p_sh, in_sh)
    with set_mesh(mesh):
        return _compile_cost(fn, args, shardings)


def probe_combo(cfg, shape, mesh, ctx, *, q_chunk=1024):
    """Composed cost estimate for one (arch × shape × mesh)."""
    from repro.launch.steps import decode_cache_slots, uses_swa_variant
    from repro.models import ssm as ssm_mod
    from repro.models import xlstm as xl_mod
    from repro.models.model import layer_sig

    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    mode = "step" if shape.kind == "decode" else "full"
    counts = Counter(layer_sig(cfg, i) for i in range(cfg.num_layers))

    per_kind = {}
    totals = {m: 0.0 for m in METRICS}
    for sig, n in counts.items():
        key = f"{sig.kind}{'+moe' if sig.is_moe else ''}"
        if mode == "step":
            slots = decode_cache_slots(cfg, shape)
            cost = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=1,
                                mode="step", train=False, cache_slots=slots)
        elif sig.kind in ("attn", "attn_local") and not (
                sig.kind == "attn_local" and cfg.sliding_window
                and S > 2 * cfg.sliding_window):
            # quadratic fit from two scan-free points; keep extrapolation
            # <= 4x (far extrapolation amplifies fit noise ~ (S/s2)^2)
            s1 = min(S, max(1024, S // 4))
            s2 = min(S, max(2048, S // 2)) if S > 1024 else S
            if s1 == s2:
                cost = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=S,
                                    mode="full", train=train)
            else:
                c1 = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=s1,
                                  mode="full", train=train)
                c2 = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=s2,
                                  mode="full", train=train)
                cost = _eval_quad(_fit_quad(c1, s1, c2, s2), S)
        elif sig.kind == "attn_local":
            w = cfg.sliding_window
            c1 = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=2 * w,
                              mode="full", train=train)
            c2 = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=4 * w,
                              mode="full", train=train)
            cost = _eval_linear(_fit_linear(c1, 2 * w, c2, 4 * w), S)
        elif sig.kind == "mamba":
            sp = min(S, ssm_mod.CHUNK)
            c = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=sp,
                             mode="full", train=train)
            cost = {m: c[m] * S / sp for m in METRICS}
        elif sig.kind == "mlstm":
            sp = min(S, xl_mod.MLSTM_CHUNK)
            c = _probe_layer(cfg, sig, ctx, mesh, batch=B, seq=sp,
                             mode="full", train=train)
            cost = {m: c[m] * S / sp for m in METRICS}
        elif sig.kind == "slstm":
            sp = min(S, 32)
            c = _probe_slstm(cfg, ctx, mesh, batch=B, seq_probe=sp,
                             train=train)
            cost = {m: c[m] * S / sp for m in METRICS}
        else:
            raise ValueError(sig.kind)
        per_kind[key] = {"count": n, **{m: cost[m] for m in METRICS}}
        for m in METRICS:
            totals[m] += n * cost[m]

    head = probe_head(cfg, ctx, mesh, batch=B,
                      seq=1 if mode == "step" else S, train=train)
    for m in METRICS:
        totals[m] += head[m]
    return {"per_kind": per_kind, "head": head, "totals": totals,
            "swa_variant": uses_swa_variant(cfg, shape)}
