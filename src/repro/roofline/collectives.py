"""Optimized-HLO collective byte census.

cost_analysis() does not expose collective traffic, so we parse
compiled.as_text() and sum the result-shape bytes of every collective op,
attributed per HLO computation so while-body (lax.scan) collectives can be
scaled by trip count by callers that know the trip counts.

Per-device traffic model (ring-algorithm ~(n-1)/n factors folded to 1):
  all-gather          result bytes        (received data)
  all-reduce          2 x operand bytes   (reduce-scatter + all-gather)
  reduce-scatter      operand bytes
  all-to-all          operand bytes
  collective-permute  operand bytes
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(?P<res>.+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|all-reduce-start|all-gather-start|"
    r"collective-permute-start)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")

_FACTOR = {"all-reduce": 2.0, "all-reduce-start": 2.0,
           "all-gather": 1.0, "all-gather-start": 1.0,
           "reduce-scatter": 1.0, "all-to-all": 1.0,
           "collective-permute": 1.0, "collective-permute-start": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str):
    """Returns dict: {"total": bytes, "by_op": {...}, "by_computation": {...},
    "count": n} summed over one execution of each computation (while bodies
    counted ONCE — callers scale by trip counts)."""
    by_op: dict = defaultdict(float)
    by_comp: dict = defaultdict(float)
    count = 0
    comp = "entry"
    for line in hlo_text.splitlines():
        mcomp = _COMP_RE.match(line)
        if mcomp and "{" in line:
            comp = mcomp.group(1)
            continue
        m = _COLL_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        base = op.replace("-start", "")
        nbytes = _shape_bytes(m.group("res"))
        if base == "all-reduce":
            # result == operand for all-reduce
            vol = _FACTOR[op] * nbytes
        elif base == "all-gather":
            vol = nbytes                      # result is the gathered buffer
        else:
            vol = _FACTOR[op] * nbytes
        by_op[base] += vol
        by_comp[comp] += vol
        count += 1
    return {"total": float(sum(by_op.values())),
            "by_op": dict(by_op), "by_computation": dict(by_comp),
            "count": count}
