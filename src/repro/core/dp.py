"""Near-optimal depth assignment — paper §II-C, Algorithm 1.

Dynamic program over (task index sorted by deadline, quantized cumulative
reward).  P(i, r) = least cumulative execution time for the top-i
earliest-deadline tasks to attain exactly reward r; S(i, r) the argmin depth
choice.  Feasibility of executing task i+1 to depth l requires
P_{i+1}^l + P(i, r̄) <= d_{i+1} - now (prefix property of EDF: tasks run in
deadline order, so the cumulative time of the first i+1 chosen prefixes is
exactly when task i+1 finishes).

FPTAS: with Δ = εR/N the plan is a (1-ε)-approximation (Theorem 1) —
property-tested against brute force in tests/test_dp.py.

Row updates run vectorized over the reward axis in numpy.  `plan()` exposes
Algorithm 1's incremental form: rows for tasks ordered before the first
changed task are reused when the planning instant is unchanged (consecutive
arrivals in a burst); otherwise feasibility thresholds (now-relative slacks)
have moved and the affected suffix is recomputed — the recompute-from-k
structure of Algorithm 1 with k = index of the first change.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INF = np.inf
SKIP = -1  # option index meaning "task contributes nothing" (P(i,r) branch)


@dataclasses.dataclass
class Option:
    depth: int           # resulting depth l
    cost: float          # additional execution time from current state
    reward: float        # predicted R_i^l
    q: int               # quantized reward


def task_options(task, predictor, delta: float):
    """Enumerate depth options for one task (paper's l ∈ {ω_i..L_i} plus the
    already-banked 'stop where we are' option for started tasks).  Rewards
    are importance-weighted (paper §II-A: the metric extends trivially to
    weighted accuracy)."""
    opts = []
    w = float(getattr(task, "weight", 1.0))
    e = task.executed
    if e >= 1:
        r = w * float(task.confidences[e - 1])
        opts.append(Option(e, 0.0, r, int(r / delta)))
        lo = e + 1
    else:
        lo = max(1, task.mandatory)
    for l in range(lo, task.num_stages + 1):
        r = w * float(predictor.predict(task, l))
        opts.append(Option(l, task.remaining_time(l), r, int(r / delta)))
    return opts


class DepthPlanner:
    """Algorithm 1 with traceback."""

    def __init__(self, delta: float = 0.1, rmax: float = 1.0,
                 max_tasks: int = 64):
        self.delta = delta
        self.rmax = rmax
        # fixed table width (Algorithm 1 grows columns with N; a fixed
        # capacity keeps previously computed rows reusable across arrivals)
        self.max_tasks = max_tasks
        self._cache_key: Optional[tuple] = None
        self._rows = []          # list of (P_row, choice_row, options)
        self.row_updates = 0     # instrumentation for the overhead benchmark

    # -- internals -----------------------------------------------------------

    def _signature(self, tasks_sorted, now):
        return (round(now, 9),) + tuple(
            (t.tid, t.executed,
             round(t.confidences[-1], 9) if t.confidences else None)
            for t in tasks_sorted)

    def _update_row(self, prev_P, prev_C, opts, slack, Q):
        P = prev_P.copy()                       # SKIP branch: P(i,r)
        C = np.full(Q + 1, SKIP, np.int32)
        for oi, o in enumerate(opts):
            if o.q == 0:
                shifted = prev_P
            else:
                shifted = np.concatenate([np.full(o.q, INF), prev_P[:Q + 1 - o.q]])
            cand = shifted + o.cost
            if o.cost > 0:                      # executing more: deadline check
                cand = np.where(cand <= slack + 1e-9, cand, INF)
            better = cand < P
            P = np.where(better, cand, P)
            C = np.where(better, oi, C)
        self.row_updates += 1
        return P, C

    # -- API -----------------------------------------------------------------

    def plan(self, tasks, now: float, predictor) -> dict:
        """Returns {tid: depth}.  Tasks with no feasible option (cannot run
        even their mandatory part by the deadline) get depth = executed
        (i.e. dropped if nothing ran yet)."""
        tasks_sorted = sorted(tasks, key=lambda t: (t.deadline, t.tid))
        N = len(tasks_sorted)
        if N == 0:
            self._cache_key = None
            return {}
        wmax = max((getattr(t, "weight", 1.0) for t in tasks_sorted),
                   default=1.0)
        Q = int(max(N, self.max_tasks) * max(1.0, wmax) * self.rmax
                / self.delta)

        sig = self._signature(tasks_sorted, now)
        k = 0
        if self._cache_key is not None and len(self._rows) and \
                sig[0] == self._cache_key[0]:
            old = self._cache_key[1:]
            new = sig[1:]
            while (k < min(len(old), len(new)) and old[k] == new[k]
                   and k < len(self._rows)
                   and len(self._rows[k][0]) == Q + 1):
                k += 1
        self._rows = self._rows[:k]

        prev_P = (self._rows[k - 1][0] if k else
                  np.concatenate([[0.0], np.full(Q, INF)]))
        for i in range(k, N):
            t = tasks_sorted[i]
            opts = task_options(t, predictor, self.delta)
            P, C = self._update_row(prev_P, None, opts, t.slack(now), Q)
            self._rows.append((P, C, opts))
            prev_P = P
        self._cache_key = sig

        # traceback from the best reachable reward (max r, then min time)
        finalP = self._rows[-1][0]
        feasible = np.isfinite(finalP)
        assignment = {}
        if not feasible.any():
            r = 0
        else:
            r = int(np.max(np.nonzero(feasible)[0]))
        for i in range(N - 1, -1, -1):
            P, C, opts = self._rows[i]
            t = tasks_sorted[i]
            ci = int(C[r]) if np.isfinite(P[r]) else SKIP
            if ci == SKIP:
                assignment[t.tid] = t.executed      # nothing more (drop if 0)
            else:
                o = opts[ci]
                assignment[t.tid] = o.depth
                r -= o.q
        return assignment


def brute_force_plan(tasks, now: float, predictor):
    """Exhaustive optimal depth assignment (exponential; tests only).

    Returns (best_total_reward, {tid: depth}).  Uses *exact* (unquantized)
    rewards — the FPTAS bound is asserted against this.
    """
    import itertools

    tasks_sorted = sorted(tasks, key=lambda t: (t.deadline, t.tid))
    choice_sets = []
    for t in tasks_sorted:
        opts = [(t.executed if t.executed else 0, 0.0,
                 float(t.confidences[-1]) if t.executed else 0.0)]
        lo = t.executed + 1 if t.executed else max(1, t.mandatory)
        for l in range(lo, t.num_stages + 1):
            opts.append((l, t.remaining_time(l),
                         float(predictor.predict(t, l))))
        choice_sets.append(opts)
    best = (-1.0, None)
    for combo in itertools.product(*choice_sets):
        cum = 0.0
        reward = 0.0
        ok = True
        for t, (depth, cost, r) in zip(tasks_sorted, combo):
            if cost > 0:
                cum += cost
                if cum > t.slack(now) + 1e-9:
                    ok = False
                    break
            reward += r
        if ok and reward > best[0]:
            best = (reward, {t.tid: d for t, (d, _, _) in
                             zip(tasks_sorted, combo)})
    return best
