"""Scheduling policies — RTDeepIoT (the paper) and the evaluated baselines.

All policies share one interface so the simulator / serving engine treats
them uniformly:

  on_arrival(active, task, now)     a request arrived
  on_stage_done(active, task, now)  a stage of `task` finished (its measured
                                    confidence is already appended)
  next_task(active, now) -> Task    whose next stage to dispatch (None: idle)

`active` excludes finished/expired tasks.  Stages are non-preemptive: once
dispatched, the simulator/executor runs the stage to completion (§II-B).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.dp import DepthPlanner
from repro.core.greedy import greedy_update


class Policy:
    name = "base"

    def __init__(self):
        self.sched_time = 0.0       # accumulated wall-clock scheduling cost
        self.invocations = 0

    def on_arrival(self, active, task, now):
        task.assigned_depth = task.clamp_depth(task.num_stages)

    def on_stage_done(self, active, task, now):
        pass

    def next_task(self, active, now) -> Optional[object]:
        raise NotImplementedError

    def batch_rank(self, task, now):
        """Preference key for batch composition (repro.serving.batch):
        co-runners at the leader's stage are admitted in this order.
        Default = EDF order; utility-aware policies override."""
        return (task.deadline, task.tid)

    def _runnable(self, active, now):
        return [t for t in active
                if t.executed < t.assigned_depth and t.deadline > now]


class RTDeepIoT(Policy):
    """The paper's scheduler: FPTAS depth assignment (Algorithm 1) on
    arrival, greedy reassignment (Eq. 7) on stage completion, EDF dispatch."""

    def __init__(self, predictor, delta: float = 0.1):
        super().__init__()
        self.predictor = predictor
        self.planner = DepthPlanner(delta=delta)
        self.name = f"rtdeepiot-{predictor.name}"

    def _replan(self, active, now):
        t0 = time.perf_counter()
        assignment = self.planner.plan(active, now, self.predictor)
        for t in active:
            t.assigned_depth = max(t.clamp_depth(assignment.get(t.tid,
                                                                t.executed)),
                                   t.executed)
        self.sched_time += time.perf_counter() - t0
        self.invocations += 1

    def on_arrival(self, active, task, now):
        task.assigned_depth = 0
        self._replan(active, now)

    def on_stage_done(self, active, task, now):
        t0 = time.perf_counter()
        # paper §II-E: if measured confidence >= prediction, the plan is
        # still optimal; otherwise try the greedy swap (Eq. 7)
        others = [t for t in active
                  if t.tid != task.tid and t.deadline > now]
        greedy_update(task, others, self.predictor)
        for t in (task, *others):       # admission caps survive the swap
            t.assigned_depth = max(t.clamp_depth(t.assigned_depth),
                                   t.executed)
        self.sched_time += time.perf_counter() - t0
        self.invocations += 1

    def _dispatch_key(self, task):
        """Dispatch preference among feasible runnable tasks (EDF);
        weight-aware variants override."""
        return (task.deadline, task.tid)

    def next_task(self, active, now):
        r = self._runnable(active, now)
        # EDF among tasks with remaining assigned work, feasibility-checked:
        # the next stage must itself finish before the deadline
        r = [t for t in r
             if now + t.stage_times[t.executed] <= t.deadline + 1e-12]
        return min(r, key=self._dispatch_key) if r else None


class WeightedRTDeepIoT(RTDeepIoT):
    """SLO-weighted RTDeepIoT (``register_policy("rtdeepiot-weighted")``).

    The FPTAS objective and the §II-E greedy swap are already
    importance-weighted through ``Task.weight`` (paper §II-A: weighted
    accuracy) — depth *planning* favors heavy classes out of the box.
    This variant extends that preference to the two remaining
    weight-blind decisions, which matter exactly under overload when
    seats are contended:

    * dispatch tie-breaks: among equal deadlines, the heavier task runs
      first;
    * batch composition: ``batch_rank`` seats co-runners by descending
      weight before urgency, so a full bucket sheds light-class work
      first.
    """

    def __init__(self, predictor, delta: float = 0.1):
        super().__init__(predictor, delta=delta)
        self.name = f"rtdeepiot-weighted-{predictor.name}"

    @staticmethod
    def _weight(task) -> float:
        return float(getattr(task, "weight", 1.0))

    def _dispatch_key(self, task):
        return (task.deadline, -self._weight(task), task.tid)

    def batch_rank(self, task, now):
        return (-self._weight(task), task.deadline, task.tid)


class EDF(Policy):
    """Classic earliest-deadline-first over entire tasks (depth = L always;
    no utility awareness, no early stopping)."""
    name = "edf"

    def next_task(self, active, now):
        r = self._runnable(active, now)
        return min(r, key=lambda t: (t.deadline, t.tid)) if r else None


class LCF(Policy):
    """Least-Confidence-First: picks the task with the lowest current
    confidence (unstarted tasks count as confidence 0); deadline breaks
    ties."""
    name = "lcf"

    def next_task(self, active, now):
        r = self._runnable(active, now)
        if not r:
            return None
        return min(r, key=lambda t: (t.last_confidence or 0.0,
                                     t.deadline, t.tid))

    def batch_rank(self, task, now):
        return (task.last_confidence or 0.0, task.deadline, task.tid)


class RR(Policy):
    """Stage-level round-robin across active tasks."""
    name = "rr"

    def __init__(self):
        super().__init__()
        self._last_tid = -1

    def next_task(self, active, now):
        r = sorted(self._runnable(active, now), key=lambda t: t.tid)
        if not r:
            return None
        for t in r:
            if t.tid > self._last_tid:
                self._last_tid = t.tid
                return t
        self._last_tid = r[0].tid
        return r[0]
