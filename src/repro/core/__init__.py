from repro.core.task import Task
from repro.core.dp import DepthPlanner, brute_force_plan, task_options
from repro.core.greedy import greedy_update
from repro.core.utility import (ExpIncrease, LinIncrease, MaxIncrease, Oracle,
                                make_predictor)
from repro.core.schedulers import (EDF, LCF, RR, Policy, RTDeepIoT,
                                   WeightedRTDeepIoT)
from repro.core.simulator import SimResult, Workload, simulate

__all__ = ["Task", "DepthPlanner", "brute_force_plan", "task_options",
           "greedy_update", "ExpIncrease", "LinIncrease", "MaxIncrease",
           "Oracle", "make_predictor", "EDF", "LCF", "RR", "Policy",
           "RTDeepIoT", "WeightedRTDeepIoT", "SimResult", "Workload", "simulate"]
