"""Discrete-event simulator for the serving system (paper §IV protocol).

Workload model (paper §IV): K concurrent closed-loop clients.  Each client
has one outstanding request at a time; when it completes (or its deadline
expires) the client immediately issues the next, with a relative deadline
drawn from U[D_l, D_u] and a sample drawn from the shuffled test set.

The simulator drives any Policy over per-sample oracle tables
(confidence[sample, stage], correct[sample, stage]) and profiled stage WCETs.
Deadline-miss semantics follow the paper: a request fails iff *no* stage
completed before its deadline; otherwise the last in-time exit's prediction
is the result.  Scheduler wall time can optionally be charged to the
simulated clock (overhead experiments, Fig. 13 analog).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.task import Task


@dataclasses.dataclass
class Workload:
    n_clients: int = 20
    d_lo: float = 0.01
    d_hi: float = 0.3
    n_requests: int = 500          # total across clients
    seed: int = 0
    mandatory_stages: int = 1


@dataclasses.dataclass
class SimResult:
    accuracy: float
    miss_rate: float
    mean_depth: float
    mean_conf: float
    overhead_frac: float
    n_requests: int
    per_request: list
    makespan: float = 0.0          # simulated seconds until the last event
    throughput: float = 0.0        # completed (non-missed) requests / second

    def row(self):
        return dict(accuracy=self.accuracy, miss_rate=self.miss_rate,
                    mean_depth=self.mean_depth, overhead=self.overhead_frac,
                    throughput=self.throughput)


def simulate(policy, workload: Workload, stage_times, conf_table,
             correct_table, *, charge_overhead: bool = False,
             dispatch_overhead: float = 0.0) -> SimResult:
    """stage_times: (L,) profiled WCETs; conf_table/correct_table:
    (n_samples, L) oracle outputs per test sample per stage."""
    rng = np.random.default_rng(workload.seed)
    n_samples, L = conf_table.shape
    stage_times = tuple(float(x) for x in stage_times)

    sample_order = rng.permutation(n_samples)
    issued = 0

    def new_task(client, now):
        nonlocal issued
        if issued >= workload.n_requests:
            return None
        rel = rng.uniform(workload.d_lo, workload.d_hi)
        t = Task(arrival=now, deadline=now + rel, stage_times=stage_times,
                 mandatory=workload.mandatory_stages,
                 sample=int(sample_order[issued % n_samples]), client=client)
        issued += 1
        return t

    now = 0.0
    active: list = []
    finished: list = []
    # each client: issue first request at a small random offset
    events = []  # (time, seq, kind, payload)
    seq = 0
    for c in range(workload.n_clients):
        t0 = float(rng.uniform(0, workload.d_lo))
        heapq.heappush(events, (t0, seq, "issue", c))
        seq += 1

    running: Optional[tuple] = None      # (task, finish_time)
    total_busy = 0.0
    sched_charged = 0.0

    def retire(task, now):
        """Move a finished/expired task out of the active set."""
        active.remove(task)
        depth = task.executed
        # count only stages that finished before the deadline — the Task's
        # executed counter is only advanced for in-time completions below
        missed = depth == 0
        correct = (not missed) and bool(correct_table[task.sample, depth - 1])
        conf = float(conf_table[task.sample, depth - 1]) if depth else 0.0
        finished.append(dict(tid=task.tid, missed=missed, correct=correct,
                             depth=depth, conf=conf, client=task.client,
                             deadline=task.deadline, arrival=task.arrival))
        # closed loop: the client reissues at *completion* time — a request
        # that finishes early frees its client immediately (an expired one
        # retires at its deadline, so `now` is correct in both cases)
        heapq.heappush(events, (now, -task.tid, "issue", task.client))

    def charge(dt):
        nonlocal now, sched_charged
        sched_charged += dt
        if charge_overhead:
            now += dt

    while events or running or any(t.executed < t.assigned_depth
                                   for t in active):
        # 1. dispatch if idle
        if running is None:
            # expire overdue tasks first
            for t in list(active):
                if t.deadline <= now:
                    retire(t, now)
            w0 = _wall()
            nxt = policy.next_task(active, now)
            charge(_wall() - w0 + (dispatch_overhead if nxt else 0.0))
            if nxt is not None:
                dur = nxt.stage_times[nxt.executed]
                running = (nxt, now + dur)
                total_busy += dur
        # 2. advance to next event
        next_event_t = events[0][0] if events else np.inf
        finish_t = running[1] if running else np.inf
        if not np.isfinite(min(next_event_t, finish_t)):
            break
        if finish_t <= next_event_t:
            now = finish_t
            task, _ = running
            running = None
            if task.deadline >= now - 1e-12:
                task.executed += 1
                task.confidences.append(
                    float(conf_table[task.sample, task.executed - 1]))
                w0 = _wall()
                policy.on_stage_done(active, task, now)
                charge(_wall() - w0)
            if task in active and (task.executed >= task.assigned_depth
                                   or task.deadline <= now):
                retire(task, now)
        else:
            now = next_event_t
            _, _, kind, client = heapq.heappop(events)
            if kind == "issue":
                t = new_task(client, now)
                if t is not None:
                    active.append(t)
                    w0 = _wall()
                    policy.on_arrival(active, t, now)
                    charge(_wall() - w0)

    # drain any still-active tasks (simulation ended)
    makespan = now
    for t in list(active):
        tend = max(now, t.deadline)
        makespan = max(makespan, tend)
        retire(t, tend)

    n = len(finished)
    acc = float(np.mean([f["correct"] for f in finished])) if n else 0.0
    miss = float(np.mean([f["missed"] for f in finished])) if n else 0.0
    depth = float(np.mean([f["depth"] for f in finished if not f["missed"]])
                  ) if n else 0.0
    conf = float(np.mean([f["conf"] for f in finished if not f["missed"]])
                 ) if n else 0.0
    denom = total_busy + policy.sched_time
    ok = sum(1 for f in finished if not f["missed"])
    return SimResult(accuracy=acc, miss_rate=miss, mean_depth=depth,
                     mean_conf=conf,
                     overhead_frac=policy.sched_time / denom if denom else 0.0,
                     n_requests=n, per_request=finished,
                     makespan=makespan,
                     throughput=ok / makespan if makespan > 0 else 0.0)


def _wall():
    import time
    return time.perf_counter()
