"""Discrete-event simulator for the serving system (paper §IV protocol).

Workload model (paper §IV): K concurrent closed-loop clients.  Each client
has one outstanding request at a time; when it completes (or its deadline
expires) the client immediately issues the next, with a relative deadline
drawn from U[D_l, D_u] and a sample drawn from the shuffled test set.

The simulator drives any Policy over per-sample oracle tables
(confidence[sample, stage], correct[sample, stage]) and profiled stage WCETs.
Deadline-miss semantics follow the paper: a request fails iff *no* stage
completed before its deadline; otherwise the last in-time exit's prediction
is the result.  Scheduler wall time can optionally be charged to the
simulated clock (overhead experiments, Fig. 13 analog).

``simulate`` is a deprecated wrapper over the public serving facade
(``repro.serving.service``): a ``ServeSpec`` on the oracle executor /
virtual clock / closed-loop source whose time model has a single batch
bucket — every dispatch is a singleton batch, i.e. exactly the paper's
Fig. 2 loop.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Workload:
    n_clients: int = 20
    d_lo: float = 0.01
    d_hi: float = 0.3
    n_requests: int = 500          # total across clients
    seed: int = 0
    mandatory_stages: int = 1


@dataclasses.dataclass
class SimResult:
    accuracy: float
    miss_rate: float
    mean_depth: float
    mean_conf: float
    overhead_frac: float
    n_requests: int
    per_request: list
    makespan: float = 0.0          # simulated seconds until the last event
    throughput: float = 0.0        # completed (non-missed) requests / second
    # unified host-cost accounting (repro.serving.runtime) ------------------
    sched_charged: float = 0.0     # all host scheduling cost incurred
    host_serial: float = 0.0       # the part that serialized with the device
    host_overhead_frac: float = 0.0   # host_serial / (busy + host_serial)
    n_dispatches: int = 0
    presel_hits: int = 0           # pipelined dispatch: pre-selections kept
    presel_misses: int = 0         # ... re-planned at dispatch time

    def row(self):
        return dict(accuracy=self.accuracy, miss_rate=self.miss_rate,
                    mean_depth=self.mean_depth, overhead=self.overhead_frac,
                    throughput=self.throughput)

    def to_dict(self, *, per_request: bool = False) -> dict:
        """All fields as a JSON-able dict (``per_request`` rows are bulky
        and excluded unless asked for)."""
        d = dataclasses.asdict(self)
        if not per_request:
            d.pop("per_request")
        return d


def simulate(policy, workload: Workload, stage_times, conf_table,
             correct_table, *, charge_overhead: bool = False,
             dispatch_overhead: float = 0.0) -> SimResult:
    """Deprecated wrapper over ``repro.serving.Service``: the paper's
    Fig. 2 loop as an unbatched (singleton-dispatch) discrete-event
    service.  stage_times: (L,) profiled WCETs; conf_table/correct_table:
    (n_samples, L) oracle outputs per test sample per stage."""
    # imported here: repro.core stays importable without pulling the serving
    # package at module-import time (the runtime imports SimResult from us)
    from repro.serving.deprecation import deprecate_once
    from repro.serving.service import ServeSpec, Service

    deprecate_once(
        "repro.core.simulate",
        "simulate() is deprecated: build a ServeSpec(batching={'mode': "
        "'none', ...}) and run it through repro.serving.Service instead")
    spec = ServeSpec(
        executor="oracle", clock="virtual", source="closed-loop",
        batching={"mode": "none",
                  "stage_times": [float(x) for x in stage_times]},
        charge_overhead=charge_overhead,
        dispatch_overhead=dispatch_overhead)
    return Service.from_spec(spec, policy=policy, workload=workload,
                             conf_table=conf_table,
                             correct_table=correct_table).run()
