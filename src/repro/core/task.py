"""Task model: deep-learning requests as imprecise computations (paper §II-B).

A task J_i is a DNN inference request with L_i stages, per-stage worst-case
execution times p_il (from profiling), an absolute deadline d_i (already
adjusted for CPU overhead + one stage of non-preemption, §II-B), a mandatory
part of ω_i stages, and a data-dependent utility R_i^l — the confidence of
stage l's exit head.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

_ids = itertools.count()


@dataclasses.dataclass
class Task:
    arrival: float
    deadline: float                  # absolute, post-adjustment (§II-B)
    stage_times: tuple               # p_il, l = 1..L
    mandatory: int = 1               # ω_i
    weight: float = 1.0              # importance (paper §II-A: weighted accuracy)
    sample: int = 0                  # dataset index (payload reference)
    client: int = 0
    tid: int = dataclasses.field(default_factory=lambda: next(_ids))
    seq_len: Optional[int] = None    # ragged input length (length-bucket WCETs)
    model: Optional[str] = None      # model-zoo id (None: single-model serving)

    # runtime state ---------------------------------------------------------
    executed: int = 0                # stages completed so far
    confidences: list = dataclasses.field(default_factory=list)
    assigned_depth: int = 0          # current depth target l_i
    depth_cap: Optional[int] = None  # admission-control ceiling on l_i
    finished_at: Optional[float] = None
    dropped: bool = False

    @property
    def num_stages(self) -> int:
        return len(self.stage_times)

    def cum_time(self, depth: int) -> float:
        """P_i^depth = sum of the first `depth` stage times."""
        return float(sum(self.stage_times[:depth]))

    def remaining_time(self, depth: int) -> float:
        """Execution time still needed to reach `depth`."""
        return float(sum(self.stage_times[self.executed:depth]))

    @property
    def last_confidence(self) -> Optional[float]:
        return self.confidences[-1] if self.confidences else None

    @property
    def completed_any(self) -> bool:
        return self.executed > 0

    def slack(self, now: float) -> float:
        return self.deadline - now

    # batch-aware timing helpers (repro.serving.batch) ----------------------
    def fits_batch(self, now: float, batch_wcet: float,
                   eps: float = 1e-12) -> bool:
        """Can this task ride a (non-preemptive) batched stage of WCET
        `batch_wcet` dispatched at `now` without missing its deadline?"""
        return now + batch_wcet <= self.deadline + eps

    def batch_slack(self, now: float, batch_wcet: float) -> float:
        """Slack left after one batched stage of WCET `batch_wcet`."""
        return self.deadline - now - batch_wcet

    def clamp_depth(self, depth: int) -> int:
        """Apply the admission-control depth cap (no-op when uncapped)."""
        cap = self.num_stages if self.depth_cap is None else self.depth_cap
        return min(depth, cap)

    def feasible_depth(self, now: float, stage_time=None) -> int:
        """Deepest depth reachable by the deadline when the remaining stages
        run back-to-back from `now`.  `stage_time` maps stage index ->
        duration (defaults to this task's own profiled stage_times)."""
        f = (lambda s: self.stage_times[s]) if stage_time is None \
            else stage_time
        t, depth = now, self.executed
        for s in range(self.executed, self.num_stages):
            t += f(s)
            if t > self.deadline + 1e-12:
                break
            depth = s + 1
        return depth
