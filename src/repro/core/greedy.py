"""Greedy depth reassignment on confidence updates — paper §II-E, Eq. (7).

When the current task J_1 finishes a stage and its measured confidence makes
the previous depth assignment look suboptimal, a full DP recompute is too
cumbersome (it would touch every later row).  Instead: try to hand J_1's
remaining time budget to the single task whose extra stages buy the most
predicted reward within that budget; swap iff its gain beats J_1's own
predicted residual gain.
"""
from __future__ import annotations


def greedy_update(current, others, predictor) -> bool:
    """Mutates assigned_depth in place.  Returns True if a swap happened.

    current: the task that just finished a stage (earliest deadline, J_1).
    others: remaining active tasks (J_2..J_N) with valid assigned_depth.
    """
    l1 = current.executed
    l1_star = current.assigned_depth
    if l1_star <= l1:
        return False
    budget = sum(current.stage_times[l1:l1_star])      # Σ p_1l, l=l_1+1..l_1*
    w_cur = float(getattr(current, "weight", 1.0))
    gain_current = w_cur * (predictor.predict(current, l1_star)
                            - predictor.predict(current, l1))

    best_gain, best_task, best_depth = 0.0, None, None
    for t in others:
        w_t = float(getattr(t, "weight", 1.0))
        li_star = max(t.assigned_depth, t.executed)
        base = predictor.predict(t, li_star) if li_star >= 1 else 0.0
        add_time = 0.0
        for l in range(li_star + 1, t.num_stages + 1):
            add_time += t.stage_times[l - 1]
            if add_time > budget + 1e-12:
                break
            gain = w_t * (predictor.predict(t, l) - base)
            if gain > best_gain:
                best_gain, best_task, best_depth = gain, t, l

    if best_task is not None and best_gain > gain_current + 1e-12:
        current.assigned_depth = l1                     # stop J_1 here
        best_task.assigned_depth = best_depth
        return True
    return False
