"""Utility (confidence) prediction for future stages — paper §II-D.

Given a task's measured exit confidences so far, predict R_i^l for deeper
stages.  The three paper heuristics plus the oracle:

  Max:  R^{l+1} = 1                     (favors lowest-confidence tasks)
  Exp:  R^{l+1} = R^l + 0.5 (1 - R^l)   (paper's best performer)
  Lin:  R^{l+1} = min(1, R^l * P^{l+1}/P^l)
  Oracle: true confidence of every stage, known a priori (upper bound)

For a task that has not yet executed any stage there is no measured
confidence; predictors seed from a *prior curve* (mean per-stage confidence
on the training set — available to the serving system from calibration).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


class UtilityPredictor:
    name = "base"

    def __init__(self, prior_curve: Sequence[float]):
        self.prior = np.asarray(prior_curve, np.float64)

    def seed(self, task) -> float:
        """Confidence to extrapolate from (measured, else prior)."""
        if task.confidences:
            return float(task.confidences[-1])
        return float(self.prior[0])

    def predict(self, task, depth: int) -> float:
        """Predicted R_i^depth (depth in 1..L).  Must be non-decreasing in
        depth for depths > executed; equals measured value at executed."""
        raise NotImplementedError

    def curve(self, task) -> np.ndarray:
        """R_i^l for l = 1..L (measured prefix + predicted suffix)."""
        L = task.num_stages
        out = np.zeros(L)
        for l in range(1, L + 1):
            out[l - 1] = self.predict(task, l)
        return out


class ExpIncrease(UtilityPredictor):
    """Each extra stage halves the distance to 1."""
    name = "exp"

    def predict(self, task, depth):
        e = task.executed
        if depth <= e and task.confidences:
            return float(task.confidences[depth - 1])
        if not task.confidences:
            # prior curve value, halving beyond its measured range
            base = float(self.prior[min(depth, len(self.prior)) - 1])
            return base
        c = float(task.confidences[-1])
        j = depth - e
        return 1.0 - (1.0 - c) * 0.5 ** j


class MaxIncrease(UtilityPredictor):
    """Assume the next stage reaches full confidence."""
    name = "max"

    def predict(self, task, depth):
        e = task.executed
        if depth <= e and task.confidences:
            return float(task.confidences[depth - 1])
        if not task.confidences:
            return 1.0 if depth > 1 else float(self.prior[0])
        return 1.0


class LinIncrease(UtilityPredictor):
    """Confidence grows proportionally to cumulative execution time."""
    name = "lin"

    def predict(self, task, depth):
        e = task.executed
        if depth <= e and task.confidences:
            return float(task.confidences[depth - 1])
        c = self.seed(task)
        anchor = max(e, 1)
        p_anchor = task.cum_time(anchor)
        p_depth = task.cum_time(depth)
        if p_anchor <= 0:
            return c
        return float(min(1.0, c * p_depth / p_anchor))


class Oracle(UtilityPredictor):
    """Knows the computed confidence of every stage beforehand (paper's
    unrealizable upper bound).  table: (n_samples, L) true confidences."""
    name = "oracle"

    def __init__(self, table: np.ndarray):
        super().__init__(table.mean(0))
        self.table = np.asarray(table, np.float64)

    def predict(self, task, depth):
        return float(self.table[task.sample, depth - 1])


PREDICTORS = {"exp": ExpIncrease, "max": MaxIncrease, "lin": LinIncrease}


def make_predictor(name: str, prior_curve=None, oracle_table=None):
    if name == "oracle":
        assert oracle_table is not None
        return Oracle(oracle_table)
    if prior_curve is None:
        prior_curve = [0.5]
    return PREDICTORS[name](prior_curve)
