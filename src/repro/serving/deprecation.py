"""One-shot deprecation warnings for the legacy serving entry points.

Each legacy face (``simulate``, ``simulate_batched``, ``ServingEngine.run``,
``BatchedServingEngine.run``) warns exactly once per process, pointing at
the ``ServeSpec``/``Service`` front door, then stays silent — the shims are
called in tight loops by old benchmarks and tests.
"""
from __future__ import annotations

import warnings

_fired: set = set()


def deprecate_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning`` the first time ``key`` is seen."""
    if key in _fired:
        return
    _fired.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def _reset() -> None:
    """Forget fired keys (tests only)."""
    _fired.clear()
