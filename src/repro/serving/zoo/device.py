"""Multi-model device executor: one accelerator, per-model stage fns.

``register_executor("zoo-device")`` (registered from
:mod:`repro.launch.serve`, next to the other jax-heavy executors) runs a
:class:`~repro.serving.runtime.device.DeviceExecutor` whose stage
dispatch routes on the batch's model id: the
:class:`~repro.serving.batch.batcher.StageBatcher` only seats same-model
co-runners, so every window is exactly one model's batched stage fn over
one shared bucket set.  The hidden-state cache, commit slicing, inflight
FIFO and telemetry are all inherited unchanged — state rows are per
request and never cross models.

This module imports jax (via the device executor); keep imports lazy on
numpy-only paths.
"""
from __future__ import annotations

from repro.serving.registry import BuildContext
from repro.serving.runtime.device import DeviceExecutor
from repro.serving.zoo.policy import zoo_from_context


class ZooDeviceExecutor(DeviceExecutor):
    """``DeviceExecutor`` routing each window to its model's stage fns.

    ``fns_by_model``/``params_by_model``: ``{model: BatchedStageFns}`` /
    ``{model: params}``.  The inherited ``stage_fns``/``params`` (may be
    ``None``) serve windows whose tasks carry no model id.
    """

    def __init__(self, fns_by_model: dict, params_by_model: dict,
                 time_model, *, stage_fns=None, params=None,
                 max_inflight: int = 1):
        super().__init__(stage_fns, params, time_model,
                         max_inflight=max_inflight)
        self.fns_by_model = dict(fns_by_model)
        self.params_by_model = dict(params_by_model)

    def _dispatch_stage(self, stage: int, tasks: list):
        m = getattr(tasks[0], "model", None)
        if m is None:
            if self.stage_fns is None:
                raise KeyError("window carries no model id and the zoo "
                               "device executor has no default stage fns")
            return super()._dispatch_stage(stage, tasks)
        try:
            fns, params = self.fns_by_model[m], self.params_by_model[m]
        except KeyError:
            raise KeyError(f"no stage fns for zoo model {m!r}; have: "
                           f"{sorted(self.fns_by_model)}") from None
        hs = [self.states[t.tid][1] for t in tasks]
        h_out, logits, conf, _mask = fns.run(stage, params, hs)
        return h_out, logits, conf


def build_zoo_device_executor(args: dict, ctx: BuildContext):
    """Factory behind ``executor="zoo-device"``.

    resources: ``zoo_models`` = ``{model: {"cfg": AnytimeConfig,
    "params": params, "stage_fns": BatchedStageFns (optional)}}``;
    optional ``cfg``/``params``/``stage_fns`` for model-less requests.
    """
    from repro.serving.batch.stage_fns import BatchedStageFns
    zoo = zoo_from_context(ctx)
    zres = ctx.resources.get("zoo_models")
    if zres is None:
        raise KeyError("executor='zoo-device' needs a 'zoo_models' "
                       "resource: {model: {'cfg': ..., 'params': ...}}")
    missing = [m for m in zoo.names() if m not in zres]
    if missing:
        raise KeyError(f"zoo_models missing models {missing}")
    buckets = ctx.time_model.buckets
    fns, params = {}, {}
    for m, entry in zres.items():
        sfns = entry.get("stage_fns")
        if sfns is None:
            sfns = BatchedStageFns(entry["cfg"], buckets)
        fns[m], params[m] = sfns, entry["params"]
    base_fns = ctx.resources.get("stage_fns")
    base_cfg = ctx.resources.get("cfg")
    if base_fns is None and base_cfg is not None:
        base_fns = BatchedStageFns(base_cfg, buckets)
    ex = ZooDeviceExecutor(
        fns, params, ctx.time_model,
        stage_fns=base_fns, params=ctx.resources.get("params"),
        max_inflight=max(1, int(ctx.spec.pipeline_depth) - 1))

    def warmup(sample_input):
        for m in sorted(fns):
            fns[m].warmup(params[m], sample_input)
    ex.warmup = warmup
    return ex
