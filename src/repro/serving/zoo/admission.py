"""Zoo-aware admission control: each request priced by its own model.

The base :class:`~repro.serving.batch.admission.AdmissionController`
prices every request and every backlog entry with one shared WCET table.
In a zoo that is doubly wrong: a cheap vision request would be rejected
because the blended (worst-case) table prices it like the LLM, and the
optimistic backlog would overstate what the queue actually owes.  This
controller resolves the per-model table through the blended
:class:`~repro.serving.zoo.models.ZooTimeModel`'s ``for_model`` for both
sides of the decision — its own mandatory cost, its feasible depth, and
each active task's amortized backlog contribution.  Tasks without a
model id (or a non-zoo time model) fall back to the shared table, so
single-model services decide identically.
"""
from __future__ import annotations

from repro.serving.batch.admission import AdmissionController


class ZooAdmissionController(AdmissionController):
    """`AdmissionController` with per-model WCET resolution."""

    def _tm_for(self, task):
        m = getattr(task, "model", None)
        if m is None:
            return self.time_model
        fm = getattr(self.time_model, "for_model", None)
        return self.time_model if fm is None else fm(m)
