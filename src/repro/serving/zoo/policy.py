"""Cross-model preemption: the paper's scheduler lifted over a model zoo.

``register_policy("rtdeepiot-zoo")`` — :class:`ZooRTDeepIoT` extends the
SLO-weighted scheduler with per-model utility prediction
(:class:`ZooPredictor`: each model's own confidence-vs-depth curve) and a
``scope`` knob that *is* the cross-model preemption policy:

* ``scope="global"`` (default) — one FPTAS plan over the whole active
  set, every model's depth options priced by its own stage costs and
  weighted by ``model weight x SLO weight``.  Under mixed-model overload
  the planner sheds the globally least-valuable *optional* stages first,
  whichever model they belong to — a cheap low-utility vision stage loses
  its seat to an expensive high-utility LLM stage and vice versa.  The
  §II-E greedy swap likewise trades depth across models.
* ``scope="siloed"`` — the ablation baseline: the active set is
  partitioned by model and each partition planned *independently against
  the full device*.  Every silo believes it owns the machine, so under
  mixed overload the union plan overcommits and admitted work misses —
  exactly what the zoo benchmark quantifies against ``"global"``.

Tasks without a model id ride the base predictor and (under
``"siloed"``) their own ``None`` partition, so single-model services are
bit-for-bit unchanged.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.dp import DepthPlanner
from repro.core.greedy import greedy_update
from repro.core.schedulers import WeightedRTDeepIoT
from repro.core.utility import UtilityPredictor, make_predictor
from repro.serving.registry import BuildContext, register_policy
from repro.serving.zoo.models import ModelZoo

SCOPES = ("global", "siloed")


class ZooPredictor(UtilityPredictor):
    """Per-model §II-D utility prediction behind one predictor surface.

    Dispatches ``seed``/``predict`` on ``task.model``: each model gets a
    predictor seeded from its own prior curve (or oracle table); tasks
    without a model fall through to ``base``.
    """
    name = "zoo"

    def __init__(self, base: UtilityPredictor, per_model: dict):
        super().__init__(base.prior)
        self.base = base
        self.per_model = dict(per_model)
        self.name = f"zoo-{base.name}"

    def _for(self, task) -> UtilityPredictor:
        m = getattr(task, "model", None)
        if m is None:
            return self.base
        return self.per_model.get(m, self.base)

    def seed(self, task) -> float:
        return self._for(task).seed(task)

    def predict(self, task, depth: int) -> float:
        return self._for(task).predict(task, depth)


class ZooRTDeepIoT(WeightedRTDeepIoT):
    """See module docstring; ``scope`` picks global vs siloed planning."""

    def __init__(self, predictor, delta: float = 0.1,
                 scope: str = "global"):
        super().__init__(predictor, delta=delta)
        if scope not in SCOPES:
            raise ValueError(f"scope must be one of {SCOPES}, got {scope!r}")
        self.scope = scope
        self.delta = delta
        self._planners: dict = {}      # model -> DepthPlanner (siloed)
        self.name = f"rtdeepiot-zoo-{scope}-{predictor.name}"

    # -- siloed scope: per-model planning ------------------------------
    def _replan(self, active, now):
        if self.scope == "global":
            return super()._replan(active, now)
        t0 = time.perf_counter()
        groups: dict = {}
        for t in active:
            groups.setdefault(getattr(t, "model", None), []).append(t)
        for m, group in groups.items():
            planner = self._planners.setdefault(
                m, DepthPlanner(delta=self.delta))
            assignment = planner.plan(group, now, self.predictor)
            for t in group:
                t.assigned_depth = max(
                    t.clamp_depth(assignment.get(t.tid, t.executed)),
                    t.executed)
        self.sched_time += time.perf_counter() - t0
        self.invocations += 1

    def on_stage_done(self, active, task, now):
        if self.scope == "global":
            return super().on_stage_done(active, task, now)
        t0 = time.perf_counter()
        m = getattr(task, "model", None)
        others = [t for t in active
                  if t.tid != task.tid and t.deadline > now
                  and getattr(t, "model", None) == m]
        greedy_update(task, others, self.predictor)
        for t in (task, *others):
            t.assigned_depth = max(t.clamp_depth(t.assigned_depth),
                                   t.executed)
        self.sched_time += time.perf_counter() - t0
        self.invocations += 1


def make_zoo_predictor(args: dict, ctx: BuildContext,
                       zoo: ModelZoo) -> ZooPredictor:
    """Per-model predictors from (in precedence order) the model's
    ``utility`` prior, its ``zoo_tables`` confidence means, or the shared
    prior; ``predictor="oracle"`` reads each model's own table."""
    name = args.get("predictor", "exp")
    ztabs = ctx.resources.get("zoo_tables") or {}
    per = {}
    for mname, zm in zoo.models.items():
        if name == "oracle":
            try:
                table = ztabs[mname]["conf"]
            except KeyError:
                raise KeyError(
                    f"predictor='oracle' needs zoo_tables[{mname!r}]"
                    "['conf']") from None
            per[mname] = make_predictor("oracle",
                                        oracle_table=np.asarray(table))
            continue
        prior = zm.utility
        if prior is None and mname in ztabs:
            prior = np.asarray(ztabs[mname]["conf"]).mean(0)
        if prior is None:
            prior = args.get("prior_curve")
        per[mname] = make_predictor(name, prior_curve=prior)
    conf = ctx.resources.get("conf_table")
    if name == "oracle":
        base = make_predictor("oracle", oracle_table=conf) \
            if conf is not None else next(iter(per.values()))
    else:
        prior = args.get("prior_curve")
        if prior is None and conf is not None:
            prior = conf.mean(0)
        base = make_predictor(name, prior_curve=prior)
    return ZooPredictor(base, per)


def zoo_from_context(ctx: BuildContext) -> ModelZoo:
    """The build's zoo: the ``zoo`` resource if supplied, else built from
    ``spec.models``."""
    zoo = ctx.resources.get("zoo")
    if zoo is not None:
        return zoo
    if not ctx.spec.models:
        raise ValueError("a zoo component needs ServeSpec.models (or a "
                         "'zoo' resource)")
    return ModelZoo.from_spec(ctx.spec.models)


@register_policy("rtdeepiot-zoo")
def _make_rtdeepiot_zoo(args: dict, ctx: BuildContext):
    """args: ``scope`` ("global"/"siloed"), plus the ``rtdeepiot`` args
    (``predictor``, ``prior_curve``, ``delta``)."""
    zoo = zoo_from_context(ctx)
    pred = make_zoo_predictor(args, ctx, zoo)
    return ZooRTDeepIoT(pred, delta=float(args.get("delta", 0.1)),
                        scope=args.get("scope", "global"))
