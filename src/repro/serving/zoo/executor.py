"""Zoo oracle executor: one virtual device, per-model oracle tables.

``register_executor("zoo-oracle")`` — the discrete-event device model for
a multi-model service.  Batch *pricing* needs no override at all:
:meth:`~repro.serving.runtime.executor.OracleExecutor.submit` prices
through :func:`~repro.serving.batch.time_model.batch_wcet`, which
resolves the batch's model against the blended
:class:`~repro.serving.zoo.models.ZooTimeModel` (the ``for_model``
dispatch).  What does need dispatch is *measurement*: each model has its
own per-sample confidence oracle, read from the ``zoo_tables`` resource
(``{model: {"conf": (n_samples, L), "correct": (n_samples, L)}}``).

:class:`ZooTableRecorder` is the matching aggregation: the golden-parity
``TableRecorder`` math with correctness/confidence looked up in the
retiring task's own model tables.
"""
from __future__ import annotations

import numpy as np

from repro.serving.registry import BuildContext, register_executor
from repro.serving.runtime.core import TableRecorder
from repro.serving.runtime.executor import OracleExecutor
from repro.serving.zoo.policy import zoo_from_context


class ZooOracleExecutor(OracleExecutor):
    """``OracleExecutor`` with per-model confidence tables.

    ``conf_tables``: ``{model: (n_samples, L) array}``; ``conf_table``
    (the inherited single table, may be ``None``) serves tasks without a
    model id.
    """

    def __init__(self, time_model, conf_tables: dict, *,
                 conf_table=None, max_inflight: int = 1):
        super().__init__(time_model, conf_table, max_inflight=max_inflight)
        self.conf_tables = dict(conf_tables)

    def _table(self, task):
        m = getattr(task, "model", None)
        if m is None:
            if self.conf_table is None:
                raise KeyError("task carries no model id and the zoo "
                               "executor has no default conf_table")
            return self.conf_table
        try:
            return self.conf_tables[m]
        except KeyError:
            raise KeyError(f"no oracle table for zoo model {m!r}; have: "
                           f"{sorted(self.conf_tables)}") from None

    def commit(self, task, k: int) -> float:
        return float(self._table(task)[task.sample, task.executed - 1])


class ZooTableRecorder(TableRecorder):
    """``TableRecorder`` resolving (conf, correct) per retiring model."""

    def __init__(self, conf_tables: dict, correct_tables: dict,
                 conf_table=None, correct_table=None):
        super().__init__(conf_table, correct_table)
        self.conf_tables = dict(conf_tables)
        self.correct_tables = dict(correct_tables)

    def _tables(self, task):
        m = getattr(task, "model", None)
        if m is None:
            return self.conf_table, self.correct_table
        return self.conf_tables[m], self.correct_tables[m]

    def on_retire(self, task, now: float, rejected: bool = False) -> None:
        conf_t, correct_t = self._tables(task)
        depth = task.executed
        missed = depth == 0
        correct = (not missed) and bool(correct_t[task.sample, depth - 1])
        conf = float(conf_t[task.sample, depth - 1]) if depth else 0.0
        self.finished.append(dict(
            tid=task.tid, missed=missed, correct=correct, depth=depth,
            conf=conf, client=task.client, sample=task.sample,
            deadline=task.deadline, arrival=task.arrival,
            rejected=rejected))


def zoo_tables_from(ctx: BuildContext) -> dict:
    """The ``zoo_tables`` resource, keys validated against the zoo."""
    tabs = ctx.resources.get("zoo_tables")
    if tabs is None:
        raise KeyError("executor='zoo-oracle' needs a 'zoo_tables' "
                       "resource: {model: {'conf': ..., 'correct': ...}}")
    return {m: {k: np.asarray(v) for k, v in d.items()}
            for m, d in tabs.items()}


@register_executor("zoo-oracle")
def _make_zoo_oracle(args: dict, ctx: BuildContext):
    """resources: ``zoo_tables`` (per-model oracle tables); optional
    ``conf_table``/``correct_table`` for model-less requests."""
    zoo = zoo_from_context(ctx)
    tabs = zoo_tables_from(ctx)
    missing = [m for m in zoo.names() if m not in tabs]
    if missing:
        raise KeyError(f"zoo_tables missing models {missing}")
    return ZooOracleExecutor(
        ctx.time_model, {m: d["conf"] for m, d in tabs.items()},
        conf_table=ctx.resources.get("conf_table"),
        max_inflight=max(1, int(ctx.spec.pipeline_depth) - 1))
