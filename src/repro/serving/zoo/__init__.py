"""Multi-model serving zoo: one Service, many models, cross-model
preemption.

The subsystem plugs into the runtime core entirely through the public
extension points (the same discipline as :mod:`repro.serving.traffic`
and :mod:`repro.launch.serve`):

* :class:`ModelZoo` / :class:`ZooModel` — the registry binding each
  model id to its WCET table, mandatory depth, utility weight and
  confidence-vs-depth prior; declared JSON-ably in ``ServeSpec.models``.
* :class:`ZooTimeModel` — blended worst-case ``BatchTimeModel`` with
  per-model ``for_model`` dispatch (what the batcher, admission and
  ``batch_wcet`` resolve).
* ``register_policy("rtdeepiot-zoo")`` — :class:`ZooRTDeepIoT`, the
  cross-model preemption policy (``scope="global"`` plans all models
  jointly; ``"siloed"`` is the per-model ablation baseline).
* ``register_executor("zoo-oracle")`` — per-model oracle tables on one
  virtual device; ``"zoo-device"`` (jax; registered from
  :mod:`repro.launch.serve`) routes real batched stage fns per model.
* :class:`ZooAdmissionController` — admission priced per request against
  its own model's tables.

Importing this package performs the numpy-only registrations; the
package itself is imported from :mod:`repro.serving`.
"""
from repro.serving.zoo.admission import ZooAdmissionController
from repro.serving.zoo.executor import (ZooOracleExecutor,
                                        ZooTableRecorder)
from repro.serving.zoo.models import (ZOO_MODEL_KEYS, ModelZoo,
                                      ZooModel, ZooTimeModel,
                                      validate_models)
from repro.serving.zoo.policy import (ZooPredictor, ZooRTDeepIoT,
                                      make_zoo_predictor,
                                      zoo_from_context)

__all__ = [
    "ZOO_MODEL_KEYS", "ModelZoo", "ZooAdmissionController", "ZooModel",
    "ZooOracleExecutor", "ZooPredictor", "ZooRTDeepIoT",
    "ZooTableRecorder", "ZooTimeModel", "make_zoo_predictor",
    "validate_models", "zoo_from_context",
]
