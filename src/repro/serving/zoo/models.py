"""Model registry for multi-model serving: ids -> tables and defaults.

One zoo = one device serving N anytime models.  Each :class:`ZooModel`
binds a model id to the things the scheduler prices and plans with:

* a per-model WCET table (:class:`~repro.serving.batch.batcher
  .BatchTimeModel`, optionally length-bucketed) — stage costs differ per
  model, so feasibility and batch pricing must too;
* the model's mandatory depth and a utility *weight* (how much one unit
  of this model's confidence is worth relative to the others — what the
  cross-model FPTAS trades off under overload);
* an optional confidence-vs-depth prior curve (``utility``) seeding the
  §II-D predictor for requests that have not executed a stage yet.

The :class:`ModelZoo` validates the set and exposes one
:class:`ZooTimeModel` — a blended worst-case ``BatchTimeModel`` over the
member tables that model-blind consumers (the §II-B deadline adjustment,
engine overlap accounting) price conservatively, with a ``for_model``
method that model-aware consumers (the
:class:`~repro.serving.batch.batcher.StageBatcher`,
:func:`~repro.serving.batch.time_model.batch_wcet`, admission) resolve to
the exact per-model table.  All batch buckets must match across models:
the device pre-compiles one shared bucket set, so a batch of n costs one
bucket no matter whose model fills it.

No jax import — the discrete-event stack builds zoos too.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.batch.batcher import DEFAULT_BUCKETS, BatchTimeModel
from repro.serving.batch.time_model import LengthBucketTimeModel

# the JSON-able per-model config keys ``ServeSpec.models`` accepts
ZOO_MODEL_KEYS = ("stage_times", "marginal", "buckets", "times",
                  "len_buckets", "len_marginal", "mandatory", "weight",
                  "utility")


@dataclasses.dataclass(frozen=True)
class ZooModel:
    """One model's serving contract inside a zoo."""
    name: str
    time_model: BatchTimeModel
    mandatory: int = 1
    weight: float = 1.0
    utility: Optional[tuple] = None    # prior confidence-vs-depth curve

    @property
    def num_stages(self) -> int:
        return self.time_model.num_stages


@dataclasses.dataclass(frozen=True)
class ZooTimeModel(BatchTimeModel):
    """Blended worst-case WCET table over a zoo's per-model tables.

    The inherited 2-D ``times`` is the per-(bucket, stage) max across
    models (stages a model lacks contribute nothing), so model-blind
    pricing stays conservative; ``for_model`` dispatches to the exact
    per-model table for the consumers that know whose batch they price.
    With a single member the blend *is* that member's table — the parity
    guarantee single-model zoo specs rely on.
    """
    models: dict = dataclasses.field(default_factory=dict)

    def for_model(self, model: str) -> BatchTimeModel:
        try:
            return self.models[model]
        except KeyError:
            raise KeyError(f"unknown zoo model {model!r}; defined: "
                           f"{sorted(self.models)}") from None

    @classmethod
    def blend(cls, models: dict) -> "ZooTimeModel":
        """Build the blend from ``{name: BatchTimeModel}`` (all members
        must share one batch-bucket set)."""
        if not models:
            raise ValueError("a zoo needs at least one model")
        tms = list(models.values())
        buckets = tms[0].buckets
        for name, tm in models.items():
            if tm.buckets != buckets:
                raise ValueError(
                    f"zoo models must share batch buckets: {name!r} has "
                    f"{tm.buckets}, expected {buckets}")
        num_stages = max(tm.num_stages for tm in tms)
        rows = tuple(
            tuple(max(tm.times[bi][s] for tm in tms if s < tm.num_stages)
                  for s in range(num_stages))
            for bi in range(len(buckets)))
        return cls(buckets=buckets, times=rows, models=dict(models))


class ModelZoo:
    """The validated model set one Service serves (``ServeSpec.models``).

    ``models``: ``{name: ZooModel}``.  ``time_model`` is the blended
    :class:`ZooTimeModel` the build threads through batcher, admission
    and deadline adjustment.
    """

    def __init__(self, models: dict):
        if not models:
            raise ValueError("a ModelZoo needs at least one model")
        self.models = dict(models)
        self.time_model = ZooTimeModel.blend(
            {name: zm.time_model for name, zm in self.models.items()})

    def __contains__(self, name) -> bool:
        return name in self.models

    def names(self) -> list:
        return sorted(self.models)

    def model(self, name: str) -> ZooModel:
        try:
            return self.models[name]
        except KeyError:
            raise KeyError(f"unknown zoo model {name!r}; defined: "
                           f"{self.names()}") from None

    @classmethod
    def from_spec(cls, spec_models: dict) -> "ModelZoo":
        """Build from the JSON-able ``ServeSpec.models`` mapping (see
        :data:`ZOO_MODEL_KEYS`; format mirrors ``ServeSpec.batching``)."""
        validate_models(spec_models)
        out = {}
        for name, cfg in spec_models.items():
            out[name] = ZooModel(
                name=name, time_model=_time_model_from(name, cfg),
                mandatory=int(cfg.get("mandatory", 1)),
                weight=float(cfg.get("weight", 1.0)),
                utility=(tuple(float(u) for u in cfg["utility"])
                         if cfg.get("utility") is not None else None))
        return cls(out)


def _time_model_from(name: str, cfg: dict) -> BatchTimeModel:
    buckets = tuple(int(b) for b in cfg.get("buckets", DEFAULT_BUCKETS))
    if cfg.get("times") is not None:
        return BatchTimeModel(
            buckets=buckets,
            times=tuple(tuple(float(t) for t in row)
                        for row in cfg["times"]))
    stage_times = tuple(float(t) for t in cfg["stage_times"])
    marginal = float(cfg.get("marginal", 0.15))
    if cfg.get("len_buckets") is not None:
        return LengthBucketTimeModel.linear(
            stage_times, buckets=buckets, marginal=marginal,
            len_buckets=tuple(int(b) for b in cfg["len_buckets"]),
            len_marginal=cfg.get("len_marginal"))
    return BatchTimeModel.linear(stage_times, buckets=buckets,
                                 marginal=marginal)


def validate_models(spec_models: dict) -> None:
    """Shape-level checks for ``ServeSpec.models`` — fail at spec time,
    not at first dispatch (the ``_validate_sharded_args`` discipline)."""
    if not isinstance(spec_models, dict):
        raise ValueError("ServeSpec.models must be a dict of model configs")
    shared = None
    for name, cfg in spec_models.items():
        if not isinstance(cfg, dict):
            raise ValueError(f"model {name!r}: config must be a dict")
        unknown = set(cfg) - set(ZOO_MODEL_KEYS)
        if unknown:
            raise ValueError(f"model {name!r}: unknown keys "
                             f"{sorted(unknown)}; known: "
                             f"{sorted(ZOO_MODEL_KEYS)}")
        if cfg.get("times") is None and cfg.get("stage_times") is None:
            raise ValueError(f"model {name!r}: needs 'stage_times' or "
                             "explicit 'times' rows")
        sts = cfg.get("stage_times")
        if sts is not None and (not sts
                                or any(float(t) <= 0 for t in sts)):
            raise ValueError(f"model {name!r}: stage_times must be a "
                             "non-empty list of positive seconds")
        buckets = tuple(int(b) for b in cfg.get("buckets", DEFAULT_BUCKETS))
        if list(buckets) != sorted(set(buckets)) or buckets[0] < 1:
            raise ValueError(f"model {name!r}: buckets must be strictly "
                             f"ascending integers >= 1, got {buckets}")
        if cfg.get("times") is not None \
                and len(cfg["times"]) != len(buckets):
            raise ValueError(f"model {name!r}: one 'times' row per bucket "
                             "required")
        if shared is None:
            shared = buckets
        elif buckets != shared:
            raise ValueError(f"model {name!r}: batch buckets {buckets} "
                             f"differ from the zoo's {shared} (the device "
                             "pre-compiles one shared bucket set)")
        mand = cfg.get("mandatory", 1)
        if isinstance(mand, bool) or not isinstance(mand, int) or mand < 1:
            raise ValueError(f"model {name!r}: mandatory must be an "
                             f"integer >= 1, got {mand!r}")
        if sts is not None and mand > len(sts):
            raise ValueError(f"model {name!r}: mandatory {mand} exceeds "
                             f"the model's {len(sts)} stages")
        if float(cfg.get("weight", 1.0)) <= 0:
            raise ValueError(f"model {name!r}: weight must be > 0")
        util = cfg.get("utility")
        if util is not None and (not util or any(
                not 0.0 <= float(u) <= 1.0 for u in util)):
            raise ValueError(f"model {name!r}: utility must be a non-empty "
                             "list of confidences in [0, 1]")
        lm = cfg.get("len_marginal")
        if lm is not None and not 0 <= float(lm) <= 1:
            raise ValueError(f"model {name!r}: len_marginal must be in "
                             "[0, 1]")
