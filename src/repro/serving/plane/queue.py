"""DurableQueue: idempotent, journaled submission + crash recovery.

**Submit path** — :class:`DurableQueue` fronts a live ``Service``:
every submission carries a client-supplied ``request_id``; the SUBMIT
record is appended *and fsynced* before the request enters the service,
so an acknowledged handle always has a durable record behind it.
Duplicate submits of the same ``request_id`` return the original handle
without touching the journal (and a replayed duplicate against a
recovered journal is a no-op) — at-most-once execution per id.

**Recovery** — the engine under the virtual clock is a deterministic
function of the arrival sequence (the PR-4 replay contract), so a crash
needs no checkpoint: :func:`recover` re-runs *all* journaled SUBMITs
through ``register_source("durable")`` (full redo — replaying only the
unfinished suffix would change the admission state the survivors saw
and diverge).  Requests already terminal in the journal get no new
RETIRE/REJECT records (idempotent appends) and are reported as
``already_delivered`` instead of re-resolved — exactly-once delivery;
everything else lands in ``responses``.  Resume-from-offset therefore
reproduces the uncrashed run's admission decisions bit-for-bit under
the virtual clock — :func:`verify_recovery` extends ``verify_replay``
to this mid-stream case.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.plane.journal import Journal, JournalObserver, scan_journal
from repro.serving.registry import register_source, resolve
from repro.serving.runtime.sources import StreamSource
from repro.serving.service import ServeSpec, Service


class DurableQueue:
    """Idempotent journaled front of one live :class:`Service`.

    Install *before* the first submission: the queue plants its
    :class:`JournalObserver` into ``service.resources`` so the build
    picks it up.  ``submit`` requires ``request.request_id``.
    """

    def __init__(self, service: Service, journal: Journal):
        self.service = service
        self.journal = journal
        if journal.spec is None:
            journal.spec = service.spec
        self._handles: dict = {}       # request_id -> ResponseHandle
        if "observer" not in service.resources:
            service.resources["observer"] = JournalObserver(journal)

    def submit(self, request, slo: Optional[str] = None,
               at: Optional[float] = None):
        rid = getattr(request, "request_id", None)
        if rid is None:
            raise ValueError("DurableQueue.submit needs request.request_id "
                             "(idempotence is keyed on it)")
        prior = self._handles.get(rid)
        if prior is not None:
            return prior               # duplicate: same handle, no journal
        offset = at
        if offset is None:
            offset = (self.service._ensure_live().clock.now()
                      if self.service._is_realtime() else 0.0)
        self.journal.append(
            "SUBMIT", offset=offset, sample=request.sample,
            client=request.client,
            slo=slo if slo is not None else getattr(request, "slo", None),
            rel_deadline=request.rel_deadline,
            tenant=getattr(request, "tenant", None), request_id=rid,
            model=getattr(request, "model", None),
            sync=True)                 # durable before the handle exists
        handle = self.service.submit(request, slo=slo, at=offset)
        self._handles[rid] = handle
        return handle

    def pending(self) -> int:
        return sum(1 for h in self._handles.values() if not h.done())


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveryResult:
    """What :func:`recover` rebuilt from a journal.

    ``responses`` — request_id -> final per-request record for requests
    the redo newly delivered; ``already_delivered`` — request_id ->
    pre-crash outcome dict (their handles resolved before the crash; the
    redo re-executes them for determinism but delivers nothing twice).
    """
    metrics: object                    # ServiceMetrics of the redo run
    responses: dict
    already_delivered: dict
    replayed: int                      # journaled SUBMITs redone
    report: dict

    @property
    def delivered_once(self) -> bool:
        return not (set(self.responses) & set(self.already_delivered))


def recover(path: str, *, spec: Optional[ServeSpec] = None,
            journal: Optional[Journal] = None, **resources) -> RecoveryResult:
    """Rebuild pending state from the journal at ``path`` and redo it
    under the virtual clock.

    The spec comes from the journal header unless overridden; the clock
    is forced virtual (deterministic redo).  Live-capable registered
    sources other than ``"live"`` (the FrontDoor) are kept — the redo
    flows through the same queueing discipline the original run used;
    plain ``"live"`` submissions re-enter through
    ``register_source("durable")``.  Appends during the redo go through
    the same journal and dedup against what already exists, so recovery
    is itself crash-safe and re-runnable."""
    header, records = scan_journal(path)
    if spec is None:
        sd = header.get("spec")
        if sd is None:
            raise ValueError(f"journal {path!r} header carries no spec; "
                             "pass spec=")
        spec = ServeSpec.from_dict(sd)
    submits = [r for r in records if r.kind == "SUBMIT"]
    pre: dict = {}
    for r in records:
        if r.kind in ("RETIRE", "REJECT") and r.request_id is not None:
            pre[r.request_id] = dict(r.outcome or {}, kind=r.kind)
    jnl = journal if journal is not None else Journal(path, spec=spec)
    res = dict(resources)
    res["observer"] = JournalObserver(jnl)
    if spec.source != "live" and \
            getattr(resolve("source", spec.source), "live", False):
        # e.g. frontdoor: same discipline on the redo, fed the journaled
        # stream (Service.run materializes it into the source factory)
        spec = dataclasses.replace(spec, clock="virtual", clock_args={})
        res["requests"] = [(r.offset, r.request()) for r in submits]
    else:
        spec = dataclasses.replace(spec, clock="virtual", clock_args={},
                                   source="durable", source_args={})
        res["durable_records"] = submits
    metrics = Service.from_spec(spec, res).run()
    jnl.sync()
    if journal is None:
        jnl.close()
    responses, overlap_ok = {}, True
    for rec in metrics.per_request:
        rid = rec.get("request_id")
        if rid is None:
            continue
        if rid in pre:
            o = pre[rid]
            for key, cast in (("depth", int), ("missed", bool),
                              ("rejected", bool)):
                if key in o and cast(o[key]) != cast(rec[key]):
                    overlap_ok = False
        else:
            responses[rid] = rec
    report = dict(n_submits=len(submits), n_pre_delivered=len(pre),
                  n_redelivered=len(responses),
                  overlap_consistent=overlap_ok)
    return RecoveryResult(metrics=metrics, responses=responses,
                          already_delivered=pre, replayed=len(submits),
                          report=report)


def verify_recovery(reference_per_request, result: RecoveryResult) -> dict:
    """``verify_replay`` extended to mid-stream resume: the redo must
    reproduce the uncrashed reference's arrival order and admission
    decisions bit-for-bit, *and* deliver each request exactly once
    (pre-crash outcomes are not re-delivered)."""
    from repro.serving.traffic.trace import verify_replay
    rep = verify_replay(reference_per_request, result.metrics.per_request)
    rep["delivered_once"] = result.delivered_once
    rep["overlap_consistent"] = result.report["overlap_consistent"]
    rep["recovered"] = bool(rep["bitwise"] and rep["delivered_once"])
    return rep


@register_source("durable")
def _make_durable(args: dict, ctx):
    """Journaled SUBMITs re-injected as a plain stream.  Reads the
    ``durable_records`` resource ([Record]) or scans
    ``source_args={"path": journal_dir}``."""
    recs = ctx.resources.get("durable_records")
    if recs is None:
        path = args.get("path")
        if path is None:
            raise KeyError("source='durable' needs source_args={'path': ...}"
                           " or a 'durable_records' resource")
        _, records = scan_journal(path)
        recs = [r for r in records if r.kind == "SUBMIT"]
    return StreamSource([(r.offset, r.request()) for r in recs],
                        ctx.task_factory)
