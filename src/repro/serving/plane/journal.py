"""Write-ahead journal: the durable plane's on-disk request log.

A :class:`Journal` is a directory of JSONL segments::

    journal_dir/
      wal-000000.jsonl     {"type": "header", "version": 2, "segment": 0,
                            "source": "...", "spec": {...}}
                           {"kind": "SUBMIT", "seq": 0, ...}
                           ...
      wal-000001.jsonl     (rotated after ``segment_records`` records)

generalizing the PR-4 trace format (one header line, then
:class:`~repro.serving.plane.records.Record` lines) into an *append*
log: ``seq`` is a monotonic offset across segments, appends are
idempotent (a second record with the same ``(kind, request_id)`` is a
no-op — what makes crash recovery re-runnable), and fsyncs are batched
(``fsync_every``) with ``sync=True`` available for the points that must
be durable before the caller proceeds — SUBMIT before the handle is
returned, RETIRE before the handle resolves.

Reopening an existing directory replays the segments to rebuild the
dedup index and continue the ``seq`` counter — the crash-recovery path
(:func:`repro.serving.plane.queue.recover`) appends through the same
journal it reads, and only genuinely-new records land.

:func:`scan_journal` tolerates a torn final line (a crash mid-append):
the partial tail is ignored, everything before it is intact — records
are only ever appended, never rewritten.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

from repro.serving.plane.records import RECORD_VERSION, Record

_SEGMENT_FMT = "wal-{:06d}.jsonl"


def _segment_paths(path: str) -> list:
    try:
        names = sorted(n for n in os.listdir(path)
                       if n.startswith("wal-") and n.endswith(".jsonl"))
    except FileNotFoundError:
        return []
    return [os.path.join(path, n) for n in names]


def _read_segment(seg_path: str, last: bool) -> tuple:
    """(header_or_None, [Record]) of one segment; a torn final line is
    tolerated only on the *last* segment (the only place a crash can
    leave one)."""
    header, records = None, []
    with open(seg_path) as f:
        lines = f.read().split("\n")
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            d = json.loads(line)
        except json.JSONDecodeError:
            if last and i == len(lines) - 1:
                break                  # torn tail from a mid-append crash
            raise ValueError(f"corrupt journal line {i} in {seg_path!r}")
        if d.get("type") == "header":
            header = d
        else:
            records.append(Record.from_dict(d))
    return header, records


def scan_journal(path: str) -> tuple:
    """Read every segment -> (header dict, [Record] in seq order)."""
    segs = _segment_paths(path)
    if not segs:
        raise FileNotFoundError(f"no journal segments under {path!r}")
    header, records = {}, []
    for i, seg in enumerate(segs):
        h, recs = _read_segment(seg, last=(i == len(segs) - 1))
        if h is not None and not header:
            header = h
        records.extend(recs)
    records.sort(key=lambda r: (r.seq if r.seq is not None else -1))
    return header, records


class Journal:
    """Append-only, segment-rotated, fsync-batched record log.

    ``spec`` (a ``ServeSpec``) goes into every segment header so
    recovery can rebuild the exact engine; ``fsync_every`` batches
    fsyncs (``lag()`` reports records flushed but not yet fsynced);
    ``segment_records`` caps records per segment before rotation.
    """

    def __init__(self, path: str, spec=None, *, source: str = "plane",
                 fsync_every: int = 8, segment_records: int = 4096):
        if fsync_every < 1:
            raise ValueError("fsync_every must be >= 1")
        if segment_records < 1:
            raise ValueError("segment_records must be >= 1")
        self.path = path
        self.source = source
        self.fsync_every = int(fsync_every)
        self.segment_records = int(segment_records)
        self.counts: dict = {}          # kind -> appended (this + prior lives)
        self._seen: set = set()         # dedup keys across all segments
        self._lock = threading.Lock()
        self._f = None
        self._seq = 0                   # next seq to assign
        self._seg = 0                   # current segment index
        self._seg_n = 0                 # records in the current segment
        self._unsynced = 0
        os.makedirs(path, exist_ok=True)
        segs = _segment_paths(path)
        header = None
        for i, seg in enumerate(segs):
            h, recs = _read_segment(seg, last=(i == len(segs) - 1))
            if h is not None and header is None:
                header = h
            for r in recs:
                key = r.dedup_key()
                if key is not None:
                    self._seen.add(key)
                self.counts[r.kind] = self.counts.get(r.kind, 0) + 1
                if r.seq is not None:
                    self._seq = max(self._seq, r.seq + 1)
            if i == len(segs) - 1:
                self._seg, self._seg_n = i, len(recs)
        if spec is None and header is not None and "spec" in header:
            from repro.serving.service import ServeSpec
            spec = ServeSpec.from_dict(header["spec"])
        self.spec = spec
        if segs:
            # a crash can leave a torn final line on the last segment;
            # records are line-framed, so drop it before appending
            with open(segs[-1], "r+") as f:
                data = f.read()
                if data and not data.endswith("\n"):
                    f.seek(data.rfind("\n") + 1)
                    f.truncate()
            self._f = open(segs[-1], "a")
        else:
            self._open_segment(0)

    # -- segments ------------------------------------------------------
    def _header(self, seg: int) -> dict:
        h = dict(type="header", version=RECORD_VERSION, segment=seg,
                 source=self.source)
        if self.spec is not None:
            h["spec"] = self.spec.to_dict()
        return h

    def _open_segment(self, seg: int) -> None:
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._unsynced = 0
        self._seg, self._seg_n = seg, 0
        self._f = open(os.path.join(self.path, _SEGMENT_FMT.format(seg)), "w")
        self._f.write(json.dumps(self._header(seg)) + "\n")
        self._f.flush()

    # -- append --------------------------------------------------------
    def append(self, kind: str, *, offset: float, sample: int = 0,
               client: int = 0, slo: Optional[str] = None,
               rel_deadline: Optional[float] = None,
               tenant: Optional[str] = None,
               request_id: Optional[str] = None,
               outcome: Optional[dict] = None,
               model: Optional[str] = None,
               sync: bool = False) -> Optional[Record]:
        """Durably append one record; returns it, or ``None`` when an
        identical ``(kind, request_id)`` record already exists (the
        idempotence that makes recovery re-runnable)."""
        with self._lock:
            rec = Record(offset=float(offset), sample=int(sample),
                         client=int(client), slo=slo,
                         rel_deadline=rel_deadline, outcome=outcome,
                         kind=kind, tenant=tenant, request_id=request_id,
                         seq=self._seq, model=model)
            key = rec.dedup_key()
            if key is not None and key in self._seen:
                return None
            if self._seg_n >= self.segment_records:
                self._open_segment(self._seg + 1)
            self._f.write(rec.to_json() + "\n")
            self._f.flush()
            self._seq += 1
            self._seg_n += 1
            self._unsynced += 1
            if key is not None:
                self._seen.add(key)
            self.counts[kind] = self.counts.get(kind, 0) + 1
            if sync or self._unsynced >= self.fsync_every:
                os.fsync(self._f.fileno())
                self._unsynced = 0
            return rec

    def sync(self) -> None:
        with self._lock:
            if self._f is not None and self._unsynced:
                os.fsync(self._f.fileno())
                self._unsynced = 0

    def lag(self) -> int:
        """Records written but not yet fsynced (the journal's durability
        lag under batched fsyncs)."""
        return self._unsynced

    @property
    def next_seq(self) -> int:
        return self._seq

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()
                os.fsync(self._f.fileno())
                self._f.close()
                self._f = None
                self._unsynced = 0

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class JournalObserver:
    """The ``observer`` resource that wires a :class:`Journal` into the
    ``Service`` lifecycle: ADMIT when the task factory claims a request,
    STAGE per in-time anytime exit, RETIRE/REJECT (fsynced) *before* the
    response handle resolves — so an outcome a caller has seen is always
    on disk.  Requests without a ``request_id`` are not journaled (they
    were never durably submitted)."""

    def __init__(self, journal: Journal):
        self.journal = journal
        self._rids: dict = {}          # tid -> (tenant, request_id)

    def on_admit(self, task, request, now: float) -> None:
        rid = getattr(request, "request_id", None)
        if rid is None:
            return
        tenant = getattr(request, "tenant", None)
        self._rids[task.tid] = (tenant, rid)
        self.journal.append("ADMIT", offset=now, sample=task.sample,
                            client=task.client, tenant=tenant,
                            request_id=rid,
                            model=getattr(request, "model", None))

    def on_stage(self, task, now: float) -> None:
        ent = self._rids.get(task.tid)
        if ent is None:
            return
        self.journal.append("STAGE", offset=now, sample=task.sample,
                            client=task.client, tenant=ent[0],
                            request_id=ent[1],
                            outcome={"depth": task.executed},
                            model=getattr(task, "model", None))

    def on_retire(self, rec: dict, now: float) -> None:
        rid = rec.get("request_id")
        if rid is None:
            return
        self._rids.pop(rec["tid"], None)
        outcome = {k: rec[k] for k in ("depth", "missed", "rejected",
                                       "latency", "deadline", "conf",
                                       "weight", "depth_cap")
                   if rec.get(k) is not None}
        self.journal.append(
            "REJECT" if rec["rejected"] else "RETIRE", offset=now,
            sample=rec["sample"], client=rec["client"], slo=rec["slo"],
            rel_deadline=rec.get("rel_deadline"), tenant=rec.get("tenant"),
            request_id=rid, outcome=outcome, model=rec.get("model"),
            sync=True)
