"""One record codec for traces *and* the write-ahead journal.

PR 4's trace schema (``repro.serving.traffic.trace``) and the durable
plane's journal share this line format: a :class:`Record` is one JSONL
line — when/what arrived, who sent it, and (optionally) what happened.
``kind`` distinguishes the journal's state transitions; plain trace
events keep the default ``EVENT`` and serialize byte-identically to the
version-1 lines, so checked-in traces keep replaying and old readers
keep working.

Record kinds (write-ahead journal, ``repro.serving.plane.journal``)::

    SUBMIT   a request was accepted for durable execution (logged, and
             fsynced, *before* the submission returns its handle)
    ADMIT    the engine turned the request into a Task
    STAGE    one anytime stage exit completed in time
    RETIRE   the request left the system with its final outcome
    REJECT   the request was refused (admission control / tenant quota)
    EVENT    a plain trace row (record/replay; the version-1 schema)

Version history: 1 — trace events only (no ``kind``); 2 — this unified
schema (``kind`` + ``tenant``/``request_id``/``seq`` fields, emitted
only when set, so EVENT rows are unchanged on disk).  The optional
``model`` field (model-zoo serving) follows the same emit-only-when-set
rule, so v1 and v2 files without it round-trip byte-identically.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.serving.engine import Request

RECORD_VERSION = 2

RECORD_KINDS = ("SUBMIT", "ADMIT", "STAGE", "RETIRE", "REJECT", "EVENT")

#: terminal kinds: the request left the system, outcome attached
TERMINAL_KINDS = ("RETIRE", "REJECT")


@dataclasses.dataclass(frozen=True)
class Record:
    """One recorded request event: arrival identity + optional outcome."""

    offset: float
    sample: int = 0
    client: int = 0
    slo: Optional[str] = None
    rel_deadline: Optional[float] = None
    outcome: Optional[dict] = None
    kind: str = "EVENT"
    tenant: Optional[str] = None
    request_id: Optional[str] = None
    seq: Optional[int] = None          # journal offset (monotonic append)
    model: Optional[str] = None        # model-zoo id (emitted only when set)

    def to_json(self) -> str:
        d = dict(offset=self.offset, sample=self.sample, client=self.client,
                 slo=self.slo, rel_deadline=self.rel_deadline)
        if self.kind != "EVENT":
            d["kind"] = self.kind
        if self.tenant is not None:
            d["tenant"] = self.tenant
        if self.model is not None:
            d["model"] = self.model
        if self.request_id is not None:
            d["request_id"] = self.request_id
        if self.seq is not None:
            d["seq"] = self.seq
        if self.outcome is not None:
            d["outcome"] = self.outcome
        return json.dumps(d)

    @classmethod
    def from_dict(cls, d: dict) -> "Record":
        # tolerant of version-1 lines: no kind/tenant/request_id/seq
        kind = d.get("kind", "EVENT")
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown record kind {kind!r}; "
                             f"known: {RECORD_KINDS}")
        seq = d.get("seq")
        return cls(offset=float(d["offset"]), sample=int(d.get("sample", 0)),
                   client=int(d.get("client", 0)), slo=d.get("slo"),
                   rel_deadline=d.get("rel_deadline"),
                   outcome=d.get("outcome"), kind=kind,
                   tenant=d.get("tenant"), request_id=d.get("request_id"),
                   seq=int(seq) if seq is not None else None,
                   model=d.get("model"))

    def request(self) -> Request:
        """Re-materialize the submission this record describes."""
        return Request(inputs=None, rel_deadline=self.rel_deadline,
                       sample=self.sample, client=self.client,
                       arrival=self.offset, slo=self.slo,
                       tenant=self.tenant, request_id=self.request_id,
                       model=self.model)

    def dedup_key(self):
        """Idempotent-append key: a journal refuses a second record with
        the same key (``None`` — anonymous records — never dedup).
        STAGE records key on depth too: one request exits many stages."""
        if self.request_id is None:
            return None
        if self.kind == "STAGE":
            return (self.kind, self.request_id,
                    (self.outcome or {}).get("depth"))
        return (self.kind, self.request_id)
