"""Offline health/stats over a journal directory.

The journal is the durable plane's source of truth, so the health
surface needs no live process: :func:`journal_stats` folds the segments
into queue depth (journaled SUBMITs with no terminal record yet),
per-tenant admit/retire/reject counts, and segment/lag figures.
``tools/planectl.py`` is the CLI over this module; a live process gets
the same numbers (plus the in-memory queue state) from
``FrontDoor.stats()``.
"""
from __future__ import annotations

from repro.serving.plane.journal import _segment_paths, scan_journal
from repro.serving.plane.records import TERMINAL_KINDS


def journal_stats(path: str) -> dict:
    """Fold the journal at ``path`` into a health/stats dict:

    ``pending`` — request_ids durably SUBMITted but not yet terminal
    (what :func:`~repro.serving.plane.queue.recover` would redeliver);
    ``per_tenant`` — submitted/admitted/retired/rejected/staged counts
    plus per-tenant pending depth; ``per_model`` — the same fold keyed
    by ``Record.model`` (only for records carrying a model-zoo id, so a
    single-model journal reports ``per_model={}``); ``counts`` — records
    by kind; ``segments``/``records``/``last_seq`` — journal shape.
    """
    header, records = scan_journal(path)
    counts: dict = {}
    per_tenant: dict = {}
    per_model: dict = {}
    submitted: dict = {}               # request_id -> tenant
    model_of: dict = {}                # request_id -> model (when zoo-tagged)
    terminal: set = set()
    last_seq = -1
    kind_key = {"SUBMIT": "submitted", "ADMIT": "admitted",
                "STAGE": "staged", "RETIRE": "retired",
                "REJECT": "rejected"}
    for r in records:
        counts[r.kind] = counts.get(r.kind, 0) + 1
        if r.seq is not None:
            last_seq = max(last_seq, r.seq)
        tenant = r.tenant or "default"
        t = per_tenant.setdefault(tenant, dict(
            submitted=0, admitted=0, staged=0, retired=0, rejected=0,
            pending=0))
        key = kind_key.get(r.kind)
        if key is not None:
            t[key] += 1
        model = getattr(r, "model", None)
        if model is not None and key is not None:
            m = per_model.setdefault(model, dict(
                submitted=0, admitted=0, staged=0, retired=0, rejected=0,
                pending=0))
            m[key] += 1
        if r.request_id is not None:
            if r.kind == "SUBMIT":
                submitted[r.request_id] = tenant
                if model is not None:
                    model_of[r.request_id] = model
            elif r.kind in TERMINAL_KINDS:
                terminal.add(r.request_id)
    pending = sorted(rid for rid in submitted if rid not in terminal)
    for rid in pending:
        per_tenant[submitted[rid]]["pending"] += 1
        if rid in model_of:
            per_model[model_of[rid]]["pending"] += 1
    return dict(
        path=path,
        version=header.get("version"),
        source=header.get("source"),
        has_spec="spec" in header,
        segments=len(_segment_paths(path)),
        records=len(records),
        last_seq=last_seq,
        counts=counts,
        queue_depth=len(pending),
        pending=pending,
        per_tenant=per_tenant,
        per_model=per_model,
    )
