"""FrontDoor: multi-tenant admission — quotas + weighted fair queueing.

The layer between clients and the engine (``spec.source="frontdoor"``):

* **Token-bucket quotas** — ``spec.tenants[name] = {"rate": r, "burst":
  b, "weight": w}``: an over-quota submission is refused at the door
  (immediately-resolved rejected handle, journaled as REJECT when a
  journal is attached) before it costs the engine anything.
* **Weighted fair queueing** — :class:`FrontDoorSource` holds one FIFO
  per tenant and releases requests to the engine by deficit round-robin
  (quantum proportional to tenant weight; an emptied queue forfeits its
  credit), optionally metered by a ``run_queue`` cap on requests in the
  engine at once — the knob that turns release order into *service*
  order under overload.  ``discipline="fifo"`` releases in global
  arrival order instead (the baseline the benchmark starves).
* **Weight composition** — tenant weight multiplies the SLO class's
  ``utility_weight`` into ``Task.weight``, so the FPTAS utility
  objective sees tenant priority end to end.

Works on both clocks like ``source="live"``: wall clock pushes into the
source behind a background engine; virtual clock buffers submissions
and ``drain()`` replays them through the same DRR arbitration
discrete-event (deterministic — what the recovery and fairness claims
are checked against).
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Optional

from repro.serving.plane.journal import Journal
from repro.serving.plane.queue import DurableQueue
from repro.serving.registry import register_source
from repro.serving.runtime.sources import RequestSource
from repro.serving.service import ResponseHandle, Service

_EPS = 1e-12

DISCIPLINES = ("drr", "fifo")

#: queue name for requests submitted without a tenant label
DEFAULT_TENANT = "default"


class TokenBucket:
    """Deterministic token bucket: refill is computed from the submit
    timestamps themselves (virtual or wall), so a replayed submission
    sequence meets identical quota decisions."""

    def __init__(self, rate: float, burst: float):
        if rate <= 0:
            raise ValueError("rate must be > 0")
        if burst < 1:
            raise ValueError("burst must be >= 1")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t = None

    def allow(self, t: float) -> bool:
        if self._t is not None and t > self._t:
            self.tokens = min(self.burst,
                              self.tokens + (t - self._t) * self.rate)
        self._t = t if self._t is None else max(self._t, t)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class FrontDoorSource(RequestSource):
    """Per-tenant queues released to the engine by DRR (or global FIFO).

    ``run_queue`` caps requests concurrently inside the engine (released
    minus retired); releases beyond it wait in their tenant queue — the
    backlog the fair-queueing discipline arbitrates.  Thread-safe like
    ``LiveSource`` (wall-clock pushes race the engine thread).
    """

    live = True                        # Service.submit may target this source

    def __init__(self, task_factory, clock, *, tenants: dict = None,
                 discipline: str = "drr", quantum: float = 1.0,
                 run_queue: Optional[int] = None, poll: float = 0.002):
        if discipline not in DISCIPLINES:
            raise ValueError(f"discipline {discipline!r} not in "
                             f"{DISCIPLINES}")
        self.task_factory = task_factory
        self.clock = clock
        self.discipline = discipline
        self.quantum = float(quantum)
        self.run_queue = int(run_queue) if run_queue is not None else None
        self.poll = float(poll)
        self._weights = {name: float(cfg.get("weight", 1.0))
                         for name, cfg in (tenants or {}).items()}
        self._queues: dict = {name: deque() for name in sorted(self._weights)}
        self._order: list = sorted(self._weights)
        self._budget: dict = {name: 0.0 for name in self._order}
        self._cursor = 0
        self._granted = False          # cursor's queue got its quantum
                                       # this visit already
        self._n = 0                    # push tiebreak (global arrival order)
        self._inflight = 0             # released to the engine, not retired
        self.released = 0
        self._lock = threading.Lock()
        # a virtual-clock build is always fed its whole stream up front
        # (Service.drain), so the intake starts closed — the loop must
        # terminate when the queues drain
        self._closed = not getattr(clock, "realtime", False)

    # -- intake --------------------------------------------------------
    def push(self, offset: float, request) -> None:
        tenant = getattr(request, "tenant", None) or DEFAULT_TENANT
        with self._lock:
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = deque()
                self._order.append(tenant)
                self._budget[tenant] = 0.0
            q.append((float(offset), self._n, request))
            self._n += 1

    def close(self) -> None:
        self._closed = True

    # -- source contract -----------------------------------------------
    def _gated(self) -> bool:
        return self.run_queue is not None and self._inflight >= self.run_queue

    def has_pending(self) -> bool:
        with self._lock:
            return any(self._queues.values()) or not self._closed

    def next_time(self) -> float:
        with self._lock:
            heads = [q[0][0] for q in self._queues.values() if q]
            if not heads or self._gated():
                # gated or empty: a retirement (which frees a slot) or a
                # push reopens the tap; the wall clock polls for it, the
                # virtual loop sees it at the next completion event
                return math.inf if self._closed \
                    else self.clock.now() + self.poll
            return min(heads)

    def pop(self, now: float):
        with self._lock:
            if self._gated():
                return None
            tenant = self._pick(now)
            if tenant is None:
                return None
            off, _, req = self._queues[tenant].popleft()
        req.arrival = off
        task = self.task_factory(req, now)
        if task is not None:
            with self._lock:
                self._inflight += 1
                self.released += 1
        return task

    def on_retire(self, task, now: float) -> None:
        with self._lock:
            self._inflight = max(0, self._inflight - 1)

    def qsize(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def tenant_depths(self) -> dict:
        with self._lock:
            return {t: len(q) for t, q in self._queues.items() if q}

    # -- arbitration ----------------------------------------------------
    def _eligible(self, now: float) -> list:
        return [t for t in self._order
                if self._queues[t] and self._queues[t][0][0] <= now + _EPS]

    def _pick(self, now: float) -> Optional[str]:
        elig = self._eligible(now)
        if not elig:
            return None
        if self.discipline == "fifo":
            return min(elig, key=lambda t: self._queues[t][0][:2])
        return self._drr_pick(set(elig))

    def _advance(self) -> None:
        self._cursor += 1
        self._granted = False

    def _drr_pick(self, elig: set) -> Optional[str]:
        """Deficit round-robin, one release per call: the cursor parks on
        a tenant while its credit lasts (so consecutive releases drain
        one queue up to its quantum), grants the quantum at most once per
        cursor visit (the ``_granted`` latch — without it every pop()
        re-grants the head queue and the round-robin degenerates to
        FIFO), and zeroes the credit of emptied queues (idle tenants
        accumulate nothing)."""
        n = len(self._order)
        for _ in range(2 * n + 1):
            t = self._order[self._cursor % n]
            if not self._queues[t]:
                self._budget[t] = 0.0
                self._advance()
                continue
            if t not in elig:
                self._advance()
                continue
            if self._budget[t] >= 1.0:
                self._budget[t] -= 1.0
                return t
            if not self._granted:
                self._granted = True
                self._budget[t] += self.quantum * self._weights.get(t, 1.0)
                if self._budget[t] >= 1.0:
                    self._budget[t] -= 1.0
                    return t
            self._advance()
        return sorted(elig)[0]         # degenerate quanta: don't stall


class FrontDoor:
    """The tenant-facing submission surface over one ``Service``.

    ``journal=`` makes submissions durable (and idempotent on
    ``request_id``) through a :class:`DurableQueue`; without it the door
    still enforces quotas and fair queueing.  ``stats()`` is the
    in-process health surface (``tools/planectl.py`` reads the same
    numbers offline from the journal)."""

    def __init__(self, service: Service, *, journal: Optional[Journal] = None):
        if service.spec.source != "frontdoor":
            raise ValueError("FrontDoor needs spec.source='frontdoor' "
                             f"(got {service.spec.source!r})")
        self.service = service
        self.journal = journal
        self.queue = DurableQueue(service, journal) \
            if journal is not None else None
        self.tenants = dict(service.spec.tenants or {})
        self._buckets = {
            name: TokenBucket(float(cfg["rate"]),
                              float(cfg.get("burst", max(1.0,
                                                         float(cfg["rate"])))))
            for name, cfg in self.tenants.items() if cfg.get("rate")}
        self.counts: dict = {}         # tenant -> submitted / quota_rejected
        self.model_counts: dict = {}   # zoo model -> same counters

    def submit(self, request, *, tenant: Optional[str] = None,
               slo: Optional[str] = None, at: Optional[float] = None,
               request_id: Optional[str] = None) -> ResponseHandle:
        if tenant is not None:
            request.tenant = tenant
        if request_id is not None:
            request.request_id = request_id
        name = getattr(request, "tenant", None) or DEFAULT_TENANT
        c = self.counts.setdefault(name,
                                   dict(submitted=0, quota_rejected=0))
        c["submitted"] += 1
        model = getattr(request, "model", None)
        mc = None
        if model is not None:
            mc = self.model_counts.setdefault(
                model, dict(submitted=0, quota_rejected=0))
            mc["submitted"] += 1
        t_sub = at
        if t_sub is None:
            t_sub = (self.service._ensure_live().clock.now()
                     if self.service._is_realtime() else 0.0)
        bucket = self._buckets.get(name)
        if bucket is not None and not bucket.allow(t_sub):
            if mc is not None:
                mc["quota_rejected"] += 1
            return self._quota_reject(request, name, slo, t_sub, c, bucket)
        if self.queue is not None:
            return self.queue.submit(request, slo=slo, at=at)
        return self.service.submit(request, slo=slo, at=at)

    def _quota_reject(self, request, tenant: str, slo, t_sub: float,
                      counts: dict, bucket: TokenBucket) -> ResponseHandle:
        counts["quota_rejected"] += 1
        svc = self.service
        svc._tenant_rejects[tenant] = svc._tenant_rejects.get(tenant, 0) + 1
        rid = getattr(request, "request_id", None)
        if self.journal is not None and rid is not None:
            self.journal.append(
                "REJECT", offset=t_sub, sample=request.sample,
                client=request.client,
                slo=slo if slo is not None else getattr(request, "slo", None),
                tenant=tenant, request_id=rid,
                outcome=dict(rejected=True, missed=True, depth=0,
                             quota=True), sync=True,
                model=getattr(request, "model", None))
        cls = svc.spec.slo_class(slo if slo is not None
                                 else getattr(request, "slo", None))
        return svc._reject_overflow(
            ResponseHandle(svc, request), request, cls,
            rule="tenant-quota", t=t_sub,
            detail={"tenant": tenant, "rate": bucket.rate,
                    "burst": bucket.burst,
                    "tokens": round(bucket.tokens, 6)})

    def drain(self):
        return self.service.drain()

    def stats(self) -> dict:
        """In-process health: per-tenant (and, for zoo-tagged requests,
        per-model) counters, queue depths, journal durability lag."""
        svc = self.service
        src = svc._live.source if svc._live is not None else None
        depths = src.tenant_depths() \
            if src is not None and hasattr(src, "tenant_depths") else {}
        out = dict(
            tenants={t: dict(c) for t, c in self.counts.items()},
            models={m: dict(c) for m, c in self.model_counts.items()},
            queued=depths,
            queue_depth=(src.qsize() if src is not None else 0)
            + len(svc._buffer),
            inflight=getattr(src, "_inflight", 0) if src is not None else 0,
        )
        if self.journal is not None:
            out["journal"] = dict(lag=self.journal.lag(),
                                  next_seq=self.journal.next_seq,
                                  counts=dict(self.journal.counts))
        return out


@register_source("frontdoor")
def _make_frontdoor(args: dict, ctx):
    """Multi-tenant fair-queueing intake.  ``source_args``:
    ``discipline`` ("drr"/"fifo"), ``quantum``, ``run_queue`` (engine
    concurrency cap), ``poll`` (wall-clock poll seconds)."""
    src = FrontDoorSource(ctx.task_factory, ctx.clock,
                          tenants=ctx.spec.tenants,
                          discipline=args.get("discipline", "drr"),
                          quantum=float(args.get("quantum", 1.0)),
                          run_queue=args.get("run_queue"),
                          poll=float(args.get("poll", 0.002)))
    for off, req in (ctx.stream or []):
        src.push(off, req)
    return src


_make_frontdoor.live = True           # Service.submit may target this key
