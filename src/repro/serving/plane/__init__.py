"""repro.serving.plane — the durable request plane.

Everything between a client and the scheduling engine that must survive
a crash: a write-ahead :class:`Journal` of request lifecycle
:class:`Record`\\ s, a :class:`DurableQueue` making submission
idempotent on ``request_id``, a multi-tenant :class:`FrontDoor`
(token-bucket quotas + deficit-round-robin fair queueing), and
:func:`recover` — full-redo crash recovery that reproduces the
uncrashed run's admission decisions bit-for-bit under the virtual
clock (:func:`verify_recovery` checks it).

Registered from outside the runtime core, like ``traffic`` and the
sharded executor: importing this package registers the ``"durable"``
and ``"frontdoor"`` source keys.
"""
from repro.serving.plane.frontdoor import (
    FrontDoor,
    FrontDoorSource,
    TokenBucket,
)
from repro.serving.plane.health import journal_stats
from repro.serving.plane.journal import Journal, JournalObserver, scan_journal
from repro.serving.plane.queue import (
    DurableQueue,
    RecoveryResult,
    recover,
    verify_recovery,
)
from repro.serving.plane.records import (
    RECORD_KINDS,
    RECORD_VERSION,
    TERMINAL_KINDS,
    Record,
)

__all__ = [
    "RECORD_KINDS",
    "RECORD_VERSION",
    "TERMINAL_KINDS",
    "DurableQueue",
    "FrontDoor",
    "FrontDoorSource",
    "Journal",
    "JournalObserver",
    "Record",
    "RecoveryResult",
    "TokenBucket",
    "journal_stats",
    "recover",
    "scan_journal",
    "verify_recovery",
]
