"""Discrete-event simulator for the *batched* serving path.

Identical workload/closed-loop/miss semantics to ``repro.core.simulator``
(K clients, reissue at completion, a request fails iff no stage finished
in time) — but the server dispatches (stage, [tasks]) micro-batches chosen
by a ``BatchPolicy``, and a batch of `n` at stage `s` occupies the
accelerator for ``time_model.wcet(s, n)`` (bucket-rounded, exactly what
the wall-clock ``BatchedServingEngine`` pays).

An optional ``AdmissionController`` runs at issue time; a rejected request
counts as a miss (depth 0) and frees its client immediately — rejecting is
a scheduling decision, not an accounting trick.
"""
from __future__ import annotations

import heapq
import time
from typing import Optional

import numpy as np

from repro.core.simulator import SimResult, Workload
from repro.core.task import Task
from repro.serving.batch.batcher import BatchTimeModel
from repro.serving.batch.policy import as_batch_policy


def simulate_batched(policy, workload: Workload, time_model: BatchTimeModel,
                     conf_table, correct_table, *,
                     charge_overhead: bool = False,
                     dispatch_overhead: float = 0.0,
                     admission=None, max_batch: int = None) -> SimResult:
    """Like ``repro.core.simulate`` but stage dispatches are micro-batches.

    `policy` may be any single-task Policy (wrapped via ``as_batch_policy``)
    or a ready-made BatchPolicy."""
    policy = as_batch_policy(policy, time_model, max_batch=max_batch)
    rng = np.random.default_rng(workload.seed)
    n_samples, L = conf_table.shape
    if time_model.num_stages != L:
        raise ValueError(f"time model has {time_model.num_stages} stages, "
                         f"oracle tables have {L}")
    single_times = time_model.single_times()

    sample_order = rng.permutation(n_samples)
    issued = 0

    def new_task(client, now):
        nonlocal issued
        if issued >= workload.n_requests:
            return None
        rel = rng.uniform(workload.d_lo, workload.d_hi)
        t = Task(arrival=now, deadline=now + rel, stage_times=single_times,
                 mandatory=workload.mandatory_stages,
                 sample=int(sample_order[issued % n_samples]), client=client)
        issued += 1
        return t

    now = 0.0
    active: list = []
    finished: list = []
    events = []                     # (time, seq, kind, payload)
    seq = 0
    for c in range(workload.n_clients):
        t0 = float(rng.uniform(0, workload.d_lo))
        heapq.heappush(events, (t0, seq, "issue", c))
        seq += 1

    running: Optional[tuple] = None      # ([tasks], finish_time)
    total_busy = 0.0

    def retire(task, now, rejected=False):
        if task in active:
            active.remove(task)
        depth = task.executed
        missed = depth == 0
        correct = (not missed) and bool(correct_table[task.sample, depth - 1])
        conf = float(conf_table[task.sample, depth - 1]) if depth else 0.0
        finished.append(dict(tid=task.tid, missed=missed, correct=correct,
                             depth=depth, conf=conf, client=task.client,
                             deadline=task.deadline, arrival=task.arrival,
                             rejected=rejected))
        # closed loop: client reissues at completion/rejection time
        heapq.heappush(events, (now, -task.tid, "issue", task.client))

    def charge(dt):
        nonlocal now
        if charge_overhead:
            now += dt

    while events or running or any(t.executed < t.assigned_depth
                                   for t in active):
        # 1. dispatch a batch if the accelerator is idle
        if running is None:
            for t in list(active):
                if t.deadline <= now:
                    retire(t, now)
            w0 = time.perf_counter()
            nb = policy.next_batch(active, now)
            charge(time.perf_counter() - w0
                   + (dispatch_overhead if nb else 0.0))
            if nb is not None:
                stage, batch = nb
                dur = time_model.wcet(stage, len(batch))
                running = (batch, now + dur)
                total_busy += dur
        # 2. advance to the next event
        next_event_t = events[0][0] if events else np.inf
        finish_t = running[1] if running else np.inf
        if not np.isfinite(min(next_event_t, finish_t)):
            break
        if finish_t <= next_event_t:
            now = finish_t
            batch, _ = running
            running = None
            for task in batch:
                if task.deadline >= now - 1e-12:
                    task.executed += 1
                    task.confidences.append(
                        float(conf_table[task.sample, task.executed - 1]))
                    w0 = time.perf_counter()
                    policy.on_stage_done(active, task, now)
                    charge(time.perf_counter() - w0)
            for task in batch:
                if task in active and (task.executed >= task.assigned_depth
                                       or task.deadline <= now):
                    retire(task, now)
        else:
            now = next_event_t
            _, _, kind, client = heapq.heappop(events)
            if kind == "issue":
                t = new_task(client, now)
                if t is None:
                    continue
                if admission is not None:
                    dec = admission.apply(active, t, now)
                    if not dec.admitted:
                        retire(t, now, rejected=True)
                        continue
                active.append(t)
                w0 = time.perf_counter()
                policy.on_arrival(active, t, now)
                charge(time.perf_counter() - w0)

    makespan = now
    for t in list(active):
        tend = max(now, t.deadline)
        makespan = max(makespan, tend)
        retire(t, tend)

    n = len(finished)
    acc = float(np.mean([f["correct"] for f in finished])) if n else 0.0
    miss = float(np.mean([f["missed"] for f in finished])) if n else 0.0
    depth = float(np.mean([f["depth"] for f in finished if not f["missed"]])
                  ) if n else 0.0
    conf = float(np.mean([f["conf"] for f in finished if not f["missed"]])
                 ) if n else 0.0
    denom = total_busy + policy.sched_time
    ok = sum(1 for f in finished if not f["missed"])
    return SimResult(accuracy=acc, miss_rate=miss, mean_depth=depth,
                     mean_conf=conf,
                     overhead_frac=policy.sched_time / denom if denom else 0.0,
                     n_requests=n, per_request=finished,
                     makespan=makespan,
                     throughput=ok / makespan if makespan > 0 else 0.0)
