"""Discrete-event simulator for the *batched* serving path.

Identical workload/closed-loop/miss semantics to ``repro.core.simulator``
(K clients, reissue at completion, a request fails iff no stage finished
in time) — but the server dispatches (stage, [tasks]) micro-batches chosen
by a ``BatchPolicy``, and a batch of `n` at stage `s` occupies the
accelerator for ``time_model.wcet(s, n)`` (bucket-rounded, exactly what
the wall-clock ``BatchedServingEngine`` pays).

An optional ``AdmissionController`` runs at issue time; a rejected request
counts as a miss (depth 0) and frees its client immediately — rejecting is
a scheduling decision, not an accounting trick.

``simulate_batched`` is a deprecated wrapper over the public serving
facade (``repro.serving.service``): a ``ServeSpec`` on the oracle
executor / virtual clock / closed-loop source with the caller's batch
time model; pipelined async dispatch is ``ServeSpec(pipeline_depth=2)``.
"""
from __future__ import annotations

from repro.core.simulator import SimResult, Workload
from repro.serving.batch.batcher import BatchTimeModel


def simulate_batched(policy, workload: Workload, time_model: BatchTimeModel,
                     conf_table, correct_table, *,
                     charge_overhead: bool = False,
                     dispatch_overhead: float = 0.0,
                     admission=None, max_batch: int = None) -> SimResult:
    """Like ``repro.core.simulate`` but stage dispatches are micro-batches.

    `policy` may be any single-task Policy (wrapped via ``as_batch_policy``)
    or a ready-made BatchPolicy."""
    from repro.serving.deprecation import deprecate_once
    from repro.serving.service import ServeSpec, Service

    deprecate_once(
        "repro.serving.batch.simulate_batched",
        "simulate_batched() is deprecated: build a ServeSpec(batching="
        "{'buckets': ..., ...}) and run it through repro.serving.Service "
        "instead")
    L = conf_table.shape[1]
    if time_model.num_stages != L:
        raise ValueError(f"time model has {time_model.num_stages} stages, "
                         f"oracle tables have {L}")
    spec = ServeSpec(
        executor="oracle", clock="virtual", source="closed-loop",
        batching={"max_batch": max_batch},
        charge_overhead=charge_overhead,
        dispatch_overhead=dispatch_overhead)
    return Service.from_spec(spec, policy=policy, workload=workload,
                             time_model=time_model, admission=admission,
                             conf_table=conf_table,
                             correct_table=correct_table).run()
