"""Continuous stage-level micro-batching serving subsystem.

Layers (see ROADMAP.md "Serving architecture"):
  batcher     BatchTimeModel (per-bucket stage WCETs) + StageBatcher
              (greedy deadline-feasible batch formation)          [no jax]
  policy      BatchPolicy contract + BatchedPolicy adapter        [no jax]
  admission   AdmissionController (reject / depth-cap)            [no jax]
  stage_fns   padded, shape-bucketed jitted stage functions
  engine      BatchedServingEngine (wall clock)
  simulator   simulate_batched (discrete event) — same policies,
              same batch semantics as the wall-clock path
"""
from repro.serving.batch.admission import (AdmissionController,
                                           AdmissionDecision)
from repro.serving.batch.batcher import (DEFAULT_BUCKETS, BatchTimeModel,
                                         StageBatcher, bucket_for)
from repro.serving.batch.engine import BatchedServingEngine
from repro.serving.batch.policy import (BatchedPolicy, BatchPolicy,
                                        as_batch_policy)
from repro.serving.batch.simulator import simulate_batched
from repro.serving.batch.stage_fns import (BatchedStageFns, StagingBuffers,
                                           pad_batch,
                                           profile_batched_stages,
                                           split_rows)
from repro.serving.batch.time_model import (DEFAULT_LEN_BUCKETS,
                                            LengthBucketTimeModel,
                                            batch_wcet, len_bucket_for,
                                            task_len_bucket)

__all__ = [
    "AdmissionController", "AdmissionDecision", "BatchTimeModel",
    "BatchedPolicy", "BatchPolicy", "BatchedServingEngine",
    "BatchedStageFns", "DEFAULT_BUCKETS", "DEFAULT_LEN_BUCKETS",
    "LengthBucketTimeModel", "StageBatcher", "StagingBuffers",
    "as_batch_policy", "batch_wcet", "bucket_for", "len_bucket_for",
    "pad_batch", "profile_batched_stages", "simulate_batched",
    "split_rows", "task_len_bucket",
]
