"""BatchedServingEngine — wall-clock continuous stage-level micro-batching.

The unbatched ``ServingEngine`` dispatches one request's stage at a time;
on a real accelerator that strands almost all throughput.  This engine
keeps the paper's user-space decision loop (admit → schedule → run one
non-preemptive unit → observe confidences → respond) but the dispatch
unit is a *padded, shape-bucketed batch* of same-stage tasks:

* a ``BatchPolicy`` picks ``(stage, [tasks])`` each cycle — plain policies
  are wrapped so RTDeepIoT/EDF/LCF/RR decide batch composition with their
  own preference order, under the invariant that no admission pushes a
  member past its deadline (batch WCET = profiled per-bucket stage time);
* §II-B deadline adjustment: the non-preemptible region is now one
  **batched** stage, so the caller-visible deadline shrinks by the host
  overhead plus the largest batched stage WCET;
* an optional ``AdmissionController`` rejects/depth-caps at arrival.

Every bucketed shape is compiled in warm-up, so steady state never
recompiles.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.task import Task
from repro.serving.batch.admission import AdmissionController
from repro.serving.batch.batcher import BatchTimeModel
from repro.serving.batch.policy import BatchPolicy, as_batch_policy
from repro.serving.batch.stage_fns import BatchedStageFns
from repro.serving.engine import Request, Response


class BatchedServingEngine:
    def __init__(self, cfg, params, policy, *, time_model: BatchTimeModel,
                 host_overhead: float = 0.0, stage_fns: BatchedStageFns = None,
                 admission: AdmissionController = None,
                 max_batch: int = None):
        self.cfg = cfg
        self.params = params
        self.time_model = time_model
        self.stage_fns = stage_fns or BatchedStageFns(cfg, time_model.buckets)
        self.policy: BatchPolicy = as_batch_policy(policy, time_model,
                                                   max_batch=max_batch)
        # largest batch this engine can actually dispatch — a custom
        # BatchPolicy without a batcher is bounded only by the bucket set
        batcher = getattr(self.policy, "batcher", None)
        self._effective_max_batch = batcher.max_batch if batcher is not None \
            else time_model.max_batch
        self.admission = admission
        self.host_overhead = host_overhead
        self.responses: list = []
        self._active: list = []
        self._states: dict = {}     # tid -> [request, hidden/inputs, result]

    # ------------------------------------------------------------------
    def _admit(self, req: Request, now: float):
        # §II-B with batching: the non-preemptible region is one *batched*
        # stage, priced at the largest batch this engine will dispatch
        worst = max(self.time_model.wcet(s, self._effective_max_batch)
                    for s in range(self.cfg.num_stages))
        adj = self.host_overhead + worst
        t = Task(arrival=now, deadline=req.arrival + req.rel_deadline - adj,
                 stage_times=self.time_model.single_times(),
                 mandatory=self.cfg.mandatory_stages, sample=req.sample,
                 client=req.client)
        if self.admission is not None:
            dec = self.admission.apply(self._active, t, now)
            if not dec.admitted:
                self.responses.append(Response(req.sample, None, 0.0, 0,
                                               True, now - req.arrival,
                                               t.deadline))
                return None
        self._active.append(t)
        self._states[t.tid] = [req, req.inputs, None]
        self.policy.on_arrival(self._active, t, now)
        return t

    def _respond(self, task: Task, now: float):
        req, _h, result = self._states.pop(task.tid)
        self._active.remove(task)
        if result is None:
            self.responses.append(Response(task.sample, None, 0.0, 0,
                                           True, now - req.arrival,
                                           task.deadline))
        else:
            pred, conf = result
            self.responses.append(Response(task.sample, int(pred),
                                           float(conf), task.executed, False,
                                           now - req.arrival, task.deadline))

    # ------------------------------------------------------------------
    def run(self, request_stream):
        """request_stream: iterable of (offset_seconds, Request), offsets
        non-decreasing relative to engine start."""
        pending = list(request_stream)
        pending.sort(key=lambda p: p[0])
        if pending:   # compile every (stage, bucket) before the clock starts
            self.stage_fns.warmup(self.params, pending[0][1].inputs)
        t_start = time.perf_counter()
        now = 0.0
        i = 0
        while i < len(pending) or self._active:
            now = time.perf_counter() - t_start
            while i < len(pending) and pending[i][0] <= now:
                off, req = pending[i]
                req.arrival = off
                self._admit(req, now)
                i += 1
            for t in list(self._active):
                if t.deadline <= now:
                    self._respond(t, now)
            nb = self.policy.next_batch(self._active, now)
            if nb is None:
                if i < len(pending):
                    time.sleep(max(0.0, min(pending[i][0] - now, 0.005)))
                    continue
                if not self._active:
                    break
                time.sleep(0.0005)
                continue
            # run one batched stage (the non-preemptive unit)
            stage, batch = nb
            states = [self._states[t.tid] for t in batch]
            h_out, logits, conf, _mask = self.stage_fns.run(
                stage, self.params, [st[1] for st in states])
            jax.block_until_ready(h_out)
            logits = np.asarray(logits)
            conf = np.asarray(conf)
            now = time.perf_counter() - t_start
            for k, (t, st) in enumerate(zip(batch, states)):
                if t.deadline >= now:          # stage finished in time
                    t.executed += 1
                    c = float(np.max(conf[k]))
                    t.confidences.append(c)
                    lg = logits[k]
                    pred = int(np.argmax(lg[0], -1)) if lg.ndim >= 2 \
                        else int(np.argmax(lg))
                    st[1] = jax.tree.map(lambda x: x[k:k + 1], h_out)
                    st[2] = (pred, c)
                    self.policy.on_stage_done(self._active, t, now)
            for t in batch:
                if t in self._active and (t.executed >= t.assigned_depth
                                          or t.deadline <= now):
                    self._respond(t, now)
        return self.responses
