"""BatchedServingEngine — wall-clock continuous stage-level micro-batching.

The unbatched ``ServingEngine`` dispatches one request's stage at a time;
on a real accelerator that strands almost all throughput.  This engine
keeps the paper's user-space decision loop (admit → schedule → run one
non-preemptive unit → observe confidences → respond) but the dispatch
unit is a *padded, shape-bucketed batch* of same-stage tasks:

* a ``BatchPolicy`` picks ``(stage, [tasks])`` each cycle — plain policies
  are wrapped so RTDeepIoT/EDF/LCF/RR decide batch composition with their
  own preference order, under the invariant that no admission pushes a
  member past its deadline (batch WCET = profiled per-bucket stage time);
* §II-B deadline adjustment: the non-preemptible region is now one
  **batched** stage, so the caller-visible deadline shrinks by the host
  overhead plus the largest batched stage WCET;
* an optional ``AdmissionController`` rejects/depth-caps at arrival.

Every bucketed shape is compiled in warm-up, so steady state never
recompiles.

``run`` is a deprecated wrapper over the public serving facade
(``repro.serving.service``): a ``ServeSpec`` on the ``device-batched``
executor / wall clock / stream source.  Because the device executor
dispatches asynchronously, ``pipelined()`` returns an engine whose core
pre-selects the next batch while the current one runs
(``ServeSpec(pipeline_depth=2)``) — the host/device overlap the ROADMAP's
async item asks for — without changing this class's legacy constructor or
``run`` signature.
"""
from __future__ import annotations

from repro.serving.batch.admission import AdmissionController
from repro.serving.batch.batcher import BatchTimeModel
from repro.serving.batch.policy import BatchPolicy, as_batch_policy
from repro.serving.batch.stage_fns import BatchedStageFns


class BatchedServingEngine:
    def __init__(self, cfg, params, policy, *, time_model: BatchTimeModel,
                 host_overhead: float = 0.0, stage_fns: BatchedStageFns = None,
                 admission: AdmissionController = None,
                 max_batch: int = None):
        self.cfg = cfg
        self.params = params
        self.time_model = time_model
        self.stage_fns = stage_fns or BatchedStageFns(cfg, time_model.buckets)
        self.policy: BatchPolicy = as_batch_policy(policy, time_model,
                                                   max_batch=max_batch)
        # largest batch this engine can actually dispatch — a custom
        # BatchPolicy without a batcher is bounded only by the bucket set
        batcher = getattr(self.policy, "batcher", None)
        self._effective_max_batch = batcher.max_batch if batcher is not None \
            else time_model.max_batch
        self.admission = admission
        self.host_overhead = host_overhead
        self.responses: list = []
        self._pipeline_depth = 1

    def pipelined(self, depth: int = 2) -> "BatchedServingEngine":
        """Enable pipelined async dispatch (host pre-selects batch N+1 while
        batch N runs on the device).  Returns self for chaining."""
        self._pipeline_depth = depth
        return self

    # ------------------------------------------------------------------
    def run(self, request_stream):
        """request_stream: iterable of (offset_seconds, Request), offsets
        non-decreasing relative to engine start."""
        from repro.serving.deprecation import deprecate_once
        from repro.serving.service import ServeSpec, Service

        deprecate_once(
            "repro.serving.batch.BatchedServingEngine.run",
            "BatchedServingEngine is deprecated: build a ServeSpec("
            "executor='device-batched', clock='wall', source='stream') "
            "and run it through repro.serving.Service instead")
        spec = ServeSpec(
            executor="device-batched", clock="wall", source="stream",
            batching={"max_batch": self._effective_max_batch},
            host_overhead=self.host_overhead,
            pipeline_depth=self._pipeline_depth)
        svc = Service.from_spec(spec, policy=self.policy, cfg=self.cfg,
                                params=self.params, stage_fns=self.stage_fns,
                                time_model=self.time_model,
                                admission=self.admission)
        svc.run(request_stream)
        self.responses.extend(svc.responses)
        return self.responses
