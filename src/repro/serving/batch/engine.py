"""BatchedServingEngine — wall-clock continuous stage-level micro-batching.

The unbatched ``ServingEngine`` dispatches one request's stage at a time;
on a real accelerator that strands almost all throughput.  This engine
keeps the paper's user-space decision loop (admit → schedule → run one
non-preemptive unit → observe confidences → respond) but the dispatch
unit is a *padded, shape-bucketed batch* of same-stage tasks:

* a ``BatchPolicy`` picks ``(stage, [tasks])`` each cycle — plain policies
  are wrapped so RTDeepIoT/EDF/LCF/RR decide batch composition with their
  own preference order, under the invariant that no admission pushes a
  member past its deadline (batch WCET = profiled per-bucket stage time);
* §II-B deadline adjustment: the non-preemptible region is now one
  **batched** stage, so the caller-visible deadline shrinks by the host
  overhead plus the largest batched stage WCET;
* an optional ``AdmissionController`` rejects/depth-caps at arrival.

Every bucketed shape is compiled in warm-up, so steady state never
recompiles.

``run`` is a compatibility shim over the unified runtime
(``repro.serving.runtime``): an ``EngineCore`` on a ``WallClock`` with a
``DeviceExecutor`` over the bucketed batched stage functions.  Because the
device executor dispatches asynchronously, ``pipelined()`` returns an
engine whose core pre-selects the next batch while the current one runs
(``pipeline_depth=2``) — the host/device overlap the ROADMAP's async item
asks for — without changing this class's legacy constructor or ``run``
signature.
"""
from __future__ import annotations

from repro.core.task import Task
from repro.serving.batch.admission import AdmissionController
from repro.serving.batch.batcher import BatchTimeModel
from repro.serving.batch.policy import BatchPolicy, as_batch_policy
from repro.serving.batch.stage_fns import BatchedStageFns
from repro.serving.engine import Request
from repro.serving.runtime import (EngineCore, ResponseRecorder, StreamSource,
                                   WallClock)
from repro.serving.runtime.device import DeviceExecutor


class BatchedServingEngine:
    def __init__(self, cfg, params, policy, *, time_model: BatchTimeModel,
                 host_overhead: float = 0.0, stage_fns: BatchedStageFns = None,
                 admission: AdmissionController = None,
                 max_batch: int = None):
        self.cfg = cfg
        self.params = params
        self.time_model = time_model
        self.stage_fns = stage_fns or BatchedStageFns(cfg, time_model.buckets)
        self.policy: BatchPolicy = as_batch_policy(policy, time_model,
                                                   max_batch=max_batch)
        # largest batch this engine can actually dispatch — a custom
        # BatchPolicy without a batcher is bounded only by the bucket set
        batcher = getattr(self.policy, "batcher", None)
        self._effective_max_batch = batcher.max_batch if batcher is not None \
            else time_model.max_batch
        self.admission = admission
        self.host_overhead = host_overhead
        self.responses: list = []
        self._pipeline_depth = 1

    def pipelined(self, depth: int = 2) -> "BatchedServingEngine":
        """Enable pipelined async dispatch (host pre-selects batch N+1 while
        batch N runs on the device).  Returns self for chaining."""
        self._pipeline_depth = depth
        return self

    # ------------------------------------------------------------------
    def _make_task(self, req: Request, now: float) -> Task:
        # §II-B with batching: the non-preemptible region is one *batched*
        # stage, priced at the largest batch this engine will dispatch
        worst = max(self.time_model.wcet(s, self._effective_max_batch)
                    for s in range(self.cfg.num_stages))
        adj = self.host_overhead + worst
        return Task(arrival=now, deadline=req.arrival + req.rel_deadline - adj,
                    stage_times=self.time_model.single_times(),
                    mandatory=self.cfg.mandatory_stages, sample=req.sample,
                    client=req.client)

    # ------------------------------------------------------------------
    def run(self, request_stream):
        """request_stream: iterable of (offset_seconds, Request), offsets
        non-decreasing relative to engine start."""
        pending = list(request_stream)
        pending.sort(key=lambda p: p[0])
        if pending:   # compile every (stage, bucket) before the clock starts
            self.stage_fns.warmup(self.params, pending[0][1].inputs)
        executor = DeviceExecutor(self.stage_fns, self.params, self.time_model)

        def admit(req, now):
            t = self._make_task(req, now)
            executor.register(t, req)
            return t

        core = EngineCore(self.policy, WallClock(), executor,
                          StreamSource(pending, admit),
                          ResponseRecorder(executor, self.responses),
                          admission=self.admission,
                          pipeline_depth=self._pipeline_depth,
                          max_batch=self._effective_max_batch)
        core.run()
        return self.responses
