"""Length-bucketed WCET pricing: ``(stage, batch-bucket, len-bucket)``.

The batch subsystem prices every dispatch through a
:class:`~repro.serving.batch.batcher.BatchTimeModel` keyed by (stage,
batch-size bucket).  Real kernel dispatches have a third shape axis: the
padded *sequence length* (classifier feature frames, decode KV-cache
slots).  A serving engine cannot recompile per length either, so lengths
are padded up to a small set of pre-compiled **length buckets**, and the
WCET table gains a length dimension:

    times3[len_bucket][batch_bucket][stage] -> seconds

``LengthBucketTimeModel`` subclasses ``BatchTimeModel`` so every existing
call site keeps working: the inherited 2-D ``times`` is the *worst case
over length buckets*, which is exactly what length-blind consumers (the
§II-B deadline adjustment's worst-stage term, ``single_times`` on tasks,
admission headroom) should price.  Length-aware consumers — the
:class:`~repro.serving.batch.batcher.StageBatcher`, the oracle executor,
the ``device-kernel`` executor — pass ``seq_len=`` to :meth:`wcet` and get
the bucket-exact cost.  Tasks carry their length in ``Task.seq_len``;
co-runners batch together only when their lengths share a bucket (the
batched shape is one pre-compiled ``(batch_bucket, len_bucket)`` pair).

No jax import — the discrete-event simulator prices ragged workloads
through this model too.
"""
from __future__ import annotations

import bisect
import dataclasses

from repro.serving.batch.batcher import BatchTimeModel, bucket_for

DEFAULT_LEN_BUCKETS = (16, 64, 256)


def len_bucket_for(seq_len: int, len_buckets) -> int:
    """Smallest length bucket holding ``seq_len`` (lengths are padded up).

    The length analog of :func:`repro.serving.batch.batcher.bucket_for` —
    the single source of the length-rounding rule."""
    i = bisect.bisect_left(len_buckets, seq_len)
    if seq_len < 1 or i == len(len_buckets):
        raise ValueError(f"seq_len {seq_len} exceeds length buckets "
                         f"{tuple(len_buckets)}")
    return len_buckets[i]


@dataclasses.dataclass(frozen=True)
class LengthBucketTimeModel(BatchTimeModel):
    """``BatchTimeModel`` with a length-bucket axis.

    ``times3[li][bi][s]`` = worst-case seconds of stage ``s`` run at batch
    bucket ``buckets[bi]`` with rows padded to ``len_buckets[li]``.  The
    inherited 2-D ``times`` must equal the per-(bucket, stage) max over
    length buckets — length-blind pricing stays conservative.
    """
    len_buckets: tuple = ()        # ascending length buckets, e.g. (16, 64)
    times3: tuple = ()             # times3[len_idx][bucket_idx][stage]

    def __post_init__(self):
        super().__post_init__()
        if tuple(sorted(self.len_buckets)) != tuple(self.len_buckets) \
                or not self.len_buckets:
            raise ValueError(f"len_buckets must be non-empty ascending: "
                             f"{self.len_buckets}")
        if len(self.times3) != len(self.len_buckets):
            raise ValueError("one WCET matrix per length bucket required")
        for li, mat in enumerate(self.times3):
            if len(mat) != len(self.buckets):
                raise ValueError(f"times3[{li}]: one row per batch bucket "
                                 f"required")
        for bi in range(len(self.buckets)):
            for s in range(self.num_stages):
                worst = max(m[bi][s] for m in self.times3)
                if abs(worst - self.times[bi][s]) > 1e-12:
                    raise ValueError(
                        "base times must be the max over length buckets "
                        f"(bucket {self.buckets[bi]}, stage {s}: "
                        f"{self.times[bi][s]} != {worst})")

    # -- length axis ----------------------------------------------------
    def len_bucket_for(self, seq_len: int) -> int:
        return len_bucket_for(seq_len, self.len_buckets)

    def wcet(self, stage: int, n: int = 1, seq_len: int = None) -> float:
        """WCET of stage ``stage`` as a batch of ``n``; with ``seq_len``,
        priced at that length's bucket, else worst-case over lengths."""
        if seq_len is None:
            return super().wcet(stage, n)
        bi = bisect.bisect_left(self.buckets, self.bucket_for(n))
        li = bisect.bisect_left(self.len_buckets,
                                self.len_bucket_for(seq_len))
        return float(self.times3[li][bi][stage])

    @classmethod
    def linear(cls, stage_times, buckets=None, marginal: float = 0.15,
               len_buckets=DEFAULT_LEN_BUCKETS,
               len_marginal: float = None) -> "LengthBucketTimeModel":
        """Analytic model: batch scaling as in ``BatchTimeModel.linear``,
        and stage time proportional to the length bucket relative to the
        largest (``len_marginal`` < 1 flattens the length dependence:
        cost = base * (len_marginal + (1 - len_marginal) * lb/max_lb))."""
        from repro.serving.batch.batcher import DEFAULT_BUCKETS
        buckets = tuple(sorted(int(b) for b in buckets or DEFAULT_BUCKETS))
        len_buckets = tuple(sorted(int(b) for b in len_buckets))
        lm = 0.25 if len_marginal is None else float(len_marginal)
        base = BatchTimeModel.linear(stage_times, buckets, marginal)
        mats = []
        for lb in len_buckets:
            frac = lm + (1.0 - lm) * lb / len_buckets[-1]
            mats.append(tuple(tuple(t * frac for t in row)
                              for row in base.times))
        worst = tuple(
            tuple(max(m[bi][s] for m in mats)
                  for s in range(len(stage_times)))
            for bi in range(len(buckets)))
        return cls(buckets=buckets, times=worst, len_buckets=len_buckets,
                   times3=tuple(mats))

    @classmethod
    def from_profile3(cls, tensor, buckets, len_buckets) \
            -> "LengthBucketTimeModel":
        """From a profiled (num_len_buckets, num_stages, num_buckets)
        WCET tensor (the 3-D analog of ``BatchTimeModel.from_profile``)."""
        buckets = tuple(sorted(int(b) for b in buckets))
        len_buckets = tuple(sorted(int(b) for b in len_buckets))
        mats = []
        for mat in tensor:
            L = len(mat)
            rows = tuple(tuple(float(mat[s][bi]) for s in range(L))
                         for bi in range(len(buckets)))
            mats.append(rows)
        worst = tuple(
            tuple(max(m[bi][s] for m in mats)
                  for s in range(len(mats[0][0])))
            for bi in range(len(buckets)))
        return cls(buckets=buckets, times=worst, len_buckets=len_buckets,
                   times3=tuple(mats))


def batch_wcet(time_model, stage: int, tasks) -> float:
    """Price one batched dispatch of ``tasks`` at ``stage``: length-aware
    when the model carries a length axis and every member declares a
    ``seq_len``, conservative (worst length bucket) otherwise.

    Model-aware when the time model dispatches per model (a ``for_model``
    method, e.g. :class:`~repro.serving.zoo.ZooTimeModel`) and the batch
    carries a ``model`` id: the batch is priced by that model's own WCET
    table (the :class:`~repro.serving.batch.batcher.StageBatcher` only
    seats same-model co-runners, so the first member's model is the
    batch's)."""
    model = getattr(tasks[0], "model", None) if tasks else None
    if model is not None:
        fm = getattr(time_model, "for_model", None)
        if fm is not None:
            time_model = fm(model)
    if isinstance(time_model, LengthBucketTimeModel):
        sls = [t.seq_len for t in tasks
               if getattr(t, "seq_len", None) is not None]
        if len(sls) == len(tasks) and sls:
            return time_model.wcet(stage, len(tasks), seq_len=max(sls))
    return time_model.wcet(stage, len(tasks))


def task_len_bucket(time_model, task):
    """The task's length bucket under ``time_model`` (None when either
    side carries no length information).  Resolves per-model tables the
    same way :func:`batch_wcet` does."""
    model = getattr(task, "model", None)
    if model is not None:
        fm = getattr(time_model, "for_model", None)
        if fm is not None:
            time_model = fm(model)
    if isinstance(time_model, LengthBucketTimeModel):
        sl = getattr(task, "seq_len", None)
        if sl is not None:
            return time_model.len_bucket_for(sl)
    return None


__all__ = ["DEFAULT_LEN_BUCKETS", "LengthBucketTimeModel", "batch_wcet",
           "bucket_for", "len_bucket_for", "task_len_bucket"]
