"""Admission control: reject or depth-cap requests an overloaded queue
cannot serve.

The paper's scheduler maximizes accuracy *given* the active set; under
sustained overload that still means every request limps through at
mandatory depth and many expire with zero stages done.  The controller
makes the overload decision explicit at arrival time:

* **mandatory-infeasible** — even the mandatory part, run solo at
  single-batch speed, cannot meet the deadline: never admitted.
* **overload** — the optimistic backlog (everyone's remaining mandatory
  work, amortized at the largest bucket's per-item rate — the best the
  batched engine could possibly do) already spends this request's slack:
  ``mode="reject"`` drops it (the client can fail fast / retry elsewhere),
  ``mode="depth_cap"`` admits it pinned to its mandatory depth.
* otherwise the request is admitted; in ``depth_cap`` mode its depth is
  capped at what is solo-feasible (``Task.feasible_depth`` under
  single-batch WCETs), which keeps the FPTAS from planning depths that
  only exist on paper.

Caps are applied through ``Task.depth_cap``, which every Policy's depth
assignment clamps against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.batch.batcher import BatchTimeModel

MODES = ("off", "reject", "depth_cap")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    depth_cap: Optional[int]       # None = uncapped
    reason: str
    # the numbers behind the rule that fired (slack, backlog, WCETs...);
    # surfaced by the obs audit log so "why was this rejected?" has a
    # quantitative answer.  None for plain admits.
    detail: Optional[dict] = None


class AdmissionController:
    def __init__(self, time_model: BatchTimeModel, mode: str = "depth_cap",
                 headroom: float = 1.0):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.time_model = time_model
        self.mode = mode
        self.headroom = headroom   # >1.0 = admit less (safety margin)
        self.rejected = 0
        self.capped = 0

    # ------------------------------------------------------------------
    def _tm_for(self, task):
        """WCET table pricing ``task`` — the hook per-model controllers
        (:class:`repro.serving.zoo.ZooAdmissionController`) override."""
        return self.time_model

    def _amortized(self, stage: int, tm=None) -> float:
        tm = self.time_model if tm is None else tm
        return tm.per_item(stage, tm.max_batch)

    def decide(self, active, task, now: float) -> AdmissionDecision:
        if self.mode == "off":
            return AdmissionDecision(True, None, "off")
        tm = self._tm_for(task)
        slack = task.deadline - now
        mand_solo = sum(tm.wcet(s, 1) for s in range(task.mandatory))
        if not task.fits_batch(now, mand_solo):
            return AdmissionDecision(
                False, None, "mandatory-infeasible",
                detail={"slack": slack, "mand_solo_wcet": mand_solo,
                        "mandatory": task.mandatory})
        # optimistic backlog: mandatory work still owed by the active set,
        # at the best per-item rate batching can buy
        backlog = sum(
            sum(self._amortized(s, self._tm_for(t))
                for s in range(t.executed, max(t.mandatory, t.executed)))
            for t in active)
        own = sum(self._amortized(s, tm) for s in range(task.mandatory))
        if now + (backlog + own) * self.headroom > task.deadline:
            detail = {"slack": slack, "backlog": backlog,
                      "own_amortized": own, "headroom": self.headroom,
                      "n_active": len(active)}
            if self.mode == "reject":
                return AdmissionDecision(False, None, "overload",
                                         detail=detail)
            return AdmissionDecision(True, task.mandatory, "overload-capped",
                                     detail=detail)
        if self.mode == "depth_cap":
            d = task.feasible_depth(now,
                                    stage_time=lambda s: tm.wcet(s, 1))
            if d < task.num_stages:
                return AdmissionDecision(
                    True, max(task.mandatory, d), "deadline-capped",
                    detail={"slack": slack, "feasible_depth": d,
                            "num_stages": task.num_stages,
                            "mand_solo_wcet": mand_solo})
        return AdmissionDecision(True, None, "ok")

    def apply(self, active, task, now: float) -> AdmissionDecision:
        """Decide and mutate ``task.depth_cap``; caller drops on reject.

        A pre-existing cap (SLO class, backpressure shedding) is only
        ever tightened — admission control must not re-open depth some
        earlier layer already took away."""
        dec = self.decide(active, task, now)
        if not dec.admitted:
            self.rejected += 1
            task.dropped = True
        elif dec.depth_cap is not None:
            self.capped += 1
            cap = max(task.mandatory, dec.depth_cap)
            task.depth_cap = cap if task.depth_cap is None \
                else min(task.depth_cap, cap)
        return dec
