"""BatchPolicy — the batched extension of the core ``Policy`` contract.

Contract
--------
``next_batch(active, now) -> Optional[(stage, [tasks])]``

Everything else (``on_arrival`` / ``on_stage_done`` / ``sched_time``)
is inherited from the single-task ``Policy`` interface, so the batched
engine and ``simulate_batched`` drive exactly the policies the paper
evaluates — RTDeepIoT, EDF, LCF, RR — with batch *composition* layered on
top of each policy's dispatch preference:

* the base policy still picks the **leader** (its ``next_task`` order:
  planned-EDF for RTDeepIoT, deadline for EDF, lowest confidence for LCF,
  the round-robin slot for RR);
* the ``StageBatcher`` then fills the bucket with deadline-feasible
  co-runners at the leader's stage, ordered by the base policy's
  ``batch_rank`` — so LCF batches low-confidence tasks together while
  EDF/RTDeepIoT batch by urgency, and *no* admission may push a member
  past its deadline (batch WCET = profiled per-bucket stage time).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.schedulers import Policy
from repro.serving.batch.batcher import StageBatcher


class BatchPolicy(Policy):
    """Policies that dispatch (stage, [tasks]) micro-batches."""
    name = "batch-base"

    def next_batch(self, active, now) -> Optional[tuple]:
        raise NotImplementedError

    def next_task(self, active, now):
        """Single-task view (lets a BatchPolicy drive unbatched paths)."""
        nb = self.next_batch(active, now)
        return nb[1][0] if nb else None


class BatchedPolicy(BatchPolicy):
    """Adapter: any single-task ``Policy`` + ``StageBatcher`` -> BatchPolicy.

    Attribute access falls through to the base policy (``sched_time``,
    ``invocations``, ``predictor`` ...), so telemetry and the §II-E hooks
    behave as if the base policy ran unbatched; time spent forming batches
    is charged to the base policy's ``sched_time``.
    """

    def __init__(self, base: Policy, batcher: StageBatcher,
                 charge_formation: bool = True):
        # no super().__init__(): sched_time/invocations live on `base`
        self.base = base
        self.batcher = batcher
        # the batched paths bill selection + batch formation to the base
        # policy's sched_time; the unbatched shims pass False, preserving
        # the legacy accounting where next_task time was never counted
        self.charge_formation = charge_formation
        self.name = f"batched-{base.name}"

    def __getattr__(self, item):
        if item == "base":          # guard: never recurse during __init__
            raise AttributeError(item)
        return getattr(self.base, item)

    def on_arrival(self, active, task, now):
        self.base.on_arrival(active, task, now)

    def on_stage_done(self, active, task, now):
        self.base.on_stage_done(active, task, now)

    def batch_rank(self, task, now):
        return self.base.batch_rank(task, now)

    def next_task(self, active, now):
        return self.base.next_task(active, now)

    def next_batch(self, active, now) -> Optional[tuple]:
        t0 = time.perf_counter()
        leader = self.base.next_task(active, now)
        if leader is None:
            if self.charge_formation:
                self.base.sched_time += time.perf_counter() - t0
            return None
        cands = self._runnable(active, now)
        batch = self.batcher.form(leader, cands, now,
                                  rank=lambda t: self.base.batch_rank(t, now))
        if self.charge_formation:
            self.base.sched_time += time.perf_counter() - t0
        return leader.executed, batch


def as_batch_policy(policy: Policy, time_model, max_batch: int = None,
                    charge_formation: bool = True, dp: int = 1) -> BatchPolicy:
    """Wrap a plain Policy for the batched engine/simulator (idempotent)."""
    if isinstance(policy, BatchPolicy):
        return policy
    return BatchedPolicy(policy, StageBatcher(time_model,
                                              max_batch=max_batch, dp=dp),
                         charge_formation=charge_formation)
