"""Batched jitted stage functions: shape buckets, padding, masks.

A serving engine cannot afford a recompile per batch size, so batches are
padded up to a small set of pre-compiled **buckets** (default
{1, 2, 4, 8, 16}): one jitted ``stage_forward`` per stage, at most
``len(buckets)`` shapes each, all compiled in ``warmup`` before the
serving clock starts.

Padding replicates the last valid sample; batch rows are independent in
every supported architecture (attention/scan mix over the sequence axis,
norms over features), so valid rows of the padded run match per-sample
runs exactly and the returned boolean mask just marks which rows are real.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import stage_forward
from repro.serving.batch.batcher import (DEFAULT_BUCKETS, BatchTimeModel,
                                         bucket_for)


class StagingBuffers:
    """Reused per-bucket host staging for batch formation.

    ``pad_batch`` used to re-stack the per-sample pytrees into fresh
    device arrays on every dispatch — a per-dispatch allocation (and a
    jitted concatenate) on the hot path.  A ``StagingBuffers`` instance
    instead keeps one pinned numpy buffer per (bucket, leaf-struct): rows
    are copied in place, padding rows replicate the last valid row, and
    the same buffer object is handed to the jitted stage fn every time —
    steady-state batch formation allocates nothing.

    The returned masks are cached per (bucket, n) and must be treated as
    read-only (they are shared across dispatches), as must the batched
    leaves themselves: the jitted callee copies them to device before the
    next ``stage`` call can overwrite them, which is the same lifetime
    contract jit already imposes on donated host buffers.
    """

    def __init__(self):
        self._bufs = {}    # (bucket, treedef, leafsig) -> list[np.ndarray]
        self._masks = {}   # (bucket, n) -> np.ndarray(bool)

    def mask(self, bucket: int, n: int) -> np.ndarray:
        key = (bucket, n)
        m = self._masks.get(key)
        if m is None:
            m = np.arange(bucket) < n
            m.setflags(write=False)
            self._masks[key] = m
        return m

    def stage(self, pytrees, bucket: int):
        """In-place ``pad_batch``: returns ``(batched, mask)`` backed by
        the reused per-bucket buffers."""
        n = len(pytrees)
        if not 0 < n <= bucket:
            raise ValueError(f"cannot pad {n} samples into bucket {bucket}")
        leaves0, treedef = jax.tree.flatten(pytrees[0])
        sig = tuple((tuple(lf.shape), np.dtype(lf.dtype)) for lf in leaves0)
        key = (bucket, treedef, sig)
        bufs = self._bufs.get(key)
        if bufs is None:
            bufs = [np.empty((bucket,) + tuple(lf.shape[1:]),
                             dtype=np.dtype(lf.dtype)) for lf in leaves0]
            self._bufs[key] = bufs
        for i, tree in enumerate(pytrees):
            leaves = leaves0 if i == 0 else treedef.flatten_up_to(tree)
            for buf, leaf in zip(bufs, leaves):
                buf[i] = np.asarray(leaf)[0]
        for buf in bufs:                   # replicate last valid row
            buf[n:] = buf[n - 1]
        return treedef.unflatten(bufs), self.mask(bucket, n)


def pad_batch(pytrees, bucket: int, staging: StagingBuffers = None):
    """Stack single-sample pytrees (leading dim 1) into a padded batch.

    Returns ``(batched, mask)`` — mask[i] is True for the len(pytrees)
    valid rows, False for the replicated padding rows.  With ``staging``,
    the batch is formed in that instance's reused per-bucket buffers
    (no per-dispatch allocation) instead of freshly stacked arrays."""
    if staging is not None:
        return staging.stage(pytrees, bucket)
    n = len(pytrees)
    if not 0 < n <= bucket:
        raise ValueError(f"cannot pad {n} samples into bucket {bucket}")
    reps = list(pytrees) + [pytrees[-1]] * (bucket - n)
    batched = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *reps)
    mask = np.arange(bucket) < n
    return batched, mask


def split_rows(tree, n: int):
    """Undo pad_batch: the first `n` rows as single-sample pytrees."""
    return [jax.tree.map(lambda x: x[i:i + 1], tree) for i in range(n)]


class BatchedStageFns:
    """Per-stage jitted batched ``stage_forward`` with bucket discipline."""

    def __init__(self, cfg, buckets=DEFAULT_BUCKETS):
        self.cfg = cfg
        self.buckets = tuple(sorted(buckets))
        self._fns = {}
        self.staging = StagingBuffers()

    def fn(self, stage: int):
        if stage not in self._fns:
            def f(params, h, _s=stage):
                return stage_forward(self.cfg, params, _s, h, mode="train")
            self._fns[stage] = jax.jit(f)
        return self._fns[stage]

    def run(self, stage: int, params, pytrees):
        """Pad, dispatch one batched stage, return (h, logits, conf, mask).

        ``pytrees``: single-sample stage inputs (raw inputs for stage 0,
        hidden states after)."""
        h, mask = pad_batch(pytrees, bucket_for(len(pytrees), self.buckets),
                            staging=self.staging)
        h_out, logits, conf = self.fn(stage)(params, h)
        return h_out, logits, conf, mask

    def warmup(self, params, sample_input):
        """Compile every (stage, bucket) shape before the clock starts."""
        for b in self.buckets:
            h = pad_batch([sample_input], b)[0]
            for s in range(self.cfg.num_stages):
                out = self.fn(s)(params, h)
                jax.block_until_ready(out[0])
                h = out[0]


def profile_batched_stages(cfg, params, fns: BatchedStageFns, sample_input, *,
                           n_runs: int = 30, percentile: float = 99.0):
    """Profile the (num_stages, num_buckets) batched-stage WCET matrix.

    Mirrors ``repro.serving.profile_stages`` (99th-percentile over timed
    runs), one column per batch-size bucket.  Returns
    ``(BatchTimeModel, matrix)``."""
    L = cfg.num_stages
    mat = np.zeros((L, len(fns.buckets)))
    for bi, b in enumerate(fns.buckets):
        h = pad_batch([sample_input], b)[0]
        for s in range(L):
            f = fns.fn(s)
            out = f(params, h)                     # compile
            jax.block_until_ready(out[0])
            ts = np.zeros(n_runs)
            for i in range(n_runs):
                t0 = time.perf_counter()
                out = f(params, h)
                jax.block_until_ready(out[0])
                ts[i] = time.perf_counter() - t0
            mat[s, bi] = np.percentile(ts, percentile)
            h = out[0]
    return BatchTimeModel.from_profile(mat, fns.buckets), mat
