"""Batch formation: shape-bucketed, deadline-feasible stage micro-batches.

Two pieces, both accelerator-agnostic (no jax import — the discrete-event
simulator uses them too):

* ``BatchTimeModel`` — profiled WCET of one *batched* stage execution per
  (stage, batch-size bucket).  Buckets are the small set of batch sizes the
  engine pre-compiles (default {1, 2, 4, 8, 16}); any batch is padded up to
  the next bucket, so the batch WCET is the bucket's WCET.
* ``StageBatcher`` — greedy deadline-feasible batch formation around a
  leader task.  Invariant (the paper's §II-B deadline semantics lifted to
  batches): admitting a task into a batch must not push any member past its
  deadline, where the batch's cost is the bucket-rounded WCET of the grown
  batch.

The non-preemptible region of §II-B therefore becomes one *batched* stage:
once a batch is dispatched, every member is committed for the full batch
WCET.  That is exactly why admission checks the grown batch's WCET against
*all* members — a bigger batch is cheaper per item but longer wall-clock.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

DEFAULT_BUCKETS = (1, 2, 4, 8, 16)


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket holding a batch of `n` (batches are padded up).

    The single source of the bucket-rounding rule: BatchTimeModel pricing
    and BatchedStageFns padding both resolve through it."""
    i = bisect.bisect_left(buckets, n)
    if n < 1 or i == len(buckets):
        raise ValueError(f"batch of {n} exceeds buckets {tuple(buckets)}")
    return buckets[i]


@dataclasses.dataclass(frozen=True)
class BatchTimeModel:
    """WCET table for batched stage executions.

    ``times[bi][s]`` = worst-case seconds of stage ``s`` run at batch-size
    bucket ``buckets[bi]``.
    """
    buckets: tuple                 # ascending batch-size buckets, e.g. (1,2,4)
    times: tuple                   # times[bucket_index][stage] -> seconds

    def __post_init__(self):
        if tuple(sorted(self.buckets)) != tuple(self.buckets):
            raise ValueError(f"buckets must ascend: {self.buckets}")
        if len(self.times) != len(self.buckets):
            raise ValueError("one WCET row per bucket required")

    @property
    def max_batch(self) -> int:
        return self.buckets[-1]

    @property
    def num_stages(self) -> int:
        return len(self.times[0])

    def bucket_for(self, n: int) -> int:
        return bucket_for(n, self.buckets)

    def wcet(self, stage: int, n: int = 1) -> float:
        """WCET of stage `stage` executed as a batch of `n` (bucket-padded)."""
        bi = bisect.bisect_left(self.buckets, self.bucket_for(n))
        return float(self.times[bi][stage])

    def per_item(self, stage: int, n: int = 1) -> float:
        """Amortized per-request cost of a batch of `n` — the throughput
        lever: with sub-linear batch scaling this falls as `n` grows."""
        return self.wcet(stage, n) / max(1, n)

    def single_times(self) -> tuple:
        """Per-stage WCETs at batch size 1 (what Task.stage_times carries)."""
        return tuple(float(self.times[0][s]) for s in range(self.num_stages))

    @classmethod
    def linear(cls, stage_times, buckets=DEFAULT_BUCKETS,
               marginal: float = 0.15) -> "BatchTimeModel":
        """Analytic model for the simulator: each extra item in a batch adds
        `marginal` of the single-item stage time (GPU batching amortizes
        weight loads, so marginal << 1)."""
        buckets = tuple(sorted(int(b) for b in buckets))
        rows = tuple(
            tuple(float(t) * (1.0 + marginal * (b - 1)) for t in stage_times)
            for b in buckets)
        return cls(buckets=buckets, times=rows)

    @classmethod
    def from_profile(cls, matrix, buckets) -> "BatchTimeModel":
        """From a profiled (num_stages, num_buckets) WCET matrix (see
        repro.serving.batch.stage_fns.profile_batched_stages)."""
        m = np.asarray(matrix, dtype=float)
        buckets = tuple(sorted(int(b) for b in buckets))
        if m.shape != (m.shape[0], len(buckets)):
            raise ValueError(f"expected (L, {len(buckets)}) matrix, "
                             f"got {m.shape}")
        rows = tuple(tuple(float(x) for x in m[:, bi])
                     for bi in range(len(buckets)))
        return cls(buckets=buckets, times=rows)


class StageBatcher:
    """Greedy deadline-feasible micro-batch formation at one stage.

    Given the leader the base policy picked, fill the rest of the bucket
    with co-runners currently at the *same* stage, in `rank` order,
    admitting a candidate only if the grown batch's (bucket-rounded) WCET
    still meets every member's deadline — including the candidate's own.

    If even the leader alone is infeasible the singleton batch is returned
    unchanged; dispatch semantics then match the unbatched engine (the
    stage runs, the deadline check afterwards decides whether it counted).

    When the time model carries a length axis
    (:class:`repro.serving.batch.time_model.LengthBucketTimeModel`) and
    tasks declare ``seq_len``, candidates are additionally filtered to the
    leader's *length bucket* — a batched dispatch is one pre-compiled
    (batch-bucket, len-bucket) shape, so only same-bucket co-runners can
    share it — and WCETs are priced at that bucket instead of the
    worst-case length.

    Multi-model serving (``repro.serving.zoo``): tasks carrying a
    ``model`` id only co-batch with *same-model* co-runners (a batched
    dispatch runs exactly one model's stage fn), and when the time model
    dispatches per model (a ``for_model`` method, e.g.
    :class:`~repro.serving.zoo.ZooTimeModel`) the batch is priced by the
    *leader's* model's WCET table.  Tasks without a model (the whole
    single-model stack) are unaffected.

    ``dp`` > 1 (row-sharded executors) prefers dp-multiple batch sizes:
    when the greedy fill lands strictly below its bucket boundary at a
    non-dp-multiple size, the lowest-ranked co-runners are deferred down
    to the nearest dp multiple *iff* that lowers the priced bucket — a
    padded row should never cross a replica when deferring it buys a
    smaller (faster) bucket.  ``dp=1`` is the identity.
    """

    def __init__(self, time_model: BatchTimeModel, max_batch: int = None,
                 dp: int = 1):
        self.time_model = time_model
        self.max_batch = min(max_batch or time_model.max_batch,
                             time_model.max_batch)
        self.dp = max(1, int(dp))

    def _model_tm(self, model):
        """The WCET table pricing ``model``'s dispatches (the shared table
        unless the time model dispatches per model)."""
        if model is None:
            return self.time_model
        fm = getattr(self.time_model, "for_model", None)
        return self.time_model if fm is None else fm(model)

    def _wcet(self, stage: int, n: int, seq_len, tm=None) -> float:
        tm = self.time_model if tm is None else tm
        if seq_len is not None:
            return tm.wcet(stage, n, seq_len=seq_len)
        return tm.wcet(stage, n)

    def _len_bucket(self, task):
        tm = self._model_tm(getattr(task, "model", None))
        lb_for = getattr(tm, "len_bucket_for", None)
        sl = getattr(task, "seq_len", None)
        if lb_for is None or sl is None:
            return None
        return lb_for(sl)

    def _prefer_dp_multiple(self, batch, tm) -> None:
        """Defer the tail of the fill order down to a dp multiple when that
        lowers the priced bucket (see class docstring).  Never touches the
        leader; deferred tasks stay queued for the next window."""
        n = len(batch)
        if self.dp <= 1 or n <= 1 or n % self.dp == 0:
            return
        bucket = tm.bucket_for(n)
        if n == bucket:
            return                     # exact bucket hit: no padding at all
        m = (n // self.dp) * self.dp
        if m >= 1 and tm.bucket_for(m) < bucket:
            del batch[m:]

    def form(self, leader, candidates, now: float, rank=None) -> list:
        stage = leader.executed
        batch = [leader]
        # singleton fast path (the unbatched engines run max_batch=1 through
        # the same code): no candidate ranking work on the dispatch hot path
        if self.max_batch <= 1:
            return batch
        lmodel = getattr(leader, "model", None)
        tm = self._model_tm(lmodel)
        lb = self._len_bucket(leader)
        seq = None if lb is None else lb
        if not leader.fits_batch(now, self._wcet(stage, 1, seq, tm)):
            return batch
        cands = [c for c in candidates
                 if c is not leader and c.executed == stage
                 and getattr(c, "model", None) == lmodel
                 and (lb is None or self._len_bucket(c) == lb)]
        cands.sort(key=rank if rank is not None
                   else (lambda t: (t.deadline, t.tid)))
        for c in cands:
            if len(batch) >= self.max_batch:
                break
            w = self._wcet(stage, len(batch) + 1, seq, tm)
            if c.fits_batch(now, w) and all(m.fits_batch(now, w)
                                            for m in batch):
                batch.append(c)
        self._prefer_dp_multiple(batch, tm)
        return batch
