"""Serving package — one public front door, one runtime core.

New code talks to :class:`~repro.serving.service.Service` built from a
declarative :class:`~repro.serving.service.ServeSpec` (components named by
registry key — see :mod:`repro.serving.registry`); the four legacy faces
(``simulate``, ``simulate_batched``, ``ServingEngine``,
``BatchedServingEngine``) are deprecated thin wrappers over it.
"""
from repro.serving.engine import (Request, Response, ServingEngine,
                                  closed_loop_stream, make_stage_fns,
                                  profile_host_overhead, profile_stages)
from repro.serving.batch import (AdmissionController, BatchedPolicy,
                                 BatchedServingEngine, BatchedStageFns,
                                 BatchPolicy, BatchTimeModel,
                                 LengthBucketTimeModel, StageBatcher,
                                 as_batch_policy, pad_batch,
                                 profile_batched_stages, simulate_batched)
from repro.serving.registry import (available, register_clock,
                                    register_executor, register_policy,
                                    register_source)
from repro.serving.runtime import (ClosedLoopSource, EngineCore,
                                   OracleExecutor, StreamSource, TableRecorder,
                                   VirtualClock, WallClock, simulate_runtime)
from repro.serving.service import (ResponseHandle, ServeSpec, Service,
                                   ServiceMetrics, ServiceResponse, SLOClass,
                                   StageExit)
# importing the traffic subsystem registers its source keys
# ("traffic", "replay") — see repro.serving.traffic for the full surface
from repro.serving.traffic import (MetricsStreamer, RequestMix, Scenario,
                                   ServiceSnapshot, TraceRecorder,
                                   TrafficSource, load_trace,
                                   make_arrival_process, record_trace,
                                   scenario_spec, verify_replay)
# the durable request plane registers "durable" and "frontdoor" —
# see repro.serving.plane for the full surface
from repro.serving.plane import (DurableQueue, FrontDoor, Journal, Record,
                                 journal_stats, recover, scan_journal,
                                 verify_recovery)
# the multi-model zoo registers "rtdeepiot-zoo" and "zoo-oracle"
# ("zoo-device" is jax-heavy and registers from repro.launch.serve)
from repro.serving.zoo import (ModelZoo, ZooAdmissionController, ZooModel,
                               ZooOracleExecutor, ZooRTDeepIoT,
                               ZooTimeModel)
# observability: per-request tracing, decision audit log, metrics registry
# (enable with ServeSpec(trace={"enabled": True}); see docs/observability.md)
from repro.serving.obs import (MetricsRegistry, RequestTrace, Span, Tracer,
                               chrome_trace, load_obs,
                               validate_chrome_trace, write_jsonl)
# adaptive control registers "rtdeepiot-adaptive" — learned workload /
# confidence curves, predictive admission, wall-clock traffic driver
# (see repro.serving.adaptive and docs/adaptive.md)
from repro.serving.adaptive import (OnlineCurveEstimator,
                                    PredictiveAdmissionController,
                                    TrafficDriver, fit_arrival_process,
                                    fit_report)

__all__ = ["Request", "Response", "ServingEngine", "closed_loop_stream",
           "make_stage_fns", "profile_host_overhead", "profile_stages",
           "AdmissionController", "BatchedPolicy", "BatchedServingEngine",
           "BatchedStageFns", "BatchPolicy", "BatchTimeModel",
           "LengthBucketTimeModel", "StageBatcher", "as_batch_policy",
           "pad_batch",
           "profile_batched_stages", "simulate_batched",
           "ClosedLoopSource", "EngineCore", "OracleExecutor", "StreamSource",
           "TableRecorder", "VirtualClock", "WallClock", "simulate_runtime",
           "ResponseHandle", "ServeSpec", "Service", "ServiceMetrics",
           "ServiceResponse", "SLOClass", "StageExit",
           "available", "register_clock", "register_executor",
           "register_policy", "register_source",
           "MetricsStreamer", "RequestMix", "Scenario", "ServiceSnapshot",
           "TraceRecorder", "TrafficSource", "load_trace",
           "make_arrival_process", "record_trace", "scenario_spec",
           "verify_replay",
           "DurableQueue", "FrontDoor", "Journal", "Record",
           "journal_stats", "recover", "scan_journal", "verify_recovery",
           "ModelZoo", "ZooAdmissionController", "ZooModel",
           "ZooOracleExecutor", "ZooRTDeepIoT", "ZooTimeModel",
           "MetricsRegistry", "RequestTrace", "Span", "Tracer",
           "chrome_trace", "load_obs", "validate_chrome_trace",
           "write_jsonl",
           "OnlineCurveEstimator", "PredictiveAdmissionController",
           "TrafficDriver", "fit_arrival_process", "fit_report"]
