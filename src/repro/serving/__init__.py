from repro.serving.engine import (Request, Response, ServingEngine,
                                  closed_loop_stream, make_stage_fns,
                                  profile_host_overhead, profile_stages)
from repro.serving.batch import (AdmissionController, BatchedPolicy,
                                 BatchedServingEngine, BatchedStageFns,
                                 BatchPolicy, BatchTimeModel, StageBatcher,
                                 as_batch_policy, pad_batch,
                                 profile_batched_stages, simulate_batched)

__all__ = ["Request", "Response", "ServingEngine", "closed_loop_stream",
           "make_stage_fns", "profile_host_overhead", "profile_stages",
           "AdmissionController", "BatchedPolicy", "BatchedServingEngine",
           "BatchedStageFns", "BatchPolicy", "BatchTimeModel",
           "StageBatcher", "as_batch_policy", "pad_batch",
           "profile_batched_stages", "simulate_batched"]
