from repro.serving.engine import (Request, Response, ServingEngine,
                                  closed_loop_stream, make_stage_fns,
                                  profile_stages)

__all__ = ["Request", "Response", "ServingEngine", "closed_loop_stream",
           "make_stage_fns", "profile_stages"]
