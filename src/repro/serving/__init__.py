from repro.serving.engine import (Request, Response, ServingEngine,
                                  closed_loop_stream, make_stage_fns,
                                  profile_host_overhead, profile_stages)
from repro.serving.batch import (AdmissionController, BatchedPolicy,
                                 BatchedServingEngine, BatchedStageFns,
                                 BatchPolicy, BatchTimeModel, StageBatcher,
                                 as_batch_policy, pad_batch,
                                 profile_batched_stages, simulate_batched)
from repro.serving.runtime import (ClosedLoopSource, EngineCore,
                                   OracleExecutor, StreamSource, TableRecorder,
                                   VirtualClock, WallClock, simulate_runtime)

__all__ = ["Request", "Response", "ServingEngine", "closed_loop_stream",
           "make_stage_fns", "profile_host_overhead", "profile_stages",
           "AdmissionController", "BatchedPolicy", "BatchedServingEngine",
           "BatchedStageFns", "BatchPolicy", "BatchTimeModel",
           "StageBatcher", "as_batch_policy", "pad_batch",
           "profile_batched_stages", "simulate_batched",
           "ClosedLoopSource", "EngineCore", "OracleExecutor", "StreamSource",
           "TableRecorder", "VirtualClock", "WallClock", "simulate_runtime"]
