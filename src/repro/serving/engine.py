"""RTDeepIoT serving engine (paper Fig. 2) — user-space, wall-clock.

The engine owns:
  * per-stage jitted functions (repro.models.stage_forward) — the
    non-preemptive dispatch units;
  * profiled per-stage WCETs (99th-percentile, paper §IV protocol);
  * a scheduling Policy (RTDeepIoT or a baseline).

Requests (input pytree + absolute wall deadline) enter a queue; the engine
loop dispatches one stage at a time on the accelerator, returns each stage's
(prediction, confidence) to the policy between stages — the user-space
decision point the paper argues for — and responds with the deepest in-time
exit when a task completes its assigned depth or its deadline expires.

Deadline adjustment (§II-B): the caller-visible deadline is reduced by the
profiled host/dispatch overhead and one worst-case stage time (the
non-preemptible region) before it reaches the scheduler.

``run`` is a compatibility shim over the unified runtime
(``repro.serving.runtime``): an ``EngineCore`` on a ``WallClock`` with a
``DeviceExecutor`` over the per-stage jitted functions, dispatching
singleton batches (``max_batch=1``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core.task import Task
from repro.models import stage_forward


@dataclasses.dataclass
class Request:
    inputs: Any                    # single-sample input pytree (no batch dim)
    rel_deadline: float
    sample: int = 0
    client: int = 0
    arrival: float = 0.0           # wall time, filled by the engine


@dataclasses.dataclass
class Response:
    sample: int
    prediction: Optional[int]
    confidence: float
    depth: int
    missed: bool
    latency: float
    deadline: float


def make_stage_fns(cfg):
    """Jitted per-stage functions: stage 0 embeds raw inputs, later stages
    consume hidden states.  Returns list of fn(params, x) -> (h, logits,
    conf)."""
    fns = []
    for s in range(cfg.num_stages):
        def fn(params, h, _s=s):
            return stage_forward(cfg, params, _s, h, mode="train")
        fns.append(jax.jit(fn))
    return fns


def profile_stages(cfg, params, stage_fns, sample_inputs, *, n_runs: int = 100,
                   percentile: float = 99.0, sync=True):
    """Per-stage WCET = `percentile` of `n_runs` timed executions (paper:
    99% CI upper bound over profiling runs on training data).

    Also measures the host dispatch overhead (round-trip time of a no-op jit
    call) used for the §II-B deadline adjustment.  Returns
    ``(wcet, times, host_overhead)``; pass the overhead straight into
    ``ServingEngine(host_overhead=...)``.
    """
    times = np.zeros((cfg.num_stages, n_runs))
    h = sample_inputs
    for s, fn in enumerate(stage_fns):
        out = fn(params, h)                        # compile
        jax.block_until_ready(out[0])
        for i in range(n_runs):
            t0 = time.perf_counter()
            out = fn(params, h)
            jax.block_until_ready(out[0])
            times[s, i] = time.perf_counter() - t0
        h = out[0]
    wcet = np.percentile(times, percentile, axis=1)
    host_overhead = profile_host_overhead(n_runs=n_runs,
                                          percentile=percentile)
    return wcet, times, host_overhead


def profile_host_overhead(*, n_runs: int = 100,
                          percentile: float = 99.0) -> float:
    """Host dispatch overhead: round-trip of a no-op jitted call (§II-B).

    This is the per-dispatch CPU cost the engine pays before the accelerator
    starts a stage, so the caller-visible deadline is shrunk by it."""
    noop = jax.jit(lambda x: x)
    z = np.zeros((), np.float32)
    jax.block_until_ready(noop(z))                 # compile
    samples = np.zeros(n_runs)
    for i in range(n_runs):
        t0 = time.perf_counter()
        jax.block_until_ready(noop(z))
        samples[i] = time.perf_counter() - t0
    return float(np.percentile(samples, percentile))


class ServingEngine:
    def __init__(self, cfg, params, policy, *, stage_wcet,
                 host_overhead: float = 0.0, stage_fns=None):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.stage_fns = stage_fns or make_stage_fns(cfg)
        self.stage_wcet = tuple(float(x) for x in stage_wcet)
        self.host_overhead = host_overhead
        self.responses: list = []

    # ------------------------------------------------------------------
    def _make_task(self, req: Request, now: float) -> Task:
        # §II-B deadline adjustment: CPU overhead + one non-preemptive stage
        adj = self.host_overhead + max(self.stage_wcet)
        return Task(arrival=now, deadline=req.arrival + req.rel_deadline - adj,
                    stage_times=self.stage_wcet,
                    mandatory=self.cfg.mandatory_stages, sample=req.sample,
                    client=req.client)

    # ------------------------------------------------------------------
    def run(self, request_stream):
        """request_stream: iterable of (offset_seconds, Request), offsets
        non-decreasing relative to engine start."""
        from repro.serving.batch.batcher import BatchTimeModel
        from repro.serving.batch.policy import as_batch_policy
        from repro.serving.runtime import (EngineCore, ResponseRecorder,
                                           StreamSource, WallClock)
        from repro.serving.runtime.device import (DeviceExecutor,
                                                  SingleStageFns)

        pending = list(request_stream)
        pending.sort(key=lambda p: p[0])
        # warm-up: compile every stage before the clock starts (deadlines are
        # milliseconds; a first-call compile would miss everything)
        if pending:
            h = pending[0][1].inputs
            for fn in self.stage_fns:
                out = fn(self.params, h)
                jax.block_until_ready(out[0])
                h = out[0]
        tm = BatchTimeModel.linear(self.stage_wcet, buckets=(1,))
        executor = DeviceExecutor(SingleStageFns(self.stage_fns), self.params,
                                  tm)

        def admit(req, now):
            t = self._make_task(req, now)
            executor.register(t, req)
            return t

        # charge_formation=False: the legacy engine never billed next_task
        # time to policy.sched_time (it holds only the policies' own hooks)
        core = EngineCore(as_batch_policy(self.policy, tm, max_batch=1,
                                          charge_formation=False),
                          WallClock(), executor, StreamSource(pending, admit),
                          ResponseRecorder(executor, self.responses))
        core.run()
        return self.responses


def closed_loop_stream(dataset_inputs, labels, *, n_clients, d_lo, d_hi,
                       n_requests, seed=0, spacing=None):
    """Open-loop approximation of the paper's K-client workload for the
    wall-clock engine: K interleaved request lanes with deadline-spaced
    issue times."""
    rng = np.random.default_rng(seed)
    n = len(labels)
    order = rng.permutation(n)
    reqs = []
    t_client = np.zeros(n_clients)
    for j in range(n_requests):
        c = int(np.argmin(t_client))
        rel = float(rng.uniform(d_lo, d_hi))
        sample = int(order[j % n])
        inputs = jax.tree.map(lambda x: x[sample:sample + 1], dataset_inputs)
        reqs.append((float(t_client[c]), Request(inputs, rel, sample, c)))
        t_client[c] += rel if spacing is None else spacing
    reqs.sort(key=lambda p: p[0])
    return reqs
