"""RTDeepIoT serving engine (paper Fig. 2) — user-space, wall-clock.

The engine owns:
  * per-stage jitted functions (repro.models.stage_forward) — the
    non-preemptive dispatch units;
  * profiled per-stage WCETs (99th-percentile, paper §IV protocol);
  * a scheduling Policy (RTDeepIoT or a baseline).

Requests (input pytree + absolute wall deadline) enter a queue; the engine
loop dispatches one stage at a time on the accelerator, returns each stage's
(prediction, confidence) to the policy between stages — the user-space
decision point the paper argues for — and responds with the deepest in-time
exit when a task completes its assigned depth or its deadline expires.

Deadline adjustment (§II-B): the caller-visible deadline is reduced by the
profiled host/dispatch overhead and one worst-case stage time (the
non-preemptible region) before it reaches the scheduler.

``run`` is a deprecated wrapper over the public serving facade
(``repro.serving.service``): a ``ServeSpec`` on the ``device-single``
executor / wall clock / stream source, dispatching singleton batches
(``batching={"mode": "none"}``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.models import stage_forward


@dataclasses.dataclass
class Request:
    inputs: Any                    # single-sample input pytree (no batch dim)
    rel_deadline: Optional[float] = None   # None: the SLO class supplies it
    sample: int = 0
    client: int = 0
    arrival: float = 0.0           # wall time, filled by the engine
    slo: Optional[str] = None      # SLO class name (repro.serving.service)
    tenant: Optional[str] = None   # tenant label (repro.serving.plane)
    request_id: Optional[str] = None  # idempotence key (durable plane)
    seq_len: Optional[int] = None  # ragged input length (length-bucket WCETs)
    model: Optional[str] = None    # model-zoo id (repro.serving.zoo)


@dataclasses.dataclass
class Response:
    sample: int
    prediction: Optional[int]
    confidence: float
    depth: int
    missed: bool
    latency: float
    deadline: float


def make_stage_fns(cfg):
    """Jitted per-stage functions: stage 0 embeds raw inputs, later stages
    consume hidden states.  Returns list of fn(params, x) -> (h, logits,
    conf)."""
    fns = []
    for s in range(cfg.num_stages):
        def fn(params, h, _s=s):
            return stage_forward(cfg, params, _s, h, mode="train")
        fns.append(jax.jit(fn))
    return fns


def profile_stages(cfg, params, stage_fns, sample_inputs, *, n_runs: int = 100,
                   percentile: float = 99.0, sync=True):
    """Per-stage WCET = `percentile` of `n_runs` timed executions (paper:
    99% CI upper bound over profiling runs on training data).

    Also measures the host dispatch overhead (round-trip time of a no-op jit
    call) used for the §II-B deadline adjustment.  Returns
    ``(wcet, times, host_overhead)``; pass the overhead straight into
    ``ServingEngine(host_overhead=...)``.
    """
    times = np.zeros((cfg.num_stages, n_runs))
    h = sample_inputs
    for s, fn in enumerate(stage_fns):
        out = fn(params, h)                        # compile
        jax.block_until_ready(out[0])
        for i in range(n_runs):
            t0 = time.perf_counter()
            out = fn(params, h)
            jax.block_until_ready(out[0])
            times[s, i] = time.perf_counter() - t0
        h = out[0]
    wcet = np.percentile(times, percentile, axis=1)
    host_overhead = profile_host_overhead(n_runs=n_runs,
                                          percentile=percentile)
    return wcet, times, host_overhead


def profile_host_overhead(*, n_runs: int = 100,
                          percentile: float = 99.0) -> float:
    """Host dispatch overhead: round-trip of a no-op jitted call (§II-B).

    This is the per-dispatch CPU cost the engine pays before the accelerator
    starts a stage, so the caller-visible deadline is shrunk by it."""
    noop = jax.jit(lambda x: x)
    z = np.zeros((), np.float32)
    jax.block_until_ready(noop(z))                 # compile
    samples = np.zeros(n_runs)
    for i in range(n_runs):
        t0 = time.perf_counter()
        jax.block_until_ready(noop(z))
        samples[i] = time.perf_counter() - t0
    return float(np.percentile(samples, percentile))


class ServingEngine:
    def __init__(self, cfg, params, policy, *, stage_wcet,
                 host_overhead: float = 0.0, stage_fns=None):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.stage_fns = stage_fns or make_stage_fns(cfg)
        self.stage_wcet = tuple(float(x) for x in stage_wcet)
        self.host_overhead = host_overhead
        self.responses: list = []

    # ------------------------------------------------------------------
    def run(self, request_stream):
        """request_stream: iterable of (offset_seconds, Request), offsets
        non-decreasing relative to engine start."""
        from repro.serving.deprecation import deprecate_once
        from repro.serving.service import ServeSpec, Service

        deprecate_once(
            "repro.serving.ServingEngine.run",
            "ServingEngine is deprecated: build a ServeSpec(executor="
            "'device-single', clock='wall', source='stream') and run it "
            "through repro.serving.Service instead")
        spec = ServeSpec(
            executor="device-single", clock="wall", source="stream",
            batching={"mode": "none",
                      "stage_times": [float(x) for x in self.stage_wcet]},
            host_overhead=self.host_overhead)
        svc = Service.from_spec(spec, policy=self.policy, cfg=self.cfg,
                                params=self.params,
                                stage_fns=self.stage_fns)
        svc.run(request_stream)
        self.responses.extend(svc.responses)
        return self.responses


def closed_loop_stream(dataset_inputs, labels, *, n_clients, d_lo, d_hi,
                       n_requests, seed=0, spacing=None):
    """Open-loop approximation of the paper's K-client workload for the
    wall-clock engine: K interleaved request lanes with deadline-spaced
    issue times."""
    rng = np.random.default_rng(seed)
    n = len(labels)
    order = rng.permutation(n)
    reqs = []
    t_client = np.zeros(n_clients)
    for j in range(n_requests):
        c = int(np.argmin(t_client))
        rel = float(rng.uniform(d_lo, d_hi))
        sample = int(order[j % n])
        inputs = jax.tree.map(lambda x: x[sample:sample + 1], dataset_inputs)
        reqs.append((float(t_client[c]), Request(inputs, rel, sample, c)))
        t_client[c] += rel if spacing is None else spacing
    reqs.sort(key=lambda p: p[0])
    return reqs
