"""Wall-clock traffic driver: generators feeding ``Service.submit()`` in
real time (ROADMAP open item 4, fourth leg).

The ``traffic`` registry source materializes an arrival process into a
virtual-clock stream; that validates scheduling logic but never exercises
the live intake path (submit -> LiveSource -> background engine ->
ResponseHandle).  :class:`TrafficDriver` closes that gap: the same seeded
``ArrivalProcess`` x :class:`~repro.serving.traffic.mix.RequestMix`
materialization, but paced against the real clock into ``submit()`` —
with a replay ``speed`` factor (2.0 = twice as fast as recorded/sampled),
so a day of diurnal traffic compresses into a test-sized burst.  A
recorded trace replays the same way via :meth:`TrafficDriver.from_trace`.

```python no-run
from repro.serving.adaptive import TrafficDriver

svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
drv = TrafficDriver(svc, arrival={"kind": "poisson", "rate": 40.0},
                    mix=[{"slo": "gold", "share": 1.0}], n_samples=100,
                    n_requests=200, seed=0, speed=4.0)
drv.run()                      # blocks; .start() runs on a thread
res = svc.drain()
assert res.n_requests == drv.submitted
```
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.serving.traffic.mix import RequestMix

__all__ = ["TrafficDriver"]

#: sleep granularity while pacing (bounded so stop() stays responsive)
_MAX_SLEEP = 0.02


class TrafficDriver:
    """Pace an open-loop request stream into ``Service.submit()`` on the
    wall clock.

    The stream is pre-materialized exactly as the virtual-clock
    ``traffic`` source does it — ``arrival.sample(rng)`` then
    ``mix.stream(rng, offsets)`` from one seeded generator — so the same
    (arrival, mix, seed) triple produces the same requests on either
    clock; only the pacing differs.  ``speed`` divides every offset:
    2.0 replays twice as fast, 0.5 at half speed.
    """

    def __init__(self, service, *, arrival=None, offsets=None, mix=None,
                 n_samples: int = None, n_requests: int = None,
                 horizon: float = None, seed: int = 0, speed: float = 1.0,
                 inputs_fn=None, tenant=None):
        if speed <= 0:
            raise ValueError("speed must be > 0")
        self.service = service
        self.speed = float(speed)
        self.tenant = tenant
        rng = np.random.default_rng(seed)
        if offsets is None:
            if arrival is None:
                raise ValueError("need arrival=... or offsets=...")
            if isinstance(arrival, dict):
                from repro.serving.traffic.generators import \
                    make_arrival_process
                arrival = make_arrival_process(**arrival)
            if n_requests is None and horizon is None:
                raise ValueError("need n_requests and/or horizon")
            offsets = arrival.sample(rng, n=n_requests, horizon=horizon)
        if isinstance(mix, RequestMix):
            pass
        elif mix is not None:
            if n_samples is None:
                raise ValueError("mix classes need n_samples=...")
            mix = RequestMix(mix, n_samples=n_samples, inputs_fn=inputs_fn)
        else:
            if n_samples is None:
                raise ValueError("need mix=... or n_samples=...")
            mix = RequestMix([], n_samples=n_samples, inputs_fn=inputs_fn)
        self.stream = mix.stream(rng, offsets)
        self.handles: list = []
        self.submitted = 0
        self._stop = threading.Event()
        self._thread = None

    # ------------------------------------------------------------------
    @classmethod
    def from_trace(cls, service, events, *, speed: float = 1.0):
        """Replay recorded trace events (``load_trace`` output) against
        the live service at ``speed``x real time."""
        from repro.serving.traffic.trace import replay_stream
        drv = cls.__new__(cls)
        drv.service = service
        drv.speed = float(speed)
        drv.tenant = None
        drv.stream = replay_stream(events)
        drv.handles = []
        drv.submitted = 0
        drv._stop = threading.Event()
        drv._thread = None
        if drv.speed <= 0:
            raise ValueError("speed must be > 0")
        return drv

    # ------------------------------------------------------------------
    def run(self) -> int:
        """Feed the whole stream, blocking; returns requests submitted."""
        t0 = time.perf_counter()
        for off, req in self.stream:
            target = float(off) / self.speed
            while not self._stop.is_set():
                dt = target - (time.perf_counter() - t0)
                if dt <= 0:
                    break
                time.sleep(min(dt, _MAX_SLEEP))
            if self._stop.is_set():
                break
            kw = {}
            if self.tenant is not None:
                kw["tenant"] = self.tenant
            self.handles.append(self.service.submit(req, **kw))
            self.submitted += 1
        return self.submitted

    def start(self) -> "TrafficDriver":
        """Run on a daemon thread; pair with :meth:`join`."""
        if self._thread is not None:
            raise RuntimeError("driver already started")
        self._thread = threading.Thread(target=self.run,
                                        name="traffic-driver", daemon=True)
        self._thread.start()
        return self

    def join(self, timeout: float = None) -> bool:
        """Wait for the feed thread; True when it finished."""
        if self._thread is None:
            return True
        self._thread.join(timeout)
        return not self._thread.is_alive()

    def stop(self) -> None:
        """Abort pacing; an in-flight sleep wakes within ~20 ms."""
        self._stop.set()
