"""Online confidence-curve estimation (ROADMAP open item 4, second leg).

The FPTAS plans against a confidence-vs-depth curve per task; everywhere
else in the repo that curve is a *static* prior (``conf_table.mean(0)``
from offline calibration).  :class:`OnlineCurveEstimator` learns it from
the stage exits the scheduler observes anyway: every completed stage
reports a measured exit confidence at a depth, and an exponential-decay
window per (class key, depth) cell keeps the table fresh under drift
while converging to the oracle mean table under stationary traffic.

:class:`AdaptivePredictor` plugs the live table into the paper's utility
interface (measured prefix, learned ratio-anchored suffix, monotone in
depth), and :class:`AdaptiveRTDeepIoT` — registered as
``register_policy("rtdeepiot-adaptive")`` — feeds every observed stage
exit back into the estimator before the §II-E greedy update runs.

```python
import numpy as np
from repro.serving.adaptive import OnlineCurveEstimator

oracle = np.sort(np.random.default_rng(0).uniform(0.3, 1.0, (500, 3)),
                 axis=1)
est = OnlineCurveEstimator(num_stages=3, prior_weight=0.0)
for row in oracle:
    for depth, conf in enumerate(row, start=1):
        est.observe(depth, conf)
learned = est.curve()
assert np.all(np.diff(learned) >= 0)          # monotone in depth
assert np.abs(learned - oracle.mean(0)).max() < 0.1
```
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.schedulers import RTDeepIoT
from repro.core.utility import UtilityPredictor

__all__ = ["OnlineCurveEstimator", "AdaptivePredictor", "AdaptiveRTDeepIoT"]

#: estimator table key for single-model traffic
GLOBAL_KEY = None


class OnlineCurveEstimator:
    """Per-class confidence-vs-depth tables from observed stage exits.

    Each (key, depth) cell is an exponentially-decayed weighted mean:
    ``observe`` scales the cell's weight and sum by ``1 - decay`` and
    adds the new outcome, so the effective window is ``~1/decay``
    observations and stale traffic ages out.  ``curve(key)`` blends the
    cell means with the prior curve at ``prior_weight`` pseudo-counts
    (unseen depths fall back to the prior entirely) and enforces
    monotone-in-depth via a running maximum — the shape the FPTAS
    utility tables require.

    ``key`` is any hashable class label (model id, SLO tier, tenant);
    ``None`` is the single-model global table.
    """

    def __init__(self, num_stages: int, prior=None, decay: float = 0.02,
                 prior_weight: float = 4.0):
        if num_stages < 1:
            raise ValueError("num_stages must be >= 1")
        if not 0.0 <= decay < 1.0:
            raise ValueError("decay must be in [0, 1)")
        self.num_stages = int(num_stages)
        if prior is None:
            # weak default: linear ramp toward confident late exits
            prior = np.linspace(0.5, 0.9, self.num_stages)
        self.prior = np.clip(np.maximum.accumulate(
            np.asarray(prior, np.float64)), 0.0, 1.0)
        if len(self.prior) != self.num_stages:
            raise ValueError(f"prior has {len(self.prior)} entries for "
                             f"{self.num_stages} stages")
        self.decay = float(decay)
        self.prior_weight = float(prior_weight)
        self._w: dict = {}           # key -> per-depth decayed weights
        self._s: dict = {}           # key -> per-depth decayed conf sums
        self.n_observed = 0

    # ------------------------------------------------------------------
    def _cells(self, key):
        if key not in self._w:
            self._w[key] = np.zeros(self.num_stages)
            self._s[key] = np.zeros(self.num_stages)
        return self._w[key], self._s[key]

    def observe(self, depth: int, conf: float, key=GLOBAL_KEY) -> None:
        """One stage-exit outcome: measured ``conf`` at ``depth`` (1..L)."""
        if not 1 <= depth <= self.num_stages:
            raise ValueError(f"depth {depth} not in 1..{self.num_stages}")
        w, s = self._cells(key)
        d = depth - 1
        w[d] = (1.0 - self.decay) * w[d] + 1.0
        s[d] = (1.0 - self.decay) * s[d] + float(conf)
        self.n_observed += 1

    def observe_exits(self, confidences, key=GLOBAL_KEY) -> None:
        """A full per-stage exit record (depth = position + 1)."""
        for depth, conf in enumerate(confidences, start=1):
            self.observe(depth, float(conf), key=key)

    # ------------------------------------------------------------------
    def weight(self, key=GLOBAL_KEY) -> np.ndarray:
        """Effective observation weight per depth (0 = never observed)."""
        return self._w.get(key, np.zeros(self.num_stages)).copy()

    def curve(self, key=GLOBAL_KEY) -> np.ndarray:
        """The learned confidence-vs-depth curve for ``key``: prior-blended
        decayed means, clipped to [0, 1], monotone non-decreasing."""
        w, s = self._w.get(key), self._s.get(key)
        if w is None:
            out = self.prior.copy()
        else:
            out = ((s + self.prior_weight * self.prior)
                   / np.maximum(w + self.prior_weight, 1e-12))
            never = (w <= 0) & (self.prior_weight <= 0)
            out[never] = self.prior[never]
        return np.maximum.accumulate(np.clip(out, 0.0, 1.0))

    def keys(self) -> list:
        return list(self._w)

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-able snapshot (string-keyed; ``None`` -> ``""``)."""
        return {"num_stages": self.num_stages, "decay": self.decay,
                "prior_weight": self.prior_weight,
                "prior": [float(x) for x in self.prior],
                "tables": {("" if k is None else str(k)):
                           {"w": [float(x) for x in self._w[k]],
                            "s": [float(x) for x in self._s[k]]}
                           for k in self._w}}

    @classmethod
    def from_dict(cls, d: dict) -> "OnlineCurveEstimator":
        est = cls(d["num_stages"], prior=d.get("prior"),
                  decay=d.get("decay", 0.02),
                  prior_weight=d.get("prior_weight", 4.0))
        for k, t in d.get("tables", {}).items():
            key = None if k == "" else k
            est._w[key] = np.asarray(t["w"], np.float64)
            est._s[key] = np.asarray(t["s"], np.float64)
        return est


def _default_key(task):
    return getattr(task, "model", None)


class AdaptivePredictor(UtilityPredictor):
    """§II-D utility predictor backed by a live learned curve.

    Measured confidences win at depths already executed; deeper depths
    read the estimator's class curve, ratio-anchored at the task's last
    measured confidence (the Lin heuristic's anchoring, but against the
    *learned* population curve instead of cumulative execution time).
    Predictions stay monotone non-decreasing beyond the executed prefix
    and never fall below the last measured value.
    """

    name = "adaptive"

    def __init__(self, estimator: OnlineCurveEstimator,
                 key_fn: Optional[Callable] = None):
        super().__init__(estimator.prior)
        self.estimator = estimator
        self.key_fn = key_fn or _default_key

    def predict(self, task, depth):
        e = task.executed
        if depth <= e and task.confidences:
            return float(task.confidences[depth - 1])
        curve = self.estimator.curve(self.key_fn(task))
        c = float(curve[min(depth, len(curve)) - 1])
        if task.confidences:
            last = float(task.confidences[-1])
            anchor = float(curve[min(max(e, 1), len(curve)) - 1])
            if anchor > 1e-9:
                c = last * (c / anchor)
            c = max(c, last)
        return float(min(1.0, max(0.0, c)))


class AdaptiveRTDeepIoT(RTDeepIoT):
    """The paper's scheduler with learned utility tables: every observed
    stage exit updates the estimator *before* the §II-E greedy check, so
    the very next replan plans against the refreshed curve."""

    def __init__(self, estimator: OnlineCurveEstimator, delta: float = 0.1,
                 key_fn: Optional[Callable] = None):
        super().__init__(AdaptivePredictor(estimator, key_fn), delta=delta)
        self.estimator = estimator
        self.name = "rtdeepiot-adaptive"

    def on_stage_done(self, active, task, now):
        if task.confidences and task.executed >= 1:
            self.estimator.observe(
                min(task.executed, self.estimator.num_stages),
                float(task.confidences[-1]),
                key=self.predictor.key_fn(task))
        super().on_stage_done(active, task, now)
