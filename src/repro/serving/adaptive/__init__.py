"""Adaptive control: learned workload + confidence curves, predictive
admission, wall-clock traffic — ``repro.serving.adaptive``.

ROADMAP open item 4, four legs over the existing subsystems (all wired
through the registry/ServeSpec front door — no core-loop changes):

* **workload** — fit Poisson/MMPP/diurnal/flash-crowd parameters from
  recorded arrivals (traces, journals, ``per_request`` rows) and score
  which kind best explains a trace (:func:`fit_report`).
* **curves** — :class:`OnlineCurveEstimator` learns per-class
  confidence-vs-depth tables from observed stage exits;
  ``policy="rtdeepiot-adaptive"`` plans the FPTAS against the live
  learned curve (a ``curve_estimator`` resource shares/warms tables
  across runs).
* **admission** — :class:`PredictiveAdmissionController` degrades at
  admission time when the fitted process forecasts near-term arrivals
  above capacity; enabled by ``spec.admission["forecast"]``.
* **driver** — :class:`TrafficDriver` paces generator/trace streams into
  ``Service.submit()`` on the wall clock with a replay ``speed`` factor.

Importing this package (``repro.serving`` does it) registers the
``rtdeepiot-adaptive`` policy key.
"""
from repro.serving.adaptive.admission import (PredictiveAdmissionController,
                                              predictive_admission)
from repro.serving.adaptive.curves import (AdaptivePredictor,
                                           AdaptiveRTDeepIoT,
                                           OnlineCurveEstimator)
from repro.serving.adaptive.driver import TrafficDriver
from repro.serving.adaptive.workload import (extract_offsets,
                                             fit_arrival_process,
                                             fit_diurnal, fit_flash_crowd,
                                             fit_mmpp, fit_poisson,
                                             fit_report)
from repro.serving.registry import register_policy

__all__ = ["OnlineCurveEstimator", "AdaptivePredictor", "AdaptiveRTDeepIoT",
           "PredictiveAdmissionController", "predictive_admission",
           "TrafficDriver", "extract_offsets", "fit_arrival_process",
           "fit_poisson", "fit_mmpp", "fit_diurnal", "fit_flash_crowd",
           "fit_report"]


@register_policy("rtdeepiot-adaptive")
def _make_rtdeepiot_adaptive(args: dict, ctx):
    """RTDeepIoT planning against *learned* confidence curves.

    args: ``delta`` (FPTAS quantization), ``decay`` / ``prior_weight``
    (estimator window), ``prior_curve`` (seed table; default
    ``conf_table.mean(0)`` when the resource exists).  A
    ``curve_estimator`` resource (an :class:`OnlineCurveEstimator`)
    overrides everything — pass the same instance to successive builds to
    keep the learned tables warm across runs.
    """
    est = ctx.resources.get("curve_estimator")
    if est is None:
        prior = args.get("prior_curve")
        if prior is None:
            ct = ctx.resources.get("conf_table")
            prior = ct.mean(0) if ct is not None else None
        num_stages = (len(prior) if prior is not None
                      else len(ctx.time_model.single_times()))
        est = OnlineCurveEstimator(
            num_stages=num_stages, prior=prior,
            decay=float(args.get("decay", 0.02)),
            prior_weight=float(args.get("prior_weight", 4.0)))
    return AdaptiveRTDeepIoT(est, delta=float(args.get("delta", 0.1)))
