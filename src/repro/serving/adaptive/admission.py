"""Predictive admission: tighten *ahead* of a forecast burst
(ROADMAP open item 4, third leg).

The reactive :class:`~repro.serving.batch.admission.AdmissionController`
prices the queue it can see; under a flash crowd that means the first
spike arrivals are admitted at full depth and miss their deadlines before
the backlog term ever registers.  This controller adds a forecast hook: a
fitted :class:`~repro.serving.traffic.generators.ArrivalProcess` (from
:mod:`~repro.serving.adaptive.workload`, e.g. yesterday's trace) predicts
the near-term arrival rate, and when that forecast exceeds the engine's
nominal full-depth capacity the controller degrades *at admission time*:

* ``mode="depth_cap"`` — requests admitted inside the forecast window are
  pinned to their mandatory depth (``forecast-capped``): optional stages
  are shed before the burst arrives, not after the queue grows.
* ``mode="reject"`` — the forecast-implied work expected to land within
  the request's slack joins the backlog term; a request whose deadline
  cannot absorb it is refused (``forecast-overload``).

Every forecast decision carries the numbers behind the rule
(forecast rate, capacity, margin, horizon) in
:class:`~repro.serving.batch.admission.AdmissionDecision.detail`, so the
observability audit log answers "why was this degraded?" quantitatively
(``planectl why`` / ``service.obs.audit_log``).

Spec wiring (JSON-round-trippable through ``ServeSpec``)::

    admission={"mode": "depth_cap",
               "forecast": {"process": fitted.to_dict(),  # arrival kind
                            "horizon": 0.25,              # lookahead (s)
                            "margin": 1.0,                # of capacity
                            "capacity": None}}            # default: nominal
"""
from __future__ import annotations

import numpy as np

from repro.serving.batch.admission import (AdmissionController,
                                           AdmissionDecision)

__all__ = ["PredictiveAdmissionController", "predictive_admission"]

#: points sampled across the lookahead window when averaging rate_at
_FORECAST_POINTS = 9


class PredictiveAdmissionController(AdmissionController):
    """Reactive admission + a fitted-process forecast rule (see module
    docstring).  ``process=None`` degrades to the reactive base."""

    def __init__(self, time_model, mode: str = "depth_cap",
                 headroom: float = 1.0, *, process=None,
                 horizon: float = 0.25, margin: float = 1.0,
                 capacity: float = None):
        super().__init__(time_model, mode=mode, headroom=headroom)
        self.process = process
        self.horizon = float(horizon)
        self.margin = float(margin)
        if capacity is None:
            # nominal full-depth service rate, the traffic scenarios' anchor
            capacity = 1.0 / sum(time_model.single_times())
        self.capacity = float(capacity)
        self.forecasted = 0          # forecast rules fired (capped+rejected)

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, time_model, admission: dict,
                    **kwargs) -> "PredictiveAdmissionController":
        """Build from a ``ServeSpec.admission`` dict with a ``forecast``
        key; the process sub-dict is a ``make_arrival_process`` kind."""
        from repro.serving.traffic.generators import make_arrival_process
        fc = dict(admission.get("forecast") or {})
        proc = fc.get("process")
        if isinstance(proc, dict):
            proc = make_arrival_process(**proc)
        return cls(time_model,
                   mode=admission.get("mode", "depth_cap"),
                   headroom=float(admission.get("headroom", 1.0)),
                   process=proc,
                   horizon=float(fc.get("horizon", 0.25)),
                   margin=float(fc.get("margin", 1.0)),
                   capacity=fc.get("capacity"), **kwargs)

    # ------------------------------------------------------------------
    def forecast_rate(self, now: float) -> float:
        """Mean predicted arrival rate over ``[now, now + horizon]``
        (processes without a pointwise rate — MMPP — use their long-run
        mean)."""
        p = self.process
        if p is None:
            return 0.0
        try:
            ts = np.linspace(now, now + self.horizon, _FORECAST_POINTS)
            return float(np.mean([p.rate_at(t) for t in ts]))
        except NotImplementedError:
            return float(p.mean_rate)

    def decide(self, active, task, now: float) -> AdmissionDecision:
        dec = super().decide(active, task, now)
        if (not dec.admitted or self.process is None
                or self.mode == "off"):
            return dec
        rate = self.forecast_rate(now)
        if rate <= self.capacity * self.margin:
            return dec
        tm = self._tm_for(task)
        detail = {"forecast_rate": rate, "capacity": self.capacity,
                  "margin": self.margin, "horizon": self.horizon,
                  "slack": task.deadline - now}
        if self.mode == "reject":
            # forecast-implied mandatory work landing within this task's
            # slack competes for the same device
            own = sum(self._amortized(s, tm) for s in range(task.mandatory))
            backlog = sum(
                sum(self._amortized(s, self._tm_for(t))
                    for s in range(t.executed, max(t.mandatory, t.executed)))
                for t in active)
            window = min(self.horizon, max(task.deadline - now, 0.0))
            expected = rate * window * own
            if now + (backlog + own + expected) * self.headroom \
                    > task.deadline:
                self.forecasted += 1
                detail.update(backlog=backlog, own_amortized=own,
                              expected_work=expected,
                              headroom=self.headroom)
                return AdmissionDecision(False, None, "forecast-overload",
                                         detail=detail)
            return dec
        # depth_cap: shed optional stages ahead of the predicted burst
        cap = task.mandatory
        if dec.depth_cap is None or dec.depth_cap > cap:
            self.forecasted += 1
            return AdmissionDecision(True, cap, "forecast-capped",
                                     detail=detail)
        return dec


def predictive_admission(time_model, admission: dict, base_cls=None):
    """Factory for :class:`Service`: a predictive controller whose
    per-task WCET resolution comes from ``base_cls`` (the zoo controller
    overrides ``_tm_for``) when one is given."""
    cls = PredictiveAdmissionController
    if base_cls is not None and base_cls is not AdmissionController:
        cls = type(f"Predictive{base_cls.__name__}",
                   (PredictiveAdmissionController, base_cls), {})
    return cls.from_config(time_model, admission)
