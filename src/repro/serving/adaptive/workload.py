"""Trace-driven workload estimation: fit arrival-process parameters from
recorded arrivals (ROADMAP open item 4, first leg).

Every serving run leaves an arrival record behind — a traffic trace
(``repro.serving.traffic.trace``), a durable-plane journal
(``repro.serving.plane``), or just ``ServiceMetrics.per_request`` rows.
This module closes the loop: given those recorded offsets, fit the
parameters of each :mod:`~repro.serving.traffic.generators` arrival kind
by method of moments (Poisson), on/off burst segmentation (MMPP),
harmonic regression on the Rayleigh-scored period (diurnal), or spike
segmentation (flash-crowd), and score which kind best explains the trace
(windowed Poisson log-likelihood with a BIC complexity penalty).

The fitted dicts are ``make_arrival_process``-compatible, so a fit can be
replayed as synthetic load, drive the wall-clock
:class:`~repro.serving.adaptive.driver.TrafficDriver`, or arm the
forecast hook of
:class:`~repro.serving.adaptive.admission.PredictiveAdmissionController`.

```python
import numpy as np
from repro.serving.traffic import make_arrival_process
from repro.serving.adaptive import fit_report

true = make_arrival_process("poisson", rate=80.0)
offsets = true.sample(np.random.default_rng(0), n=2000)
report = fit_report(offsets)
assert report["best"] == "poisson"
assert abs(report["fits"]["poisson"]["rate"] - 80.0) / 80.0 < 0.1
```
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

__all__ = ["extract_offsets", "fit_poisson", "fit_mmpp", "fit_diurnal",
           "fit_flash_crowd", "fit_report", "fit_arrival_process"]

#: minimum arrivals before any fit is meaningful
MIN_ARRIVALS = 8

#: record kinds that mark an arrival (trace events + journal submissions)
_ARRIVAL_KINDS = ("EVENT", "SUBMIT")


# ---------------------------------------------------------------------------
# offset extraction — one reader for every arrival record the repo produces
# ---------------------------------------------------------------------------

def extract_offsets(source) -> np.ndarray:
    """Sorted arrival offsets from any arrival record the repo produces.

    Accepts an array/list of floats, ``ServiceMetrics.per_request`` rows,
    ``TraceEvent``/``Record`` lists, a trace/journal JSONL path, or a
    journal *directory* (every ``wal-*.jsonl`` segment is scanned;
    only ``EVENT``/``SUBMIT`` records count as arrivals).
    """
    if isinstance(source, (str, os.PathLike)):
        path = os.fspath(source)
        if os.path.isdir(path):
            offs = []
            for seg in sorted(os.listdir(path)):
                if seg.startswith("wal-") and seg.endswith(".jsonl"):
                    offs.append(extract_offsets(os.path.join(path, seg)))
            if not offs:
                raise ValueError(f"no wal-*.jsonl segments under {path!r}")
            return np.sort(np.concatenate(offs))
        offs = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if d.get("type") == "header":
                    continue
                if d.get("kind", "EVENT") in _ARRIVAL_KINDS:
                    offs.append(float(d["offset"]))
        return np.sort(np.asarray(offs, float))
    out = []
    for item in source:
        if isinstance(item, dict):              # per_request rows
            out.append(float(item.get("offset", item.get("arrival"))))
        elif hasattr(item, "offset"):           # TraceEvent / Record
            if getattr(item, "kind", "EVENT") in _ARRIVAL_KINDS:
                out.append(float(item.offset))
        else:                                   # plain offsets
            out.append(float(item))
    return np.sort(np.asarray(out, float))


def _check(offsets) -> np.ndarray:
    offsets = extract_offsets(offsets)
    if len(offsets) < MIN_ARRIVALS:
        raise ValueError(f"need >= {MIN_ARRIVALS} arrivals to fit, "
                         f"got {len(offsets)}")
    if offsets[-1] - offsets[0] <= 0:
        raise ValueError("arrivals span zero time — cannot fit a rate")
    return offsets


def _windowed(offsets: np.ndarray, window: float = None):
    """(rates, window_starts, window) — arrival counts per fixed window.

    Default window targets ~8 arrivals per window so burst segmentation
    sees state dwell times, not single-arrival shot noise.
    """
    span = offsets[-1] - offsets[0]
    if window is None:
        window = span / max(len(offsets) // 8, 4)
    n_win = max(int(math.ceil(span / window)), 1)
    edges = offsets[0] + window * np.arange(n_win + 1)
    counts, _ = np.histogram(offsets, bins=edges)
    return counts / window, edges[:-1], window


# ---------------------------------------------------------------------------
# per-kind fitters
# ---------------------------------------------------------------------------

def fit_poisson(offsets) -> dict:
    """Method of moments on inter-arrival gaps: conditioning on the first
    arrival, the MLE of a homogeneous rate is (n-1)/span."""
    offsets = _check(offsets)
    span = offsets[-1] - offsets[0]
    return {"kind": "poisson", "rate": float((len(offsets) - 1) / span)}


def _two_means(rates: np.ndarray, iters: int = 32):
    """Two-cluster 1-D segmentation (Lloyd's): (labels, lo, hi)."""
    lo, hi = float(rates.min()), float(rates.max())
    labels = np.zeros(len(rates), bool)
    for _ in range(iters):
        thr = 0.5 * (lo + hi)
        new = rates >= thr
        if not new.any() or new.all():
            break
        nlo = float(rates[~new].mean())
        nhi = float(rates[new].mean())
        if (new == labels).all() and nlo == lo and nhi == hi:
            break
        labels, lo, hi = new, nlo, nhi
    return labels, lo, hi


def _mmpp_segment(offsets, window=None):
    """(labels, rates, window) — on/off burst segmentation of windowed
    rates (the state path the MMPP fit and its likelihood score share)."""
    rates, _starts, w = _windowed(offsets, window)
    labels, _, _ = _two_means(rates)
    return labels, rates, w


def fit_mmpp(offsets, window: float = None) -> dict:
    """On/off burst segmentation: two-means clustering of windowed rates
    into a quiet and a burst state; per-state rates are the mean windowed
    rate, dwell means the mean contiguous run length per state."""
    offsets = _check(offsets)
    labels, rates, w = _mmpp_segment(offsets, window)
    if labels.any() and not labels.all():
        rate_on = float(rates[labels].mean())
        rate_off = float(rates[~labels].mean())
    else:
        # one state only — degenerate to Poisson-at-one-rate
        rate_on = rate_off = float(rates.mean())
    runs_on, runs_off, cur, state = [], [], 0, bool(labels[0])
    for lab in labels:
        if bool(lab) == state:
            cur += 1
        else:
            (runs_on if state else runs_off).append(cur)
            cur, state = 1, bool(lab)
    (runs_on if state else runs_off).append(cur)
    mean_on = float(np.mean(runs_on)) * w if runs_on else w
    mean_off = float(np.mean(runs_off)) * w if runs_off else w
    return {"kind": "mmpp", "rate_on": rate_on, "rate_off": rate_off,
            "mean_on": mean_on, "mean_off": mean_off}


def _rayleigh(offsets: np.ndarray, period: float) -> float:
    """Rayleigh statistic |sum exp(2*pi*i*t/P)| / n: the phase coherence
    of the arrivals at candidate period P (peaks at the true period of a
    sinusoidally modulated Poisson process)."""
    ph = 2.0 * np.pi * offsets / period
    return float(np.hypot(np.cos(ph).sum(), np.sin(ph).sum())
                 / len(offsets))


def fit_diurnal(offsets, periods=None) -> dict:
    """Harmonic regression at the Rayleigh-scored period.

    The generator's rate is ``m - a*cos(2*pi*t/period)`` with the trough
    at t = 0 (``m = (base+peak)/2``, ``a = (peak-base)/2``) — the phase
    convention every :class:`DiurnalArrivals` trace starts from.  The
    period maximizes the Rayleigh statistic over a coarse-then-refined
    grid; the amplitude follows from the harmonic moment
    ``E[sum cos(2*pi*t_j/P)] = -a * span / 2``.
    """
    offsets = _check(offsets)
    span = offsets[-1] - offsets[0]
    if periods is None:
        # need >= ~1.5 observed cycles for the period to be identifiable
        periods = np.geomspace(span / 40.0, span / 1.5, 160)
    scores = [_rayleigh(offsets, p) for p in periods]
    best = float(periods[int(np.argmax(scores))])
    # local refinement around the coarse winner
    fine = np.linspace(best * 0.85, best * 1.15, 121)
    best = float(fine[int(np.argmax([_rayleigh(offsets, p) for p in fine]))])
    m = len(offsets) / span
    a = -2.0 / span * float(np.cos(2.0 * np.pi * offsets / best).sum())
    a = min(max(a, 0.0), m)           # rates stay >= 0
    return {"kind": "diurnal", "base_rate": float(m - a),
            "peak_rate": float(m + a), "period": best}


def fit_flash_crowd(offsets, window: float = None) -> dict:
    """Spike segmentation: base rate from the windows outside the widest
    significantly-elevated contiguous run, spike rate/extent from the run
    containing the peak window."""
    offsets = _check(offsets)
    rates, starts, w = _windowed(offsets, window)
    base = float(np.median(rates))
    # significance: beyond Poisson counting noise at the base rate
    thresh = max(2.0 * base, base + 3.0 * math.sqrt(max(base / w, 1e-12)))
    hot = rates > thresh
    if not hot.any():
        return {"kind": "flash-crowd", "base_rate": base,
                "spike_rate": base, "spike_at": float(offsets[-1]),
                "spike_len": 0.0}
    peak = int(np.argmax(rates))
    lo = peak
    while lo > 0 and hot[lo - 1]:
        lo -= 1
    hi = peak
    while hi + 1 < len(hot) and hot[hi + 1]:
        hi += 1
    cold = np.concatenate([rates[:lo], rates[hi + 1:]])
    return {"kind": "flash-crowd",
            "base_rate": float(cold.mean()) if len(cold) else base,
            "spike_rate": float(rates[lo:hi + 1].mean()),
            "spike_at": float(starts[lo]),
            "spike_len": float(w * (hi - lo + 1))}


# ---------------------------------------------------------------------------
# model scoring — which kind best explains the trace
# ---------------------------------------------------------------------------

#: free parameters per kind (the BIC complexity penalty); MMPP adds one
#: per transition of its fitted label path — the segmentation is itself
#: estimated from the scored counts, so each changepoint is a parameter
#: (otherwise two-means clustering of plain Poisson noise always "wins")
_N_PARAMS = {"poisson": 1, "mmpp": 4, "diurnal": 3, "flash-crowd": 4}


def _window_rates_for(kind: str, fit: dict, starts, w, labels):
    """Predicted per-window rate under a fitted kind."""
    mid = starts + 0.5 * w
    if kind == "poisson":
        return np.full(len(starts), fit["rate"])
    if kind == "mmpp":
        return np.where(labels, fit["rate_on"], fit["rate_off"])
    from repro.serving.traffic.generators import make_arrival_process
    proc = make_arrival_process(**fit)
    return np.asarray([proc.rate_at(t) for t in mid])


def _loglik(counts: np.ndarray, rates: np.ndarray, w: float) -> float:
    """Windowed Poisson log-likelihood sum(k ln(r w) - r w) (the k!
    term is model-independent and cancels in comparisons)."""
    mu = np.maximum(rates * w, 1e-12)
    return float((counts * np.log(mu) - mu).sum())


def fit_report(offsets, window: float = None) -> dict:
    """Fit every arrival kind and score which best explains the trace.

    Scores are BIC-penalized windowed Poisson log-likelihoods
    (``ll - 0.5 * n_params * ln(n_windows)``); ``best`` names the
    highest-scoring kind and ``fits[best]`` rebuilds it through
    ``make_arrival_process``.
    """
    offsets = _check(offsets)
    rates, starts, w = _windowed(offsets, window)
    counts = rates * w
    labels, _, _ = _two_means(rates)
    fits = {"poisson": fit_poisson(offsets),
            "mmpp": fit_mmpp(offsets, window),
            "diurnal": fit_diurnal(offsets),
            "flash-crowd": fit_flash_crowd(offsets, window)}
    scores = {}
    n_trans = int(np.count_nonzero(labels[1:] != labels[:-1]))
    for kind, fit in fits.items():
        k = _N_PARAMS[kind] + (n_trans if kind == "mmpp" else 0)
        pred = _window_rates_for(kind, fit, starts, w, labels)
        scores[kind] = (_loglik(counts, pred, w)
                        - 0.5 * k * math.log(len(starts)))
    best = max(scores, key=scores.get)
    return {"n_arrivals": int(len(offsets)),
            "span": float(offsets[-1] - offsets[0]),
            "window": float(w), "best": best,
            "fits": fits, "scores": {k: round(v, 3)
                                     for k, v in scores.items()}}


def fit_arrival_process(offsets, window: float = None):
    """The best-scoring fitted :class:`ArrivalProcess` for a trace."""
    from repro.serving.traffic.generators import make_arrival_process
    report = fit_report(offsets, window)
    return make_arrival_process(**report["fits"][report["best"]])
