"""Per-request span tracing + scheduler decision audit log.

The :class:`Tracer` is the one observability hook threaded through the
serving stack (``EngineCore``, the executors via window close events,
``AdmissionController`` via decision details, ``Service``/``FrontDoor``
via intake audit rows).  It is **passive**: every hook only appends to
Python lists using timestamps the engine already computed, so a traced
run schedules bit-for-bit identically to an untraced one on the virtual
clock — the engine never charges host time for tracing and the tracer
never reads the clock itself.

It is also cheap enough to leave on in benchmarks (the ``obs`` figure
measures the bound): hot-path hooks only append scalars to per-request
accumulator lists; :class:`RequestTrace` objects (typed spans, sorted)
are materialised lazily, on first access to ``traces``/``trace()`` —
after the run, off the timed path.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry

__all__ = ["Span", "RequestTrace", "Tracer", "TRACE_KEYS"]

# allowed keys of the ``ServeSpec.trace`` dict
TRACE_KEYS = ("enabled", "spans", "audit", "metrics", "export", "chrome")

# chronological tie-break priority for spans sharing a timestamp
_SPAN_ORDER = {"queued": 0, "admitted": 1, "batched": 2, "dispatch": 3,
               "device-window": 4, "stage-exit": 5, "retire": 6,
               "expire": 6}

# per-request accumulator slots (a list, not a dict — hot path)
_T_ADMIT, _T_FIRST, _DEV, _BATCHES, _WINDOWS, _EXITS, _DECISION, _DETAIL \
    = range(8)


def _new_entry(t_admit: float) -> list:
    return [t_admit, None, 0.0, [], [], [], None, None]


class Span:
    """One typed interval (or instant, ``t0 == t1``) of a request's life."""

    __slots__ = ("name", "t0", "t1", "attrs")

    def __init__(self, name: str, t0: float, t1: float,
                 attrs: Optional[dict] = None):
        self.name = name
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.attrs = attrs or {}

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = {"name": self.name, "t0": self.t0, "t1": self.t1}
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Span({self.name!r}, {self.t0:.4f}..{self.t1:.4f})"


class RequestTrace:
    """Everything observed about one request through the Fig. 2 loop."""

    __slots__ = ("tid", "request_id", "tenant", "slo", "model", "decision",
                 "depth_cap", "latency", "depth", "missed", "rejected",
                 "queue_wait", "host_time", "device_time", "spans")

    def __init__(self, tid: int, spans: List[Span], **meta: Any):
        self.tid = tid
        self.spans = spans
        for k in ("request_id", "tenant", "slo", "model", "decision",
                  "depth_cap", "latency", "depth", "missed", "rejected",
                  "queue_wait", "host_time", "device_time"):
            setattr(self, k, meta.get(k))

    def span_names(self) -> List[str]:
        return [s.name for s in self.spans]

    def to_dict(self) -> dict:
        d: Dict[str, Any] = {"tid": self.tid}
        for k in ("request_id", "tenant", "slo", "model", "decision",
                  "depth_cap", "latency", "depth", "missed", "rejected",
                  "queue_wait", "host_time", "device_time"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        d["spans"] = [s.to_dict() for s in self.spans]
        return d


class Tracer:
    """Low-overhead observability recorder (see module docstring).

    Built by ``Service`` from the ``ServeSpec.trace`` dict; reachable on a
    finished service as ``service.obs``.  ``spans``/``audit``/``metrics``
    toggles gate the three recording planes independently; ``export`` /
    ``chrome`` are file paths written when the run finishes.
    """

    def __init__(self, *, spans: bool = True, audit: bool = True,
                 metrics: bool = True, export: Optional[str] = None,
                 chrome: Optional[str] = None):
        self.spans_on = bool(spans)
        self.audit_on = bool(audit)
        self.registry = MetricsRegistry.serving_default() if metrics else None
        self.export_path = export
        self.chrome_path = chrome
        self.time_model = None          # set by Service._build when known
        # live per-request accumulators, keyed by task tid (see slot
        # constants above)
        self._req: Dict[int, list] = {}
        self._open: deque = deque()     # in-flight device windows
        self.windows: List[dict] = []   # closed device windows
        self.audit_log: List[dict] = []
        # retired requests: raw (entry, outcome) tuples, materialised into
        # RequestTrace objects lazily by the ``traces`` property
        self._done: Dict[int, tuple] = {}
        self._traces: Dict[int, RequestTrace] = {}
        self._by_rid: Dict[str, int] = {}
        self._buckets_cache: Dict[int, int] = {}
        # cached instrument refs for the hot path
        reg = self.registry
        self._h_latency = reg.histogram("latency") if reg else None
        self._h_qwait = reg.histogram("queue_wait") if reg else None
        self._h_qdepth = reg.histogram("queue_depth_sampled") if reg else None
        self._h_occ = reg.histogram("batch_occupancy") if reg else None
        self._h_depth = reg.histogram("depth_served") if reg else None
        self._g_qdepth = reg.gauge("queue_depth") if reg else None
        self._c_admitted = reg.counter("requests_admitted") if reg else None
        self._c_dispatch = reg.counter("dispatches") if reg else None
        self._c_windows = reg.counter("windows_closed") if reg else None

    @classmethod
    def from_config(cls, conf: dict) -> "Tracer":
        return cls(spans=conf.get("spans", True),
                   audit=conf.get("audit", True),
                   metrics=conf.get("metrics", True),
                   export=conf.get("export"),
                   chrome=conf.get("chrome"))

    # -- engine hooks (called with engine-computed timestamps only) --------

    def on_admit(self, task, now: float, n_active: int) -> None:
        """Task popped from the source; spans start, queue depth sampled."""
        self._req[task.tid] = _new_entry(now)
        if self._g_qdepth is not None:
            self._g_qdepth.value = float(n_active)
            self._h_qdepth.observe(n_active)

    def on_admission(self, task, now: float, dec) -> None:
        """Admission decided (``dec is None`` means no controller)."""
        e = self._req.get(task.tid)
        reg = self.registry
        if dec is None or (dec.admitted and dec.depth_cap is None):
            if e is not None:
                e[_DECISION] = "admitted" if dec is None else dec.reason
            if self._c_admitted is not None:
                self._c_admitted.value += 1
            return
        if e is not None:
            e[_DECISION] = dec.reason
            e[_DETAIL] = dec.detail
        if reg is not None:
            if not dec.admitted:
                reg.counter("requests_rejected").inc()
            else:
                self._c_admitted.value += 1
                reg.counter("requests_capped").inc()
        if self.audit_on:
            self.audit(dec.reason, now, dec.detail, tid=task.tid,
                       model=getattr(task, "model", None))

    def on_dispatch(self, stage: int, batch, now: float,
                    wcet: float) -> None:
        """Batch handed to the executor; opens a device-window record."""
        n = len(batch)
        bucket = self._bucket(n)
        tids = tuple(t.tid for t in batch)
        self._open.append({"stage": stage, "t0": now, "n": n,
                           "bucket": bucket, "wcet": wcet, "tids": tids})
        spans = self.spans_on
        for t in batch:
            e = self._req.get(t.tid)
            if e is not None:
                if e[_T_FIRST] is None:
                    e[_T_FIRST] = now
                if spans:
                    e[_BATCHES].append((now, stage, n, bucket, wcet))
        if self._c_dispatch is not None:
            self._c_dispatch.value += 1
            self._h_occ.observe(n)

    def on_window_close(self, stage: int, batch, t1: float) -> None:
        """Executor completed a window; charge device time to every rider."""
        tids = tuple(t.tid for t in batch)
        w = None
        for cand in self._open:
            if cand["stage"] == stage and cand["tids"] == tids:
                w = cand
                break
        if w is None:                       # unmatched (foreign executor)
            w = {"stage": stage, "t0": t1, "n": len(batch),
                 "bucket": self._bucket(len(batch)), "wcet": None,
                 "tids": tids}
        else:
            self._open.remove(w)
        w["t1"] = t1
        self.windows.append(w)
        dur = t1 - w["t0"]
        t0 = w["t0"]
        spans = self.spans_on
        for t in batch:
            e = self._req.get(t.tid)
            if e is not None:
                e[_DEV] += dur
                if spans:
                    e[_WINDOWS].append((stage, t0, t1, w["n"]))
        if self._c_windows is not None:
            self._c_windows.value += 1

    def on_stage_exit(self, task, now: float) -> None:
        if not self.spans_on:
            return
        e = self._req.get(task.tid)
        if e is not None:
            conf = task.confidences[-1] if task.confidences else None
            e[_EXITS].append((now, task.executed, conf))

    def on_topoff(self, stage: int, presel_tids, final_tids,
                  now: float) -> None:
        """Preselected batch was revalidated into a different seating."""
        if self.registry is not None:
            self.registry.counter("topoffs").inc()
        if self.audit_on:
            added = [t for t in final_tids if t not in presel_tids]
            removed = [t for t in presel_tids if t not in final_tids]
            self.audit("batch-top-off", now,
                       {"stage": stage, "presel_n": len(presel_tids),
                        "final_n": len(final_tids), "added": added,
                        "removed": removed})

    def on_pullin(self, task, now: float, cap: int) -> None:
        """Live cancel pulled the task's depth down to ``cap``."""
        if self.registry is not None:
            self.registry.counter("pullins").inc()
        if self.audit_on:
            self.audit("cancel-pullin", now,
                       {"executed": task.executed, "cap": cap,
                        "mandatory": task.mandatory}, tid=task.tid)

    # -- audit log ---------------------------------------------------------

    def audit(self, rule: str, t: float, detail: Optional[dict] = None,
              *, tid: Optional[int] = None,
              request_id: Optional[str] = None,
              tenant: Optional[str] = None, slo: Optional[str] = None,
              model: Optional[str] = None) -> None:
        row: Dict[str, Any] = {"t": float(t), "rule": rule,
                               "detail": detail or {}}
        if tid is not None:
            row["tid"] = tid
        if request_id is not None:
            row["request_id"] = request_id
        if tenant is not None:
            row["tenant"] = tenant
        if slo is not None:
            row["slo"] = slo
        if model is not None:
            row["model"] = model
        self.audit_log.append(row)

    def ingest_pending(self, rows: List[dict]) -> None:
        """Drain intake-side audit rows buffered before/outside the engine.

        Each row is an audit dict plus a ``kind`` key mapping it onto the
        registry counters (``reject`` -> requests_rejected, ``shed`` ->
        requests_capped, matching the ``MetricsStreamer`` split)."""
        while rows:
            row = dict(rows.pop(0))
            kind = row.pop("kind", None)
            if self.registry is not None:
                if kind == "reject":
                    self.registry.counter("requests_rejected").inc()
                elif kind == "shed":
                    self.registry.counter("requests_capped").inc()
            if self.audit_on:
                self.audit_log.append(row)

    # -- retire ------------------------------------------------------------

    def finalize(self, task, now: float, rejected: bool, t0: float,
                 rec: dict) -> None:
        """Close out a request: inject time splits into its per-request
        row (emit-only-when-set) and stash the raw accumulators for lazy
        RequestTrace materialisation."""
        e = self._req.pop(task.tid, None)
        latency = rec.get("latency", now - t0)
        if e is None:                     # tracer attached mid-flight
            e = _new_entry(t0)
        t_first = e[_T_FIRST]
        queue_wait = (t_first - t0) if t_first is not None else latency
        device_time = e[_DEV]
        host_time = latency - queue_wait - device_time
        if host_time < 0.0:
            host_time = 0.0
        decision = e[_DECISION]
        if decision is None:
            decision = "rejected" if rejected else "admitted"
        rec["queue_wait"] = queue_wait
        rec["host_time"] = host_time
        rec["device_time"] = device_time
        rec["decision"] = decision
        if self.registry is not None:
            self._h_latency.observe(latency)
            self._h_qwait.observe(queue_wait)
            if not rejected:
                self._h_depth.observe(rec.get("depth", task.executed))
            if rec.get("missed"):
                self.registry.counter("requests_missed").inc()
        if not self.spans_on:
            return
        meta = (rec.get("request_id"), rec.get("tenant"), rec.get("slo"),
                rec.get("model"), latency, rec.get("depth", task.executed),
                bool(rec.get("missed")))
        self._done[task.tid] = (e, t0, now, bool(rejected), decision,
                                task.depth_cap, queue_wait, host_time,
                                device_time, meta)
        rid = meta[0]
        if rid is not None:
            self._by_rid[str(rid)] = task.tid

    def _materialize(self, tid: int) -> RequestTrace:
        (e, t0, now, rejected, decision, depth_cap, queue_wait, host_time,
         device_time, meta) = self._done.pop(tid)
        rid, tenant, slo, model, latency, depth, missed = meta
        t_first = e[_T_FIRST]
        spans = [Span("queued", t0, t_first if t_first is not None else now)]
        if not rejected:
            adm_attrs: Dict[str, Any] = {"decision": decision}
            if e[_DETAIL]:
                adm_attrs["detail"] = e[_DETAIL]
            if depth_cap is not None:
                adm_attrs["depth_cap"] = depth_cap
            spans.append(Span("admitted", e[_T_ADMIT], e[_T_ADMIT],
                              adm_attrs))
        for (t, stage, n, bucket, wcet) in e[_BATCHES]:
            spans.append(Span("batched", t, t,
                              {"stage": stage, "n": n, "bucket": bucket}))
            spans.append(Span("dispatch", t, t,
                              {"stage": stage, "wcet": wcet}))
        for (stage, w0, w1, n) in e[_WINDOWS]:
            spans.append(Span("device-window", w0, w1,
                              {"stage": stage, "n": n}))
        for (t, stage, conf) in e[_EXITS]:
            attrs: Dict[str, Any] = {"stage": stage}
            if conf is not None:
                attrs["conf"] = float(conf)
            spans.append(Span("stage-exit", t, t, attrs))
        end = "expire" if (missed and not rejected) else "retire"
        end_attrs: Dict[str, Any] = {"latency": latency, "depth": depth}
        if rejected:
            end_attrs["rejected"] = True
        spans.append(Span(end, now, now, end_attrs))
        spans.sort(key=lambda s: (s.t0, _SPAN_ORDER.get(s.name, 9)))
        tr = RequestTrace(tid, spans, request_id=rid, tenant=tenant,
                          slo=slo, model=model, decision=decision,
                          depth_cap=depth_cap, latency=latency, depth=depth,
                          missed=missed, rejected=rejected,
                          queue_wait=queue_wait, host_time=host_time,
                          device_time=device_time)
        self._traces[tid] = tr
        return tr

    # -- lookup / export ---------------------------------------------------

    @property
    def traces(self) -> Dict[int, RequestTrace]:
        """Finished requests as RequestTrace objects, keyed by tid
        (materialised on first access — off the hot path)."""
        while self._done:
            self._materialize(next(iter(self._done)))
        return self._traces

    def trace(self, key) -> Optional[RequestTrace]:
        """Look up a finished request by tid (int) or request_id (str)."""
        if isinstance(key, str) and not key.isdigit():
            tid = self._by_rid.get(key)
        else:
            tid = int(key)
        if tid is None:
            return None
        if tid in self._done:
            return self._materialize(tid)
        return self._traces.get(tid)

    def audit_for(self, key) -> List[dict]:
        """Audit rows for one request, matched by tid or request_id."""
        tr = self.trace(key)
        rows = []
        for row in self.audit_log:
            if tr is not None and row.get("tid") == tr.tid:
                rows.append(row)
            elif isinstance(key, str) and row.get("request_id") == key:
                rows.append(row)
        return rows

    def _bucket(self, n: int) -> int:
        b = self._buckets_cache.get(n)
        if b is not None:
            return b
        tm = self.time_model
        buckets = getattr(tm, "buckets", None) if tm is not None else None
        b = n
        if buckets:
            b = int(buckets[-1])
            for cand in buckets:
                if cand >= n:
                    b = int(cand)
                    break
        self._buckets_cache[n] = b
        return b

    def export_jsonl(self, path: str) -> str:
        from .export import write_jsonl
        return write_jsonl(self, path)

    def chrome_trace(self) -> dict:
        from .export import chrome_trace
        return chrome_trace(self)

    def close(self) -> None:
        """Write any configured export files (called when a run finishes)."""
        if self.export_path:
            self.export_jsonl(self.export_path)
        if self.chrome_path:
            import json
            with open(self.chrome_path, "w") as fh:
                json.dump(self.chrome_trace(), fh)
