"""Exports for the observability layer: JSONL and Chrome trace_event.

``write_jsonl``/``load_obs`` round-trip everything a :class:`Tracer`
recorded (traces, audit rows, device windows, metrics snapshot) through
one self-describing JSONL file — the format ``tools/planectl.py trace|
why|top`` reads, so post-hoc debugging needs no live process.

``chrome_trace`` renders the same run in Chrome ``trace_event`` format
(the JSON-object flavour: ``{"traceEvents": [...]}``) so it opens in
Perfetto / ``chrome://tracing``: device windows on overlap-free lanes
under one "device" process, each request's life on its own row under a
"requests" process, audit rows as instant events.  Timestamps are
microseconds as the format requires.
"""
from __future__ import annotations

import json
from typing import IO, List, Tuple

__all__ = ["write_jsonl", "load_obs", "chrome_trace",
           "validate_chrome_trace"]

OBS_VERSION = 1

_US = 1e6   # trace_event timestamps are microseconds


def write_jsonl(tracer, path: str) -> str:
    """Serialise ``tracer`` to ``path`` (one JSON object per line)."""
    with open(path, "w") as fh:
        _dump(tracer, fh)
    return path


def _dump(tracer, fh: IO[str]) -> None:
    head = {"type": "header", "obs_version": OBS_VERSION,
            "n_traces": len(tracer.traces),
            "n_audit": len(tracer.audit_log),
            "n_windows": len(tracer.windows)}
    fh.write(json.dumps(head) + "\n")
    for tid in sorted(tracer.traces):
        row = tracer.traces[tid].to_dict()
        row["type"] = "trace"
        fh.write(json.dumps(row) + "\n")
    for row in tracer.audit_log:
        fh.write(json.dumps({"type": "audit", **row}) + "\n")
    for w in tracer.windows:
        fh.write(json.dumps({"type": "window", "stage": w["stage"],
                             "t0": w["t0"], "t1": w["t1"], "n": w["n"],
                             "bucket": w["bucket"],
                             "tids": list(w["tids"])}) + "\n")
    if tracer.registry is not None:
        fh.write(json.dumps({"type": "metrics",
                             "metrics": tracer.registry.to_dict()}) + "\n")


def load_obs(path: str) -> dict:
    """Parse a JSONL export back into ``{header, traces, audit, windows,
    metrics}`` — traces keyed by tid, with a ``by_request_id`` index."""
    out = {"header": None, "traces": {}, "audit": [], "windows": [],
           "metrics": None, "by_request_id": {}}
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            kind = row.pop("type", None)
            if kind == "header":
                out["header"] = row
            elif kind == "trace":
                out["traces"][row["tid"]] = row
                rid = row.get("request_id")
                if rid is not None:
                    out["by_request_id"][rid] = row["tid"]
            elif kind == "audit":
                out["audit"].append(row)
            elif kind == "window":
                out["windows"].append(row)
            elif kind == "metrics":
                out["metrics"] = row["metrics"]
    return out


def _assign_lanes(windows: List[dict]) -> List[Tuple[int, dict]]:
    """Greedy interval-graph colouring: overlapping windows get distinct
    lanes so Perfetto draws them side by side instead of merged."""
    lanes_end: List[float] = []
    placed = []
    for w in sorted(windows, key=lambda w: (w["t0"], w["t1"])):
        lane = None
        for i, end in enumerate(lanes_end):
            if w["t0"] >= end - 1e-12:
                lane = i
                break
        if lane is None:
            lane = len(lanes_end)
            lanes_end.append(w["t1"])
        else:
            lanes_end[lane] = w["t1"]
        placed.append((lane, w))
    return placed


def chrome_trace(tracer) -> dict:
    """Render the tracer's run as a Chrome ``trace_event`` document."""
    ev: List[dict] = []
    ev.append({"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
               "args": {"name": "device"}})
    ev.append({"ph": "M", "name": "process_name", "pid": 2, "tid": 0,
               "args": {"name": "requests"}})
    placed = _assign_lanes(tracer.windows)
    n_lanes = 1 + max((lane for lane, _ in placed), default=-1)
    for lane in range(n_lanes):
        ev.append({"ph": "M", "name": "thread_name", "pid": 1, "tid": lane,
                   "args": {"name": f"window lane {lane}"}})
    for lane, w in placed:
        ev.append({"ph": "X", "name": f"stage {w['stage']} x{w['n']}",
                   "cat": "device-window", "pid": 1, "tid": lane,
                   "ts": w["t0"] * _US,
                   "dur": max(w["t1"] - w["t0"], 0.0) * _US,
                   "args": {"stage": w["stage"], "n": w["n"],
                            "bucket": w["bucket"]}})
    for tid in sorted(tracer.traces):
        tr = tracer.traces[tid]
        label = tr.request_id or f"tid {tid}"
        ev.append({"ph": "M", "name": "thread_name", "pid": 2, "tid": tid,
                   "args": {"name": str(label)}})
        for s in tr.spans:
            if s.t1 > s.t0:
                ev.append({"ph": "X", "name": s.name, "cat": "request",
                           "pid": 2, "tid": tid, "ts": s.t0 * _US,
                           "dur": (s.t1 - s.t0) * _US,
                           "args": dict(s.attrs)})
            else:
                ev.append({"ph": "i", "name": s.name, "cat": "request",
                           "pid": 2, "tid": tid, "ts": s.t0 * _US,
                           "s": "t", "args": dict(s.attrs)})
    for row in tracer.audit_log:
        ev.append({"ph": "i", "name": row["rule"], "cat": "audit",
                   "pid": 2, "tid": row.get("tid", 0),
                   "ts": row["t"] * _US, "s": "p",
                   "args": dict(row.get("detail", {}))})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def validate_chrome_trace(doc: dict) -> List[str]:
    """Check ``doc`` against the trace_event schema essentials; returns a
    list of problems (empty == valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    ev = doc.get("traceEvents")
    if not isinstance(ev, list):
        return ["missing traceEvents array"]
    for i, e in enumerate(ev):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "M", "B", "E", "C"):
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if "name" not in e:
            problems.append(f"{where}: missing name")
        if "pid" not in e or "tid" not in e:
            problems.append(f"{where}: missing pid/tid")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: bad dur {dur!r}")
        if ph == "i" and e.get("s") not in ("t", "p", "g", None):
            problems.append(f"{where}: bad scope {e.get('s')!r}")
    return problems
