"""Metrics registry: counters, gauges, histograms with explicit buckets.

One registry instance lives on a :class:`~repro.serving.obs.Tracer` and is
the single accumulation point for serving statistics — the
``MetricsStreamer`` reads its counters instead of re-deriving them from
scattered engine fields, and the JSONL export serialises ``to_dict()``
verbatim.  Everything here is plain Python arithmetic on ``__slots__``
objects so the hot-path cost of an ``observe()`` is one bisect plus three
adds.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "QUEUE_DEPTH_BUCKETS", "BATCH_OCCUPANCY_BUCKETS",
    "DEPTH_BUCKETS",
]

# explicit bucket edges (upper bounds, seconds / counts).  A value lands in
# the first bucket whose edge is >= value; values past the last edge go to
# the overflow bucket.
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0)
QUEUE_DEPTH_BUCKETS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
BATCH_OCCUPANCY_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32)
DEPTH_BUCKETS: Tuple[float, ...] = (0, 1, 2, 3, 4, 6, 8)


class Counter:
    """Monotonic count (requests admitted, rejected, windows closed...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, v: int = 1) -> None:
        self.value += v

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-observed level (queue depth, live cache entries...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram; ``counts[-1]`` is the overflow bucket."""

    __slots__ = ("name", "buckets", "counts", "n", "total")

    def __init__(self, name: str, buckets: Sequence[float]):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name!r}: buckets must be a "
                             f"non-empty sorted sequence, got {buckets!r}")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.n = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.buckets, v)] += 1
        self.n += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def to_dict(self) -> dict:
        return {"type": "histogram", "buckets": list(self.buckets),
                "counts": list(self.counts), "n": self.n,
                "sum": self.total}


class MetricsRegistry:
    """Named metric instruments, created on first use.

    ``serving_default()`` pre-creates the standard serving instruments so
    hot paths can cache direct references instead of doing dict lookups.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def counter(self, name: str) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name)
        return m

    def gauge(self, name: str) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name)
        return m

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(
                name, buckets if buckets is not None else LATENCY_BUCKETS)
        return m

    def names(self):
        return sorted(self._metrics)

    def to_dict(self) -> dict:
        return {name: m.to_dict()
                for name, m in sorted(self._metrics.items())}

    @classmethod
    def serving_default(cls) -> "MetricsRegistry":
        reg = cls()
        # "capped" counts every degraded-not-dropped outcome (admission
        # depth caps + intake shed-optional), mirroring MetricsStreamer
        for c in ("requests_admitted", "requests_rejected",
                  "requests_capped", "requests_missed",
                  "windows_closed", "dispatches", "topoffs", "pullins"):
            reg.counter(c)
        reg.gauge("queue_depth")
        reg.histogram("latency", LATENCY_BUCKETS)
        reg.histogram("queue_wait", LATENCY_BUCKETS)
        reg.histogram("queue_depth_sampled", QUEUE_DEPTH_BUCKETS)
        reg.histogram("batch_occupancy", BATCH_OCCUPANCY_BUCKETS)
        reg.histogram("depth_served", DEPTH_BUCKETS)
        return reg
