"""repro.serving.obs — request tracing, audit log, metrics, exports.

The observability layer for the serving stack: a passive
:class:`Tracer` threaded through the Fig. 2 loop records one
:class:`RequestTrace` per request (typed spans with queue-wait / host /
device time splits), a scheduler decision audit log (which rule fired
and the numbers behind it), and a :class:`MetricsRegistry`, exporting to
JSONL and Chrome ``trace_event`` JSON.  Enable via ``ServeSpec(trace=
{"enabled": True})``; see docs/observability.md.

```python
import numpy as np
from repro.serving import ServeSpec, Service

rng = np.random.default_rng(0)
conf = np.sort(rng.uniform(0.3, 1.0, (64, 3)), axis=1)
correct = rng.uniform(size=(64, 3)) < conf

spec = ServeSpec(policy="rtdeepiot", policy_args={"delta": 0.3},
                 batching={"stage_times": [0.004, 0.007, 0.010],
                           "buckets": [1, 2, 4], "marginal": 0.15},
                 source_args={"n_clients": 4, "d_lo": 0.02, "d_hi": 0.25,
                              "n_requests": 12},
                 trace={"enabled": True})
svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
svc.run()
tr = next(iter(svc.obs.traces.values()))
assert tr.span_names()[0] == "queued" and tr.span_names()[-1] in (
    "retire", "expire")
assert svc.obs.registry.histogram("latency").n == 12
```
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      LATENCY_BUCKETS, QUEUE_DEPTH_BUCKETS,
                      BATCH_OCCUPANCY_BUCKETS, DEPTH_BUCKETS)
from .tracer import Span, RequestTrace, Tracer, TRACE_KEYS
from .export import (write_jsonl, load_obs, chrome_trace,
                     validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "QUEUE_DEPTH_BUCKETS", "BATCH_OCCUPANCY_BUCKETS",
    "DEPTH_BUCKETS",
    "Span", "RequestTrace", "Tracer", "TRACE_KEYS",
    "write_jsonl", "load_obs", "chrome_trace", "validate_chrome_trace",
]
