"""Component registry: string keys -> serving-component factories.

``ServeSpec`` (repro.serving.service) names its policy, executor, clock
and source by *string key*; this module is where those keys resolve.  The
four registries are entry-point style — anything (an example, a benchmark,
a launcher, a test, a downstream package) can plug a new scheduler or
executor in without touching the core modules:

    from repro.serving.registry import register_policy

    @register_policy("my-scheduler")
    def _make(args, ctx):
        return MyScheduler(**args)

    spec = ServeSpec(policy="my-scheduler", policy_args={...})

Factory contract
----------------
``factory(args: dict, ctx: BuildContext) -> component``

* ``args`` — the spec's JSON-able ``*_args`` dict for this component.
* ``ctx``  — the build context: the full ``spec``, the caller-supplied
  ``resources`` (non-serializable runtime objects: oracle tables, params,
  stage fns, workloads, request streams), and the pieces built so far
  (``time_model``/``max_batch`` always; ``policy``/``clock``/``executor``
  for later stages; ``task_factory``/``stream`` for sources).

Built-in keys (registered below; device executors import jax lazily so
this module stays numpy-only):

========  =================================================================
policy    ``rtdeepiot`` (predictor/prior_curve/delta/oracle via args),
          ``rtdeepiot-weighted`` (same + ``Task.weight``-aware dispatch
          and batch seating), ``edf``, ``lcf``, ``rr``
executor  ``oracle`` (conf tables + BatchTimeModel),
          ``device-single`` (per-stage jitted fns, singleton dispatch),
          ``device-batched`` (bucketed BatchedStageFns)
clock     ``virtual`` (discrete event), ``wall`` (real time)
source    ``closed-loop`` (§IV K-client workload), ``stream``
          ((offset, Request) list), ``live`` (``Service.submit`` queue)
========  =================================================================

Keys registered from *outside* this module (the extension-point proof —
see ``docs/extending.md`` for the worked tutorial):

* ``repro.serving.traffic`` — sources ``traffic`` (seeded open-loop
  arrival generators x per-class request mixes) and ``replay`` (recorded
  JSONL traces re-injected bit-for-bit);
* ``repro.launch.serve`` — executors ``device-sharded`` (the batched
  engine pjit-sharded over a ``(dp, tp)`` mesh, 1x1 fallback on
  single-device hosts) and ``device-kernel`` (Pallas stage bodies: fused
  exit-confidence epilogue, ragged decode batching over per-request KV
  caches, length-bucketed WCETs) plus the decode launcher's
  ``conf-target`` / ``decode`` / ``token-loop``.

Example — a custom policy, end to end:

```python
from repro.core.schedulers import EDF
from repro.serving import ServeSpec, Service
from repro.serving.registry import register_policy

@register_policy("my-edf")
def _make(args, ctx):
    return EDF()

import numpy as np
conf = np.full((50, 3), 0.8); correct = conf > np.random.default_rng(0).random((50, 3))
spec = ServeSpec(policy="my-edf",
                 batching={"mode": "none", "stage_times": [0.01] * 3},
                 source_args={"n_clients": 4, "d_lo": 0.02, "d_hi": 0.2,
                              "n_requests": 40})
res = Service.from_spec(spec, conf_table=conf, correct_table=correct).run()
assert res.n_requests == 40
```
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

KINDS = ("policy", "executor", "clock", "source")

_REGISTRY: dict = {kind: {} for kind in KINDS}


@dataclasses.dataclass
class BuildContext:
    """Everything a component factory may need besides its own args."""
    spec: Any                           # the ServeSpec being built
    resources: dict                     # caller-supplied runtime objects
    time_model: Any = None              # BatchTimeModel (set before factories)
    max_batch: Optional[int] = None
    policy: Any = None                  # set before executor/source factories
    clock: Any = None                   # set before executor/source factories
    executor: Any = None                # set before source factories
    task_factory: Optional[Callable] = None   # (Request, now) -> Task
    stream: Any = None                  # materialized (offset, Request) list


def register(kind: str, name: str, factory: Callable = None):
    """Register ``factory`` under ``name``; usable as a decorator."""
    if kind not in KINDS:
        raise KeyError(f"unknown registry kind {kind!r}; kinds: {KINDS}")

    def deco(fn):
        _REGISTRY[kind][str(name)] = fn
        return fn
    return deco(factory) if factory is not None else deco


def register_policy(name, factory=None):
    return register("policy", name, factory)


def register_executor(name, factory=None):
    return register("executor", name, factory)


def register_clock(name, factory=None):
    return register("clock", name, factory)


def register_source(name, factory=None):
    return register("source", name, factory)


def resolve(kind: str, name: str) -> Callable:
    """The factory registered for ``name`` (KeyError lists what exists)."""
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        raise KeyError(f"no {kind} registered under {name!r}; "
                       f"available: {available(kind)}") from None


def available(kind: str) -> list:
    return sorted(_REGISTRY[kind])


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------

def _predictor_from(args: dict, ctx: BuildContext):
    from repro.core.utility import make_predictor
    name = args.get("predictor", "exp")
    if name == "oracle":
        return make_predictor("oracle",
                              oracle_table=ctx.resources["conf_table"])
    prior = args.get("prior_curve")
    if prior is None:
        prior = ctx.resources["conf_table"].mean(0)
    return make_predictor(name, prior_curve=prior)


@register_policy("rtdeepiot")
def _make_rtdeepiot(args: dict, ctx: BuildContext):
    """The paper's scheduler.  args: ``predictor`` (exp/max/lin/oracle),
    ``prior_curve`` (list; default: conf_table.mean(0)), ``delta``."""
    from repro.core.schedulers import RTDeepIoT
    return RTDeepIoT(_predictor_from(args, ctx),
                     delta=float(args.get("delta", 0.1)))


@register_policy("rtdeepiot-weighted")
def _make_rtdeepiot_weighted(args: dict, ctx: BuildContext):
    """SLO-weighted RTDeepIoT: the FPTAS objective weighted by
    ``Task.weight`` (as the base planner already is) *plus* weight-aware
    dispatch tie-breaks and batch seating — gold-class requests win
    contended utility under overload.  Same args as ``rtdeepiot``."""
    from repro.core.schedulers import WeightedRTDeepIoT
    return WeightedRTDeepIoT(_predictor_from(args, ctx),
                             delta=float(args.get("delta", 0.1)))


@register_policy("edf")
def _make_edf(args, ctx):
    from repro.core.schedulers import EDF
    return EDF()


@register_policy("lcf")
def _make_lcf(args, ctx):
    from repro.core.schedulers import LCF
    return LCF()


@register_policy("rr")
def _make_rr(args, ctx):
    from repro.core.schedulers import RR
    return RR()


# ---------------------------------------------------------------------------
# built-in clocks
# ---------------------------------------------------------------------------

@register_clock("virtual")
def _make_virtual(args, ctx):
    from repro.serving.runtime.clock import VirtualClock
    return VirtualClock(charge_overhead=ctx.spec.charge_overhead)


@register_clock("wall")
def _make_wall(args, ctx):
    from repro.serving.runtime.clock import WallClock
    return WallClock(max_sleep=float(args.get("max_sleep", 0.005)))


# ---------------------------------------------------------------------------
# built-in executors
# ---------------------------------------------------------------------------

@register_executor("oracle")
def _make_oracle(args, ctx):
    from repro.serving.runtime.executor import OracleExecutor
    # pipeline_depth >= 3 enqueues depth-1 virtual device windows, same
    # scaling as the device executors (one running + the rest queued)
    return OracleExecutor(
        ctx.time_model, ctx.resources["conf_table"],
        max_inflight=max(1, int(ctx.spec.pipeline_depth) - 1))


@register_executor("device-single")
def _make_device_single(args, ctx):
    """Per-stage jitted fns, singleton dispatch (the legacy ServingEngine
    device).  resources: cfg, params, optionally stage_fns (fn list)."""
    import jax

    from repro.serving.engine import make_stage_fns
    from repro.serving.runtime.device import DeviceExecutor, SingleStageFns
    cfg, params = ctx.resources["cfg"], ctx.resources["params"]
    fns = ctx.resources.get("stage_fns") or make_stage_fns(cfg)
    ex = DeviceExecutor(SingleStageFns(fns), params, ctx.time_model)

    def warmup(sample_input):
        h = sample_input
        for fn in fns:
            out = fn(params, h)
            jax.block_until_ready(out[0])
            h = out[0]
    ex.warmup = warmup
    return ex


@register_executor("device-batched")
def _make_device_batched(args, ctx):
    """Bucketed batched stage fns (the legacy BatchedServingEngine device).
    resources: cfg, params, optionally stage_fns (BatchedStageFns)."""
    from repro.serving.batch.stage_fns import BatchedStageFns
    from repro.serving.runtime.device import DeviceExecutor
    cfg, params = ctx.resources["cfg"], ctx.resources["params"]
    sfns = ctx.resources.get("stage_fns") or \
        BatchedStageFns(cfg, ctx.time_model.buckets)
    ex = DeviceExecutor(sfns, params, ctx.time_model)
    ex.warmup = lambda sample_input: sfns.warmup(params, sample_input)
    return ex


# ---------------------------------------------------------------------------
# built-in sources
# ---------------------------------------------------------------------------

@register_source("closed-loop")
def _make_closed_loop(args, ctx):
    """The §IV K-client workload.  resources: workload (or build one from
    args: n_clients/d_lo/d_hi/n_requests/seed/mandatory_stages) +
    conf_table (sample count)."""
    from repro.core.simulator import Workload
    from repro.serving.runtime.sources import ClosedLoopSource
    wl = ctx.resources.get("workload")
    if wl is None:
        wl = Workload(**args)
    n_samples = ctx.resources["conf_table"].shape[0]
    return ClosedLoopSource(wl, n_samples, ctx.time_model.single_times())


@register_source("stream")
def _make_stream(args, ctx):
    """Pre-materialized (offset, Request) list — passed to ``Service.run``
    or as the ``requests`` resource."""
    from repro.serving.runtime.sources import StreamSource
    stream = ctx.stream if ctx.stream is not None \
        else ctx.resources.get("requests", [])
    return StreamSource(stream, ctx.task_factory)


@register_source("live")
def _make_live(args, ctx):
    """``Service.submit`` queue (wall clock: background engine thread;
    virtual clock: buffered until ``drain``)."""
    from repro.serving.service import LiveSource
    return LiveSource(ctx.task_factory, ctx.clock,
                      poll=float(args.get("poll", 0.002)))
