"""DeviceExecutor — real jitted stage functions behind the runtime core.

``submit`` dispatches the batched stage *without* blocking (XLA dispatch is
asynchronous), so with ``pipeline_depth=2`` the core pre-selects the next
batch on the host while the device computes; ``complete`` blocks on the
results and reads the wall clock for the completion time, exactly the
instant the legacy engines stamped after ``block_until_ready``.

Per-request state (input/hidden pytree, deepest in-time exit) lives here:
the executor is the layer that owns device data, so the engines' old
``_states`` dict moves in with it.  That dict is the serving stack's
hidden-state cache: a request's state is registered at admission,
**persisted across stage dispatches** (each ``commit`` slices the
request's row out of the batched stage output — a device-resident array,
never copied to host between stages) and **evicted on retire** (the
recorder pops it via ``pop_state``).  ``cache_stats()`` reports
live/peak/evicted counts so tests and metrics can hold the cache to that
lifecycle.  ``ShardedDeviceExecutor`` (:mod:`repro.launch.sharded`) runs
the same contract with stage fns sharded over a device mesh.
"""
from __future__ import annotations

import math

import jax
import numpy as np


class SingleStageFns:
    """Adapt the unbatched engine's per-stage ``fn(params, h)`` list to the
    batched ``run(stage, params, pytrees)`` surface (batches of exactly 1)."""

    def __init__(self, fns):
        self.fns = fns

    def run(self, stage: int, params, pytrees):
        h, logits, conf = self.fns[stage](params, pytrees[0])
        return h, logits, conf, np.ones(1, bool)


class DeviceExecutor:
    def __init__(self, stage_fns, params, time_model):
        self.stage_fns = stage_fns      # object with .run(stage, params, [h])
        self.params = params
        self.time_model = time_model
        self.total_busy = 0.0           # host-observed device-busy seconds
        self.states: dict = {}          # tid -> [request, hidden/inputs, exit]
        self.evictions = 0              # states popped on retire
        self.peak_cached = 0            # high-water mark of live states
        self._running = None
        self._done = None

    # -- request state (the hidden-state cache) ------------------------
    def register(self, task, request) -> None:
        """Admit ``task``'s state into the cache (raw inputs until the
        first stage commits a hidden row)."""
        self.states[task.tid] = [request, request.inputs, None]
        self.peak_cached = max(self.peak_cached, len(self.states))

    def pop_state(self, task):
        """Evict on retire — the other end of the cache lifecycle."""
        self.evictions += 1
        return self.states.pop(task.tid)

    def cache_stats(self) -> dict:
        return dict(live=len(self.states), peak=self.peak_cached,
                    evictions=self.evictions)

    # -- Executor contract ---------------------------------------------
    @property
    def busy(self) -> bool:
        return self._running is not None

    def wcet(self, stage: int, n: int) -> float:
        return self.time_model.wcet(stage, n)

    def submit(self, stage: int, tasks: list, now: float) -> None:
        hs = [self.states[t.tid][1] for t in tasks]
        h_out, logits, conf, _mask = self.stage_fns.run(stage, self.params, hs)
        self._running = (stage, tasks, h_out, logits, conf, now)

    def finish_time(self):
        # real devices do not announce completion times — the core must
        # block (None), unlike the oracle executor's known virtual finish
        return None if self.busy else math.inf

    def complete(self, clock):
        stage, tasks, h_out, logits, conf, t0 = self._running
        self._running = None
        jax.block_until_ready(h_out)
        self.total_busy += clock.now() - t0
        self._done = (h_out, np.asarray(logits), np.asarray(conf))
        return stage, tasks

    def commit(self, task, k: int) -> float:
        h_out, logits, conf = self._done
        c = float(np.max(conf[k]))
        lg = logits[k]
        pred = int(np.argmax(lg[0], -1)) if lg.ndim >= 2 else int(np.argmax(lg))
        st = self.states[task.tid]
        st[1] = jax.tree.map(lambda x: x[k:k + 1], h_out)
        st[2] = (pred, c)
        return c

    def running_tasks(self) -> list:
        return list(self._running[1]) if self._running is not None else []
