"""DeviceExecutor — real jitted stage functions behind the runtime core.

``submit`` dispatches the batched stage *without* blocking (XLA dispatch is
asynchronous), so with ``pipeline_depth=2`` the core pre-selects the next
batch on the host while the device computes; ``complete`` blocks on the
results and reads the wall clock for the completion time, exactly the
instant the legacy engines stamped after ``block_until_ready``.

Multiple in-flight windows: the executor accepts up to ``max_inflight``
submitted-but-uncompleted batches (a FIFO — XLA executes dispatches in
submission order on one device stream).  The core enqueues further windows
while ``accepting`` is true (``pipeline_depth >= 3``), so the device never
drains between windows waiting for host work.  ``complete`` retires the
oldest window; ``running_tasks`` covers every queued window so the core
never double-dispatches an in-flight task.

Per-request state (input/hidden pytree, deepest in-time exit) lives here:
the executor is the layer that owns device data, so the engines' old
``_states`` dict moves in with it.  That dict is the serving stack's
hidden-state cache: a request's state is registered at admission,
**persisted across stage dispatches** (each ``commit`` slices the
request's row out of the batched stage output — a device-resident array,
never copied to host between stages) and **evicted on retire** (the
recorder pops it via ``pop_state``).  ``cache_stats()`` reports
live/peak/evicted counts so tests and metrics can hold the cache to that
lifecycle.  ``ShardedDeviceExecutor`` (:mod:`repro.launch.sharded`) runs
the same contract with stage fns sharded over a device mesh;
``KernelDeviceExecutor`` (:mod:`repro.launch.kernel`) swaps the stage
bodies for Pallas-kernel-backed fns.

Telemetry: per-stage host seconds (synchronous dispatch + commit work,
measured on ``perf_counter`` so it is meaningful under any engine clock)
vs device seconds (time the host spent *blocked* in ``block_until_ready``)
— the measured decomposition behind the kernel-serving figure's
"device-time-dominated" claim, surfaced via :meth:`device_time_stats`.
"""
from __future__ import annotations

import collections
import math
import time

import jax
import numpy as np


class SingleStageFns:
    """Adapt the unbatched engine's per-stage ``fn(params, h)`` list to the
    batched ``run(stage, params, pytrees)`` surface (batches of exactly 1)."""

    def __init__(self, fns):
        self.fns = fns

    def run(self, stage: int, params, pytrees):
        h, logits, conf = self.fns[stage](params, pytrees[0])
        return h, logits, conf, np.ones(1, bool)


class DeviceExecutor:
    def __init__(self, stage_fns, params, time_model, *,
                 max_inflight: int = 1):
        self.stage_fns = stage_fns      # object with .run(stage, params, [h])
        self.params = params
        self.time_model = time_model
        self.max_inflight = max(1, int(max_inflight))
        self.total_busy = 0.0           # host-observed device-busy seconds
        self.states: dict = {}          # tid -> [request, hidden/inputs, exit]
        self.evictions = 0              # states popped on retire
        self.peak_cached = 0            # high-water mark of live states
        self._inflight = collections.deque()   # submitted, oldest first
        self._done = None
        # per-stage host/device seconds (see module docstring)
        self.stage_host_time: dict = collections.defaultdict(float)
        self.stage_device_time: dict = collections.defaultdict(float)

    # -- request state (the hidden-state cache) ------------------------
    def register(self, task, request) -> None:
        """Admit ``task``'s state into the cache (raw inputs until the
        first stage commits a hidden row)."""
        self.states[task.tid] = [request, request.inputs, None]
        self.peak_cached = max(self.peak_cached, len(self.states))

    def pop_state(self, task):
        """Evict on retire — the other end of the cache lifecycle."""
        self.evictions += 1
        return self.states.pop(task.tid)

    def cache_stats(self) -> dict:
        return dict(live=len(self.states), peak=self.peak_cached,
                    evictions=self.evictions)

    def device_time_stats(self) -> dict:
        """Measured per-stage host vs device seconds (and their totals)."""
        return dict(
            host_time=float(sum(self.stage_host_time.values())),
            device_time=float(sum(self.stage_device_time.values())),
            stage_host_time={int(s): float(v)
                             for s, v in sorted(self.stage_host_time.items())},
            stage_device_time={int(s): float(v) for s, v in
                               sorted(self.stage_device_time.items())})

    # -- stage dispatch (subclass seam) --------------------------------
    def _dispatch_stage(self, stage: int, tasks: list):
        """Run the batched stage, returning the window's payload (opaque
        to the core; ``_commit_from`` consumes it)."""
        hs = [self.states[t.tid][1] for t in tasks]
        h_out, logits, conf, _mask = self.stage_fns.run(stage, self.params,
                                                        hs)
        return h_out, logits, conf

    def _block_on(self, payload) -> None:
        jax.block_until_ready(payload[0])

    def _finalize(self, payload):
        h_out, logits, conf = payload
        return h_out, np.asarray(logits), np.asarray(conf)

    # -- Executor contract ---------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._inflight)

    @property
    def accepting(self) -> bool:
        """May the core submit another window while ``busy``?"""
        return len(self._inflight) < self.max_inflight

    def wcet(self, stage: int, n: int) -> float:
        return self.time_model.wcet(stage, n)

    def submit(self, stage: int, tasks: list, now: float) -> None:
        w0 = time.perf_counter()
        payload = self._dispatch_stage(stage, tasks)
        self.stage_host_time[stage] += time.perf_counter() - w0
        self._inflight.append((stage, tasks, payload, now))

    def finish_time(self):
        # real devices do not announce completion times — the core must
        # block (None), unlike the oracle executor's known virtual finish
        return None if self.busy else math.inf

    def complete(self, clock):
        stage, tasks, payload, t0 = self._inflight.popleft()
        w0 = time.perf_counter()
        self._block_on(payload)
        self.stage_device_time[stage] += time.perf_counter() - w0
        self.total_busy += clock.now() - t0
        self._done = (stage, self._finalize(payload))
        return stage, tasks

    def commit(self, task, k: int) -> float:
        stage, (h_out, logits, conf) = self._done
        w0 = time.perf_counter()
        c = float(np.max(conf[k]))
        lg = logits[k]
        pred = int(np.argmax(lg[0], -1)) if lg.ndim >= 2 else int(np.argmax(lg))
        st = self.states[task.tid]
        st[1] = jax.tree.map(lambda x: x[k:k + 1], h_out)
        st[2] = (pred, c)
        self.stage_host_time[stage] += time.perf_counter() - w0
        return c

    def running_tasks(self) -> list:
        return [t for (_s, tasks, _p, _t0) in self._inflight for t in tasks]
