"""Unified async event-driven serving runtime (one loop, four faces).

``EngineCore`` is the single implementation of the paper's user-space
scheduling loop — admit → expire → dispatch → observe → retire, §II-B
deadline semantics, admission control, closed-loop reissue, and result
aggregation — parameterized along three axes:

=====================  ========================  =========================
axis                   discrete-event            wall clock
=====================  ========================  =========================
Clock                  ``VirtualClock``          ``WallClock``
Executor               ``OracleExecutor``        ``DeviceExecutor``
                       (conf/correct tables +    (jitted stage fns,
                       ``BatchTimeModel``)       async XLA dispatch)
RequestSource          ``ClosedLoopSource``      ``StreamSource``
                       (K clients, §IV)          ((offset, Request) list)
=====================  ========================  =========================

New callers do not wire these axes by hand: the public front door is
``repro.serving.service`` — a declarative ``ServeSpec`` resolved through
``repro.serving.registry`` builds the ``EngineCore``.  The legacy entry
points are deprecated wrappers over that facade (all public signatures
unchanged, one-shot ``DeprecationWarning`` each):

* ``repro.core.simulate``            → ``ServeSpec(batching={"mode":
  "none", ...})`` — single-bucket pricing, every dispatch a singleton.
* ``repro.serving.batch.simulate_batched`` → ``ServeSpec`` with the
  caller's time model / admission controller / ``max_batch``.
* ``repro.serving.ServingEngine.run``      → ``ServeSpec(executor=
  "device-single", clock="wall", source="stream")``.
* ``repro.serving.batch.BatchedServingEngine.run`` → ``ServeSpec(
  executor="device-batched", clock="wall", source="stream")``.

Runtime-only capabilities on top of the unified core:

* ``pipeline_depth=2`` — pipelined async dispatch: the host pre-selects
  batch *N+1* while batch *N* runs on the device, re-validating deadline
  feasibility at true dispatch time (see ``EngineCore._revalidate``).
* ``policy_cost`` — deterministic per-invocation host-cost model, so
  charged-overhead comparisons are reproducible.
* unified host-cost accounting (``sched_charged`` / ``host_serial`` /
  ``host_overhead_frac`` / ``n_dispatches`` on ``SimResult``) on every
  path, fixing the legacy ``simulate_batched`` dropping charged time.

``DeviceExecutor`` lives in ``repro.serving.runtime.device`` (imports jax);
everything imported here is numpy-only so the simulators stay light.
"""
from repro.serving.runtime.clock import Clock, VirtualClock, WallClock
from repro.serving.runtime.core import (EngineCore, ResponseRecorder,
                                        TableRecorder, simulate_runtime)
from repro.serving.runtime.executor import Executor, OracleExecutor
from repro.serving.runtime.sources import (ClosedLoopSource, RequestSource,
                                           StreamSource)

__all__ = [
    "Clock", "ClosedLoopSource", "EngineCore", "Executor", "OracleExecutor",
    "RequestSource", "ResponseRecorder", "StreamSource", "TableRecorder",
    "VirtualClock", "WallClock", "simulate_runtime",
]
