"""Clock abstraction for the unified serving runtime.

The one event loop in ``repro.serving.runtime.core`` is parameterized by
*where time comes from*:

* ``VirtualClock`` — discrete-event time.  The loop jumps the clock to the
  next interesting instant (arrival or batch completion); host scheduling
  cost is *charged* to the clock only when ``charge_overhead`` is set
  (paper Fig. 12/13 protocol, where scheduler wall time competes with the
  workload for the same timeline).
* ``WallClock`` — real time.  ``now`` reads ``time.perf_counter``; waiting
  is sleeping (capped so arrivals and deadline expiries are polled at the
  same granularity as the legacy engines); host cost charges itself by
  actually elapsing.
"""
from __future__ import annotations

import math
import time


class Clock:
    """Time source driving an :class:`~repro.serving.runtime.core.EngineCore`.

    ``realtime`` distinguishes the two idle semantics: a virtual loop with
    nothing left to dispatch exits (remaining tasks drain at their
    deadlines), a wall-clock loop must keep polling until real deadlines
    expire.
    """

    realtime: bool = False

    def now(self) -> float:
        raise NotImplementedError

    def advance_to(self, t: float) -> None:
        raise NotImplementedError

    def charge(self, dt: float) -> None:
        """Serialize `dt` seconds of host work onto this timeline."""
        raise NotImplementedError


class VirtualClock(Clock):
    realtime = False

    def __init__(self, charge_overhead: bool = False):
        self._now = 0.0
        self.charge_overhead = charge_overhead

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if math.isfinite(t):
            self._now = max(self._now, t)

    def charge(self, dt: float) -> None:
        if self.charge_overhead:
            self._now += dt


class WallClock(Clock):
    """Real time, started on first use.

    ``advance_to`` sleeps toward the target but never more than
    ``max_sleep`` at once — the loop re-polls arrivals and deadline
    expiries at the legacy engines' granularity (5 ms toward a known
    arrival, 0.5 ms when idling against deadline expiry).
    """

    realtime = True

    def __init__(self, max_sleep: float = 0.005):
        self.max_sleep = max_sleep
        self._t0 = None

    def start(self) -> None:
        if self._t0 is None:        # idempotent: a live Service starts the
            self._t0 = time.perf_counter()   # clock before the engine does

    def now(self) -> float:
        if self._t0 is None:
            self.start()
        return time.perf_counter() - self._t0

    def advance_to(self, t: float) -> None:
        if not math.isfinite(t):
            return
        time.sleep(max(0.0, min(t - self.now(), self.max_sleep)))

    def charge(self, dt: float) -> None:
        pass                     # real host work already elapsed on this clock
