"""Request sources: *where tasks come from* for the unified runtime.

* ``ClosedLoopSource`` — the paper's §IV workload: K closed-loop clients,
  each with one outstanding request; completing (or expiring, or being
  rejected) a request immediately reissues the next with a fresh relative
  deadline U[D_l, D_u] and the next sample of a seed-shuffled test set.
  This reproduces the legacy simulators' RNG draw order and event
  tie-breaking exactly (golden-parity tests hold the runtime to it).
* ``StreamSource`` — a pre-materialized ``(offset_seconds, Request)``
  stream for the wall-clock engines; a caller-supplied factory turns each
  Request into an admitted-shape ``Task`` (§II-B deadline adjustment lives
  in the engine, which knows its host overhead and batch pricing).
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.task import Task


class RequestSource:
    def has_pending(self) -> bool:
        raise NotImplementedError

    def next_time(self) -> float:
        raise NotImplementedError

    def pop(self, now: float):
        """Materialize the earliest pending arrival (or None if the
        request budget is exhausted / the arrival produced no task)."""
        raise NotImplementedError

    def on_retire(self, task, now: float) -> None:
        """A task left the system (completed / expired / rejected)."""

    def qsize(self) -> int:
        """Arrivals still pending (metrics streaming / backpressure)."""
        return 0


class ClosedLoopSource(RequestSource):
    def __init__(self, workload, n_samples: int, stage_times):
        self.workload = workload
        self.stage_times = tuple(float(x) for x in stage_times)
        rng = np.random.default_rng(workload.seed)
        self.sample_order = rng.permutation(n_samples)
        self.rng = rng
        self.n_samples = n_samples
        self.issued = 0
        self.events = []             # (time, tiebreak, client)
        for c in range(workload.n_clients):
            t0 = float(rng.uniform(0, workload.d_lo))
            heapq.heappush(self.events, (t0, c, c))

    def has_pending(self) -> bool:
        return bool(self.events)

    def next_time(self) -> float:
        return self.events[0][0] if self.events else math.inf

    def pop(self, now: float):
        _, _, client = heapq.heappop(self.events)
        wl = self.workload
        if self.issued >= wl.n_requests:
            return None
        rel = self.rng.uniform(wl.d_lo, wl.d_hi)
        t = Task(arrival=now, deadline=now + rel, stage_times=self.stage_times,
                 mandatory=wl.mandatory_stages,
                 sample=int(self.sample_order[self.issued % self.n_samples]),
                 client=client)
        self.issued += 1
        return t

    def on_retire(self, task, now: float) -> None:
        # closed loop: the client reissues at *completion* time — a request
        # that finishes early frees its client immediately (an expired one
        # retires at its deadline, so `now` is correct in both cases)
        heapq.heappush(self.events, (now, -task.tid, task.client))

    def qsize(self) -> int:
        return len(self.events)


class StreamSource(RequestSource):
    def __init__(self, stream, task_factory):
        """``stream``: iterable of (offset_seconds, Request); ``task_factory``
        maps (request, now) -> Task (already registered with the executor).

        The stream is sorted by offset on construction (stable, so
        same-offset requests keep their input order) — callers may hand
        arrivals in any order without silently mis-ordering admissions
        (property-tested with shuffled offsets in tests/test_traffic.py).
        """
        self.pending = sorted(list(stream), key=lambda p: p[0])
        self.task_factory = task_factory
        self.i = 0

    def has_pending(self) -> bool:
        return self.i < len(self.pending)

    def next_time(self) -> float:
        return self.pending[self.i][0] if self.has_pending() else math.inf

    def pop(self, now: float):
        off, req = self.pending[self.i]
        self.i += 1
        req.arrival = off
        return self.task_factory(req, now)

    def qsize(self) -> int:
        return len(self.pending) - self.i
