"""EngineCore — the one serving event loop (paper Fig. 2, §II-B).

admit → expire → dispatch → observe → retire, parameterized by a
:class:`~repro.serving.runtime.clock.Clock` (virtual vs wall time), an
:class:`~repro.serving.runtime.executor.Executor` (oracle tables vs real
jitted stages) and a :class:`~repro.serving.runtime.sources.RequestSource`
(closed-loop clients vs a request stream).  The four legacy entry points
(``simulate``, ``simulate_batched``, ``ServingEngine``,
``BatchedServingEngine``) are thin configurations of this loop.

Pipelined async dispatch (``pipeline_depth=2``): with synchronous dispatch
the host blocks on the device, so every piece of host work — policy
selection, §II-E hooks, submit overhead — serializes with execution.  With
pipelining the host returns from the (asynchronous) submit immediately and
works *inside* the device window: it pre-selects batch *N+1* from the
tasks not in flight (re-pre-selecting when an arrival lands mid-window, so
the choice never goes stale against admissions), and when the device frees
the pre-selection is re-validated at true dispatch time — members must
still be active, at the pre-selected stage, below their assigned depth,
and the grown batch's bucket-rounded WCET must still meet every
co-runner's deadline (the PR-1 StageBatcher invariant; the leader keeps
the legacy dispatch-anyway singleton semantics).  The re-check also *tops
off* the batch with newly-eligible same-stage tasks under the same
invariant, so pipelining costs no batching opportunity.

Host-cost accounting is one uniform rule: host work performed while a
device window is open is hidden up to the window's duration; the rest
serializes.  Synchronous dispatch never opens a window (the host is
blocked), so every charge serializes — exactly the legacy accounting.

* ``sched_charged``  — all host scheduling cost incurred (policy calls,
  §II-E hooks, per-dispatch overhead), whether or not it serialized;
* ``host_serial``    — the part that serialized with device execution
  (== ``sched_charged`` for synchronous dispatch; smaller when pipelined).

``policy_cost`` replaces *measured* policy wall time with a deterministic
per-invocation charge — benchmarks compare pipelined vs synchronous
dispatch without host-timing jitter in the virtual timeline.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core.simulator import SimResult
from repro.serving.batch.batcher import StageBatcher
from repro.serving.batch.policy import as_batch_policy

_EPS = 1e-12


class TableRecorder:
    """Aggregates retirements into the simulators' ``SimResult``."""

    def __init__(self, conf_table, correct_table):
        self.conf_table = conf_table
        self.correct_table = correct_table
        self.finished: list = []

    def on_retire(self, task, now: float, rejected: bool = False) -> None:
        depth = task.executed
        # a request fails iff *no* stage completed before its deadline —
        # Task.executed only advances for in-time completions
        missed = depth == 0
        correct = (not missed) and bool(self.correct_table[task.sample,
                                                           depth - 1])
        conf = float(self.conf_table[task.sample, depth - 1]) if depth else 0.0
        self.finished.append(dict(tid=task.tid, missed=missed, correct=correct,
                                  depth=depth, conf=conf, client=task.client,
                                  sample=task.sample, deadline=task.deadline,
                                  arrival=task.arrival, rejected=rejected))

    def result(self, core) -> SimResult:
        finished = self.finished
        n = len(finished)
        ok = [f for f in finished if not f["missed"]]
        acc = float(np.mean([f["correct"] for f in finished])) if n else 0.0
        miss = float(np.mean([f["missed"] for f in finished])) if n else 0.0
        # guard on the non-missed subset, not n: an all-miss run must
        # report 0.0, not NaN (which would poison the JSON exports)
        depth = float(np.mean([f["depth"] for f in ok])) if ok else 0.0
        conf = float(np.mean([f["conf"] for f in ok])) if ok else 0.0
        busy = core.executor.total_busy
        sched = core.policy.sched_time
        denom = busy + sched
        hdenom = busy + core.host_serial
        ok = sum(1 for f in finished if not f["missed"])
        makespan = core.makespan
        return SimResult(
            accuracy=acc, miss_rate=miss, mean_depth=depth, mean_conf=conf,
            overhead_frac=sched / denom if denom else 0.0,
            n_requests=n, per_request=finished, makespan=makespan,
            throughput=ok / makespan if makespan > 0 else 0.0,
            sched_charged=core.sched_charged, host_serial=core.host_serial,
            host_overhead_frac=core.host_serial / hdenom if hdenom else 0.0,
            n_dispatches=core.n_dispatches, presel_hits=core.presel_hits,
            presel_misses=core.presel_misses)


class ResponseRecorder:
    """Builds the wall-clock engines' ``Response`` list from retirements."""

    def __init__(self, executor, responses: list):
        from repro.serving.engine import Response   # local: keeps layering
        self._Response = Response
        self.executor = executor
        self.responses = responses

    def on_retire(self, task, now: float, rejected: bool = False) -> None:
        req, _h, result = self.executor.pop_state(task)
        if result is None:
            self.responses.append(self._Response(
                task.sample, None, 0.0, 0, True, now - req.arrival,
                task.deadline))
        else:
            pred, conf = result
            self.responses.append(self._Response(
                task.sample, int(pred), float(conf), task.executed, False,
                now - req.arrival, task.deadline))


class EngineCore:
    def __init__(self, policy, clock, executor, source, recorder, *,
                 admission=None, pipeline_depth: int = 1,
                 dispatch_overhead: float = 0.0, policy_cost=None,
                 max_batch: int = None, tracer=None):
        self.policy = policy               # a BatchPolicy (see as_batch_policy)
        self.clock = clock
        self.executor = executor
        self.source = source
        self.recorder = recorder
        # optional obs hook (repro.serving.obs.Tracer) — passive: records
        # engine-computed timestamps only, never charges host time, so the
        # virtual timeline is identical with or without it
        self.tracer = tracer
        # optional per-stage observation hook (Service streams anytime
        # exits through it); legacy recorders don't define it
        self._on_stage = getattr(recorder, "on_stage", None)
        self.admission = admission
        self.pipeline_depth = pipeline_depth
        self.dispatch_overhead = dispatch_overhead
        self.policy_cost = policy_cost
        batcher = getattr(policy, "batcher", None)
        self.max_batch = max_batch if max_batch is not None else \
            (batcher.max_batch if batcher is not None else 1)
        # pipelined re-validation re-forms batches through a StageBatcher
        # (one implementation of the deadline invariant); custom policies
        # without one get a batcher over the executor's time model
        if batcher is None:
            tm = getattr(executor, "time_model", None)
            batcher = StageBatcher(tm, max_batch=self.max_batch,
                                   dp=getattr(executor, "dp", 1)) \
                if tm is not None else None
        self._batcher = batcher
        # telemetry -----------------------------------------------------
        self.sched_charged = 0.0
        self.host_serial = 0.0
        self.n_dispatches = 0
        self.presel_hits = 0
        self.presel_misses = 0
        self.makespan = 0.0
        self._active: list = []
        self._presel = None                # (stage, batch) pre-selection
        self._overlap_left = 0.0           # hideable host seconds, all windows
        self._win_overlap = []             # per open window, oldest first
        self._pullins: list = []           # cancel-after-admission requests

    # ------------------------------------------------------------------
    def _cost(self, measured: float) -> float:
        return measured if self.policy_cost is None else self.policy_cost

    def _account(self, cost: float) -> None:
        """One accounting rule: host work is hidden by the open device
        window(s) (pipelined mode keeps ``_overlap_left`` > 0 while batches
        are in flight), anything beyond it serializes with execution.
        With several windows enqueued (``pipeline_depth >= 3``) the budget
        drains oldest-window-first — host work happens during the window
        that is actually running."""
        hidden = min(cost, self._overlap_left)
        self._overlap_left -= hidden
        left = hidden
        for i in range(len(self._win_overlap)):
            if left <= 0.0:
                break
            take = min(left, self._win_overlap[i])
            self._win_overlap[i] -= take     # entry stays (one per window)
            left -= take
        serial = cost - hidden
        self.sched_charged += cost
        self.host_serial += serial
        self.clock.charge(serial)

    def _alive(self) -> bool:
        if self.clock.realtime:
            return bool(self._active)
        return any(t.executed < t.assigned_depth for t in self._active)

    def _retire(self, task, now: float, rejected: bool = False) -> None:
        if task in self._active:
            self._active.remove(task)
        self.recorder.on_retire(task, now, rejected)
        self.source.on_retire(task, now)

    def _expire(self, now: float) -> None:
        for t in list(self._active):
            if t.deadline <= now:
                self._retire(t, now)

    # -- cancellation after admission ----------------------------------
    def request_pullin(self, task) -> None:
        """Thread-safe (GIL append) request to shed ``task``'s remaining
        *optional* stages: its depth target is pulled in to the mandatory
        part already owed, and once nothing mandatory remains the task
        retires immediately with its deepest in-time exit — the paper's
        imprecise-computation cancel, applied live."""
        self._pullins.append(task)

    def _apply_pullins(self, now: float) -> None:
        inflight = {id(t) for t in self.executor.running_tasks()}
        while self._pullins:
            t = self._pullins.pop()
            if t not in self._active:
                continue                   # already retired — nothing to shed
            cap = max(t.mandatory, t.executed)
            t.depth_cap = cap if t.depth_cap is None else min(t.depth_cap, cap)
            t.assigned_depth = max(t.executed, min(t.assigned_depth, cap))
            if self.tracer is not None:
                self.tracer.on_pullin(t, now, cap)
            # an in-flight member finishes its committed stage first (§II-B
            # non-preemption); _complete retires it via the depth check
            if t.executed >= cap and id(t) not in inflight:
                self._retire(t, now)

    # -- dispatch ------------------------------------------------------
    def _revalidate(self, presel, now: float):
        """Feasibility re-check of a pre-selected batch at true dispatch
        time: if the leader still stands, the batch is re-FORMED around it
        by the StageBatcher — the single implementation of the PR-1
        deadline invariant — over everything now eligible, so surviving
        co-runners are re-admitted and newly-eligible same-stage tasks top
        the batch off.  Returns None when the leader no longer stands and
        the policy must run again."""
        stage, batch = presel
        leader = batch[0]
        inflight = {id(t) for t in self.executor.running_tasks()}
        if not (leader in self._active and leader.executed == stage
                and leader.executed < leader.assigned_depth
                and leader.deadline > now and id(leader) not in inflight):
            return None
        if self._batcher is None:
            return stage, [leader]
        cands = [t for t in self._active
                 if t.executed == stage and t.executed < t.assigned_depth
                 and t.deadline > now and id(t) not in inflight]
        return stage, self._batcher.form(
            leader, cands, now, rank=lambda t: self.policy.batch_rank(t, now))

    def _preselect(self, now: float) -> None:
        """Pick the next batch while the device is busy — host work inside
        the open window, hidden by ``_account`` up to the batch duration."""
        inflight = {id(t) for t in self.executor.running_tasks()}
        cands = [t for t in self._active if id(t) not in inflight]
        w0 = time.perf_counter()
        nb = self.policy.next_batch(cands, now)
        self._account(self._cost(time.perf_counter() - w0))
        self._presel = None if nb is None or not nb[1] else (nb[0], nb[1])

    def _dispatch(self, now: float) -> bool:
        nb = None
        if self._presel is not None:
            presel_tids = [t.tid for t in self._presel[1]] \
                if self.tracer is not None else None
            nb = self._revalidate(self._presel, now)
            self._presel = None
            if nb is not None:
                self.presel_hits += 1
                if presel_tids is not None:
                    final_tids = [t.tid for t in nb[1]]
                    if final_tids != presel_tids:
                        self.tracer.on_topoff(nb[0], presel_tids,
                                              final_tids, now)
            else:
                self.presel_misses += 1
        if nb is None:
            # in-flight members (possible while enqueueing extra windows at
            # pipeline_depth >= 3) are never candidates for a fresh pick
            inflight = {id(t) for t in self.executor.running_tasks()}
            cands = [t for t in self._active if id(t) not in inflight] \
                if inflight else self._active
            w0 = time.perf_counter()
            nb = self.policy.next_batch(cands, now)
            self._account(self._cost(time.perf_counter() - w0))
        if nb is None or not nb[1]:
            return False
        self._account(self.dispatch_overhead)
        stage, batch = nb
        now = self.clock.now()        # charges may have advanced virtual time
        self.executor.submit(stage, batch, now)
        self.n_dispatches += 1
        if self.tracer is not None:
            self.tracer.on_dispatch(stage, batch, now,
                                    self.executor.wcet(stage, len(batch)))
        if self.pipeline_depth >= 2:
            # async host: the submit returned without blocking — everything
            # the host does until the window closes can hide inside it
            # (windows stack when several batches are enqueued)
            w = self.executor.wcet(stage, len(batch))
            self._overlap_left += w
            self._win_overlap.append(w)
            self._preselect(now)
        return True

    def _complete(self) -> None:
        stage, batch = self.executor.complete(self.clock)
        if self.tracer is not None:
            self.tracer.on_window_close(stage, batch, self.clock.now())
        # the oldest window closed: drop its unused overlap budget; later
        # still-open windows keep theirs (empty list -> 0.0, the legacy
        # single-window behavior)
        if self._win_overlap:
            self._win_overlap.pop(0)
        self._overlap_left = float(sum(self._win_overlap))
        for k, t in enumerate(batch):
            now = self.clock.now()
            if t.deadline >= now - _EPS:          # stage finished in time
                t.executed += 1
                t.confidences.append(self.executor.commit(t, k))
                if self._on_stage is not None:
                    self._on_stage(t, now)
                if self.tracer is not None:
                    self.tracer.on_stage_exit(t, now)
                w0 = time.perf_counter()
                self.policy.on_stage_done(self._active, t, now)
                self._account(self._cost(time.perf_counter() - w0))
        now = self.clock.now()
        for t in batch:
            if t in self._active and (t.executed >= t.assigned_depth
                                      or t.deadline <= now):
                self._retire(t, now)

    def _admit(self, now: float) -> None:
        if self.source.next_time() > now + _EPS:
            return
        task = self.source.pop(now)
        if task is None:
            return
        tr = self.tracer
        if tr is not None:
            tr.on_admit(task, now, len(self._active))
        if self.admission is not None:
            dec = self.admission.apply(self._active, task, now)
            if tr is not None:
                tr.on_admission(task, now, dec)
            if not dec.admitted:
                # rejecting is a scheduling decision, not an accounting
                # trick: the request counts as a miss and frees its client
                self._retire(task, now, rejected=True)
                return
        elif tr is not None:
            tr.on_admission(task, now, None)
        self._active.append(task)
        w0 = time.perf_counter()
        self.policy.on_arrival(self._active, task, now)
        self._account(self._cost(time.perf_counter() - w0))
        if self.pipeline_depth >= 2 and self.executor.busy:
            # refresh the pre-selection against the admission (and its
            # replan) — more host work inside the still-open window
            self._preselect(now)

    # ------------------------------------------------------------------
    def run(self):
        clock, ex, src = self.clock, self.executor, self.source
        if clock.realtime:
            clock.start()
        while src.has_pending() or ex.busy or self._alive():
            now = clock.now()
            if self._pullins:
                self._apply_pullins(now)
            if clock.realtime:
                # wall clock: drain everything that has arrived before the
                # dispatch decision (legacy engine order — the policy must
                # see the whole backlog).  The virtual loop instead admits
                # one event per iteration, exactly like the legacy
                # simulators (same-instant events interleave with dispatch
                # attempts, which golden parity pins down).
                while src.has_pending() and src.next_time() <= now + _EPS:
                    self._admit(now)
            if not ex.busy:
                self._expire(now)
                self._dispatch(now)
            elif self.pipeline_depth >= 3 and getattr(ex, "accepting", False):
                # deep pipeline: stack further device windows behind the
                # running one so the device never drains while the host
                # works; an executor without an `accepting` property keeps
                # the single-in-flight contract
                self._dispatch(now)
            t_arr = src.next_time()
            t_fin = ex.finish_time() if ex.busy else math.inf
            if ex.busy and t_fin is None:
                # wall-clock device: only blocking reveals completion.  A
                # pipelined host admits whatever already arrived before it
                # blocks (triggering a pre-selection refresh inside the
                # open window); the synchronous engine keeps the legacy
                # order — arrivals are admitted only between executions.
                if self.pipeline_depth >= 2:
                    while src.has_pending() \
                            and src.next_time() <= clock.now() + _EPS:
                        self._admit(clock.now())
                self._complete()
                continue
            if not math.isfinite(min(t_arr, t_fin)):
                if clock.realtime and self._active:
                    clock.advance_to(now + 0.0005)   # poll deadline expiry
                    continue
                break
            if t_fin <= t_arr:
                self._complete()
            else:
                clock.advance_to(t_arr)
                if not clock.realtime:
                    self._admit(clock.now())
        # drain: the simulation ended with tasks still active — they retire
        # at their deadlines, which extends the makespan accordingly
        now = clock.now()
        makespan = now
        for t in list(self._active):
            tend = max(now, t.deadline)
            makespan = max(makespan, tend)
            self._retire(t, tend)
        self.makespan = makespan
        return self.recorder


def simulate_runtime(policy, workload, time_model, conf_table, correct_table,
                     *, charge_overhead: bool = False,
                     dispatch_overhead: float = 0.0, admission=None,
                     max_batch: int = None, pipeline_depth: int = 1,
                     policy_cost=None) -> SimResult:
    """Discrete-event run of the unified core over oracle tables.

    ``simulate`` (unbatched: single-bucket time model, ``max_batch=1``) and
    ``simulate_batched`` are this with ``pipeline_depth=1``; pipelined
    async dispatch and deterministic host-cost models are runtime-only.
    """
    from repro.serving.runtime.clock import VirtualClock
    from repro.serving.runtime.executor import OracleExecutor
    from repro.serving.runtime.sources import ClosedLoopSource

    pol = as_batch_policy(policy, time_model, max_batch=max_batch)
    core = EngineCore(
        pol, VirtualClock(charge_overhead=charge_overhead),
        OracleExecutor(time_model, conf_table,
                       max_inflight=max(1, pipeline_depth - 1)),
        ClosedLoopSource(workload, conf_table.shape[0],
                         time_model.single_times()),
        TableRecorder(conf_table, correct_table),
        admission=admission, pipeline_depth=pipeline_depth,
        dispatch_overhead=dispatch_overhead, policy_cost=policy_cost,
        max_batch=min(max_batch or time_model.max_batch,
                      time_model.max_batch))
    recorder = core.run()
    return recorder.result(core)
