"""Executor abstraction: *what actually runs* a dispatched stage batch.

The runtime core dispatches non-preemptive ``(stage, [tasks])`` units and
observes one confidence per in-time member.  Where those numbers come from
is the executor's business:

* ``OracleExecutor`` (here, numpy-only) — the discrete-event simulators'
  device model: a batch of ``n`` at stage ``s`` occupies the device for
  ``time_model.wcet(s, n)`` virtual seconds and each member's confidence
  is read from the per-sample oracle table.
* ``DeviceExecutor`` (``repro.serving.runtime.device``, jax) — real jitted
  stage functions on the accelerator; completion time is whenever
  ``block_until_ready`` returns on the wall clock.
* ``ShardedDeviceExecutor`` (``repro.launch.sharded``, registered as
  ``device-sharded`` from ``repro.launch.serve``) — the same contract with
  stage fns sharded over a ``(dp, tp)`` device mesh.

Contract (the device is one non-preemptive resource; pipelining overlaps
*host* work with it, not device work with device work):

    wcet(stage, n)            feasibility price of a batch of n
    submit(stage, tasks, now) start the batch (must not block)
    busy                      a batch is in flight
    finish_time()             known completion time of the *oldest*
                              in-flight batch, +inf when idle, or ``None``
                              when only blocking can tell (wall)
    complete(clock)           finish the oldest in-flight batch; advances/
                              reads the clock; returns (stage, tasks)
    commit(task, k)           record member k's stage output (called only
                              for members whose stage finished in time);
                              returns the measured confidence

Executors hold a *single* in-flight batch unless they expose an
``accepting`` property; when present and true, the core (at
``pipeline_depth >= 3``) may ``submit`` further batches while ``busy`` —
they queue behind the running one (FIFO) and ``complete`` retires them
oldest-first.  ``running_tasks()`` must cover every queued window so the
core never double-dispatches an in-flight task.
"""
from __future__ import annotations

import math


class Executor:
    @property
    def busy(self) -> bool:
        raise NotImplementedError

    def wcet(self, stage: int, n: int) -> float:
        raise NotImplementedError

    def submit(self, stage: int, tasks: list, now: float) -> None:
        raise NotImplementedError

    def finish_time(self):
        raise NotImplementedError

    def complete(self, clock) -> tuple:
        raise NotImplementedError

    def commit(self, task, k: int) -> float:
        raise NotImplementedError

    def running_tasks(self) -> list:
        raise NotImplementedError


class OracleExecutor(Executor):
    """Virtual device over oracle tables and a ``BatchTimeModel``.

    ``total_busy`` accumulates device-occupied virtual seconds (the
    denominator of the paper's overhead fraction).  ``max_inflight > 1``
    models a deep dispatch pipeline (``pipeline_depth >= 3``): further
    windows queue FIFO behind the running one and start the moment it
    finishes — the virtual-clock analog of multiple enqueued device
    windows.
    """

    def __init__(self, time_model, conf_table, *, max_inflight: int = 1):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.time_model = time_model
        self.conf_table = conf_table
        self.max_inflight = int(max_inflight)
        self.total_busy = 0.0
        self._inflight: list = []    # (stage, tasks, finish_time), oldest 1st

    @property
    def busy(self) -> bool:
        return bool(self._inflight)

    @property
    def accepting(self) -> bool:
        """Room for another enqueued window (core dispatches extra windows
        at ``pipeline_depth >= 3`` only while this holds)."""
        return len(self._inflight) < self.max_inflight

    def wcet(self, stage: int, n: int) -> float:
        return self.time_model.wcet(stage, n)

    def submit(self, stage: int, tasks: list, now: float) -> None:
        # length-aware when the model has a length axis and the batch
        # declares seq_lens (repro.serving.batch.time_model.batch_wcet)
        from repro.serving.batch.time_model import batch_wcet
        dur = batch_wcet(self.time_model, stage, tasks)
        self.total_busy += dur
        # a queued window starts when the one ahead of it finishes
        start = max(now, self._inflight[-1][2]) if self._inflight else now
        self._inflight.append((stage, tasks, start + dur))

    def finish_time(self):
        return self._inflight[0][2] if self._inflight else math.inf

    def complete(self, clock) -> tuple:
        stage, tasks, t_fin = self._inflight.pop(0)
        clock.advance_to(t_fin)
        return stage, tasks

    def commit(self, task, k: int) -> float:
        # called after task.executed was advanced for this stage
        return float(self.conf_table[task.sample, task.executed - 1])

    def running_tasks(self) -> list:
        return [t for _, tasks, _ in self._inflight for t in tasks]
