"""Executor abstraction: *what actually runs* a dispatched stage batch.

The runtime core dispatches non-preemptive ``(stage, [tasks])`` units and
observes one confidence per in-time member.  Where those numbers come from
is the executor's business:

* ``OracleExecutor`` (here, numpy-only) — the discrete-event simulators'
  device model: a batch of ``n`` at stage ``s`` occupies the device for
  ``time_model.wcet(s, n)`` virtual seconds and each member's confidence
  is read from the per-sample oracle table.
* ``DeviceExecutor`` (``repro.serving.runtime.device``, jax) — real jitted
  stage functions on the accelerator; completion time is whenever
  ``block_until_ready`` returns on the wall clock.
* ``ShardedDeviceExecutor`` (``repro.launch.sharded``, registered as
  ``device-sharded`` from ``repro.launch.serve``) — the same contract with
  stage fns sharded over a ``(dp, tp)`` device mesh.

Contract (single in-flight batch — the device is one non-preemptive
resource; pipelining overlaps *host* work with it, not device work with
device work):

    wcet(stage, n)            feasibility price of a batch of n
    submit(stage, tasks, now) start the batch (must not block)
    busy                      a batch is in flight
    finish_time()             known completion time, +inf when idle, or
                              ``None`` when only blocking can tell (wall)
    complete(clock)           finish the in-flight batch; advances/reads
                              the clock; returns (stage, tasks)
    commit(task, k)           record member k's stage output (called only
                              for members whose stage finished in time);
                              returns the measured confidence
"""
from __future__ import annotations

import math


class Executor:
    @property
    def busy(self) -> bool:
        raise NotImplementedError

    def wcet(self, stage: int, n: int) -> float:
        raise NotImplementedError

    def submit(self, stage: int, tasks: list, now: float) -> None:
        raise NotImplementedError

    def finish_time(self):
        raise NotImplementedError

    def complete(self, clock) -> tuple:
        raise NotImplementedError

    def commit(self, task, k: int) -> float:
        raise NotImplementedError

    def running_tasks(self) -> list:
        raise NotImplementedError


class OracleExecutor(Executor):
    """Virtual device over oracle tables and a ``BatchTimeModel``.

    ``total_busy`` accumulates device-occupied virtual seconds (the
    denominator of the paper's overhead fraction).
    """

    def __init__(self, time_model, conf_table):
        self.time_model = time_model
        self.conf_table = conf_table
        self.total_busy = 0.0
        self._running = None         # (stage, tasks, finish_time)

    @property
    def busy(self) -> bool:
        return self._running is not None

    def wcet(self, stage: int, n: int) -> float:
        return self.time_model.wcet(stage, n)

    def submit(self, stage: int, tasks: list, now: float) -> None:
        dur = self.time_model.wcet(stage, len(tasks))
        self.total_busy += dur
        self._running = (stage, tasks, now + dur)

    def finish_time(self):
        return self._running[2] if self._running is not None else math.inf

    def complete(self, clock) -> tuple:
        stage, tasks, t_fin = self._running
        self._running = None
        clock.advance_to(t_fin)
        return stage, tasks

    def commit(self, task, k: int) -> float:
        # called after task.executed was advanced for this stage
        return float(self.conf_table[task.sample, task.executed - 1])

    def running_tasks(self) -> list:
        return list(self._running[1]) if self._running is not None else []
