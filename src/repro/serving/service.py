"""One public serving API: declarative ``ServeSpec`` + ``Service`` facade.

The paper's user-space scheduler (Fig. 2, §II-B) is one admission point in
front of the anytime model; this module is that front door for the whole
package.  Instead of hand-wiring Clock x Executor x Source x Policy per
caller, a **ServeSpec** *names* every component by string key (resolved
through :mod:`repro.serving.registry`, so new schedulers/executors plug in
without touching core modules) and round-trips through JSON; a **Service**
built from it owns the engine lifecycle:

* ``Service.from_spec(spec, **resources)`` — resources are the
  non-serializable runtime objects (oracle tables, params, workloads,
  request streams, or ready-made component *instances*, which skip the
  registry lookup for that slot).
* ``run(stream=None) -> ServiceMetrics`` — one-shot batch mode: drive the
  configured source (closed-loop workload or request stream) to
  completion.
* ``submit(request, slo="gold") -> ResponseHandle`` — live mode
  (``source="live"``): a future with ``result(timeout)``, ``cancel()``
  and ``stages()`` — an iterator streaming each anytime
  (prediction, confidence) exit as it lands, the paper's
  anytime-prediction contract made API-visible.  On a wall clock the
  engine serves from a background thread; on a virtual clock submissions
  buffer until ``drain()`` replays them discrete-event.
* per-request **SLO classes** — named tiers mapping to relative deadline,
  utility weight and depth cap (``spec.slo_classes``), applied at
  admission and further clamped by the ``AdmissionController``.
* ``metrics() -> ServiceMetrics`` — structured superset of ``SimResult``
  (per-class breakdown, admission/cancellation counts), JSON-exportable.
* graceful ``drain()`` / ``close()``.

The four legacy faces (``simulate``, ``simulate_batched``,
``ServingEngine``, ``BatchedServingEngine``) are deprecated thin wrappers
over this facade; their fixed-seed golden-parity results are preserved
bit-for-bit (tests/test_runtime.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import math
import queue
import threading
from concurrent.futures import CancelledError
from typing import Any, Optional

from repro.core.simulator import SimResult
from repro.core.task import Task
from repro.serving.batch.admission import AdmissionController
from repro.serving.batch.batcher import DEFAULT_BUCKETS, BatchTimeModel
from repro.serving.batch.policy import as_batch_policy
from repro.serving.registry import BuildContext, resolve
from repro.serving.runtime.core import (EngineCore, ResponseRecorder,
                                        TableRecorder)
from repro.serving.runtime.sources import RequestSource, StreamSource

_SENTINEL = object()

# backpressure overflow policies for a bounded live intake (semantics in
# repro.serving.traffic.control, which re-exports this as OVERFLOW_MODES)
_OVERFLOW_MODES = ("reject", "shed-optional")


# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A named service tier: the §II-B deadline/utility contract per class.

    ``rel_deadline`` fills in requests that carry none; ``utility_weight``
    becomes ``Task.weight`` (the paper's weighted-accuracy importance);
    ``depth_cap`` pins ``Task.depth_cap`` before admission control (which
    may clamp it further under overload).
    """
    name: str
    rel_deadline: Optional[float] = None
    utility_weight: float = 1.0
    depth_cap: Optional[int] = None

    @classmethod
    def from_dict(cls, name: str, d: dict) -> "SLOClass":
        return cls(name=name,
                   rel_deadline=d.get("rel_deadline"),
                   utility_weight=float(d.get("utility_weight", 1.0)),
                   depth_cap=d.get("depth_cap"))


# ---------------------------------------------------------------------------
# ServeSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeSpec:
    """Declarative engine description — JSON/dict round-trippable.

    Component slots (``policy``/``executor``/``clock``/``source``) are
    registry keys (:mod:`repro.serving.registry`); their ``*_args`` dicts
    are passed to the factories verbatim.

    ``batching`` describes the ``BatchTimeModel`` and batch discipline:

    * ``{"mode": "none", "stage_times": [...]}`` — singleton dispatch,
      single-bucket pricing, legacy unbatched accounting (formation time
      not billed) — exactly the old ``simulate``/``ServingEngine``.
    * ``{"buckets": [...], "stage_times": [...], "marginal": 0.15}`` —
      analytic linear model (``BatchTimeModel.linear``).
    * ``{"buckets": [...], "times": [[...]]}`` — explicit per-bucket WCET
      rows (a profiled model, serialized).
    * a ``time_model`` *resource* overrides all of the above;
      ``max_batch``/``charge_formation`` keys still apply.

    ``admission``: ``{"mode": "reject"|"depth_cap", "headroom": 1.0}``
    (empty dict = no admission control); an optional ``forecast`` key
    (``{"process": {"kind": ...}, "horizon": ..., "margin": ...,
    "capacity": ...}``) arms the predictive controller
    (``repro.serving.adaptive``): a fitted arrival process tightens depth
    caps / rejects ahead of a forecast burst.  ``slo_classes``: name ->
    ``{rel_deadline, utility_weight, depth_cap}``.

    Full field reference: ``docs/serving-api.md`` (kept in sync by the
    docs-check CI job).  Example — declare, round-trip, validate, run:

    ```python
    import numpy as np
    from repro.serving import ServeSpec, Service

    rng = np.random.default_rng(0)
    conf = np.sort(rng.uniform(0.3, 1.0, (50, 3)), axis=1)
    correct = rng.uniform(size=(50, 3)) < conf
    spec = ServeSpec(policy="edf",
                     batching={"mode": "none", "stage_times": [0.01] * 3},
                     source_args={"n_clients": 4, "d_lo": 0.02,
                                  "d_hi": 0.2, "n_requests": 20})
    spec = ServeSpec.from_json(spec.to_json()).validate()
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    assert res.n_requests == 20
    ```
    """
    policy: str = "rtdeepiot"
    policy_args: dict = dataclasses.field(default_factory=dict)
    executor: str = "oracle"
    executor_args: dict = dataclasses.field(default_factory=dict)
    clock: str = "virtual"
    clock_args: dict = dataclasses.field(default_factory=dict)
    source: str = "closed-loop"
    source_args: dict = dataclasses.field(default_factory=dict)
    batching: dict = dataclasses.field(default_factory=dict)
    admission: dict = dataclasses.field(default_factory=dict)
    slo_classes: dict = dataclasses.field(default_factory=dict)
    default_slo: Optional[str] = None
    pipeline_depth: int = 1
    dispatch_overhead: float = 0.0
    policy_cost: Optional[float] = None
    charge_overhead: bool = False
    host_overhead: float = 0.0
    # > 0: stream windowed ServiceSnapshot rows to the ``on_metrics``
    # callback resource every `metrics_interval` service seconds
    # (repro.serving.traffic.control)
    metrics_interval: float = 0.0
    # tenant -> {"weight": w, "rate": r, "burst": b}: multi-tenant front
    # door (repro.serving.plane.frontdoor).  ``weight`` scales both the
    # fair-queueing quantum and the task's utility weight; ``rate``/
    # ``burst`` define the tenant's token-bucket submission quota.
    tenants: dict = dataclasses.field(default_factory=dict)
    # model id -> per-model config (stage_times/marginal/buckets/times/
    # len_buckets/len_marginal/mandatory/weight/utility): the multi-model
    # zoo (repro.serving.zoo).  Requests carrying ``Request.model`` are
    # priced, planned and admitted against their own model's tables;
    # empty dict = single-model serving, bit-for-bit unchanged.
    models: dict = dataclasses.field(default_factory=dict)
    # observability (repro.serving.obs): ``{"enabled": True}`` attaches a
    # passive Tracer (per-request spans + decision audit log + metrics
    # registry, reachable as ``service.obs`` after a run).  Optional keys:
    # ``spans``/``audit``/``metrics`` (bools, default True) gate the three
    # recording planes; ``export``/``chrome`` are file paths written when
    # the run finishes (JSONL / Chrome trace_event JSON).  Empty dict =
    # tracing off, zero overhead.
    trace: dict = dataclasses.field(default_factory=dict)

    # -- round trip ----------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeSpec keys: {sorted(unknown)}")
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "ServeSpec":
        return cls.from_dict(json.loads(s))

    # -- validation ----------------------------------------------------
    def validate(self) -> "ServeSpec":
        """Resolve every registry key and sanity-check the scalar fields;
        raises with the available keys on a miss.  Returns self."""
        for kind, name in (("policy", self.policy),
                           ("executor", self.executor),
                           ("clock", self.clock), ("source", self.source)):
            resolve(kind, name)
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        mode = self.admission.get("mode")
        if mode is not None and mode not in ("off", "reject", "depth_cap"):
            raise ValueError(f"admission mode {mode!r} not in "
                             "('off', 'reject', 'depth_cap')")
        forecast = self.admission.get("forecast")
        if forecast is not None:
            proc = forecast.get("process") if isinstance(forecast, dict) \
                else None
            if not isinstance(proc, dict) or "kind" not in proc:
                raise ValueError(
                    "admission forecast needs {'process': {'kind': ..., "
                    "...arrival args}} (a make_arrival_process dict)")
            from repro.serving.traffic.generators import ARRIVAL_KINDS
            if proc["kind"] not in ARRIVAL_KINDS:
                raise ValueError(
                    f"forecast process kind {proc['kind']!r} not in "
                    f"{sorted(ARRIVAL_KINDS)}")
        for name, d in self.slo_classes.items():
            c = SLOClass.from_dict(name, d)
            if c.rel_deadline is not None and c.rel_deadline <= 0:
                raise ValueError(f"SLO {name!r}: rel_deadline must be > 0")
            if c.depth_cap is not None and c.depth_cap < 1:
                raise ValueError(f"SLO {name!r}: depth_cap must be >= 1")
        if self.default_slo is not None \
                and self.default_slo not in self.slo_classes:
            raise ValueError(f"default_slo {self.default_slo!r} is not a "
                             f"defined SLO class")
        if self.metrics_interval < 0:
            raise ValueError("metrics_interval must be >= 0")
        if self.executor == "device-sharded":
            self._validate_sharded_args()
        if self.executor == "device-kernel":
            self._validate_kernel_args()
        if self.source == "live":
            bound = self.source_args.get("bound")
            if bound is not None and int(bound) < 1:
                raise ValueError("live source 'bound' must be >= 1")
            ov = self.source_args.get("overflow")
            if ov is not None and ov not in _OVERFLOW_MODES:
                raise ValueError(f"live source overflow {ov!r} not in "
                                 f"{_OVERFLOW_MODES}")
        for name, cfg in self.tenants.items():
            if not isinstance(cfg, dict):
                raise ValueError(f"tenant {name!r}: config must be a dict")
            if float(cfg.get("weight", 1.0)) <= 0:
                raise ValueError(f"tenant {name!r}: weight must be > 0")
            rate = cfg.get("rate")
            if rate is not None and float(rate) <= 0:
                raise ValueError(f"tenant {name!r}: rate must be > 0")
            if float(cfg.get("burst", 1.0)) < 1:
                raise ValueError(f"tenant {name!r}: burst must be >= 1")
        if self.models:
            # lazy: the zoo subsystem owns its config schema, the same
            # discipline as _validate_sharded_args
            from repro.serving.zoo import validate_models
            validate_models(self.models)
        if self.trace:
            from repro.serving.obs import TRACE_KEYS
            unknown = set(self.trace) - set(TRACE_KEYS)
            if unknown:
                raise ValueError(f"unknown trace keys: {sorted(unknown)} "
                                 f"(allowed: {TRACE_KEYS})")
            for key in ("export", "chrome"):
                v = self.trace.get(key)
                if v is not None and not isinstance(v, str):
                    raise ValueError(f"trace {key!r} must be a file path")
        if self.source == "frontdoor":
            disc = self.source_args.get("discipline")
            if disc is not None and disc not in ("drr", "fifo"):
                raise ValueError(f"frontdoor discipline {disc!r} not in "
                                 "('drr', 'fifo')")
            rq = self.source_args.get("run_queue")
            if rq is not None and int(rq) < 1:
                raise ValueError("frontdoor 'run_queue' must be >= 1")
            if float(self.source_args.get("quantum", 1.0)) <= 0:
                raise ValueError("frontdoor 'quantum' must be > 0")
        return self

    def _validate_sharded_args(self) -> None:
        """Shape-level checks for ``executor="device-sharded"`` args (the
        factory itself lives in :mod:`repro.launch.sharded`): dp/tp must be
        whole parallelism factors, ``mesh`` two distinct axis names.  Fail
        here, at spec time, not at first dispatch on a warm engine."""
        # lazy: the factory (and its arg list) lives with the executor it
        # validates; repro.launch.sharded does not import this module back
        from repro.launch.sharded import SHARDED_ARGS
        ea = self.executor_args
        known = set(SHARDED_ARGS)
        unknown = set(ea) - known
        if unknown:
            raise ValueError(f"unknown device-sharded executor_args: "
                             f"{sorted(unknown)}; known: {sorted(known)}")
        for key in ("dp", "tp"):
            v = ea.get(key, 1)
            if isinstance(v, bool) or not isinstance(v, int) or v < 1:
                raise ValueError(f"device-sharded {key!r} must be an "
                                 f"integer >= 1, got {v!r}")
        axes = ea.get("mesh")
        if axes is not None:
            if (not isinstance(axes, (list, tuple)) or len(axes) != 2
                    or not all(isinstance(a, str) and a for a in axes)
                    or axes[0] == axes[1]):
                raise ValueError(
                    "device-sharded 'mesh' must be two distinct axis names "
                    f"[dp_axis, tp_axis], got {axes!r}")
        if float(ea.get("collective", 0.0)) < 0:
            raise ValueError("device-sharded 'collective' must be >= 0")

    def _validate_kernel_args(self) -> None:
        """Shape-level checks for ``executor="device-kernel"`` args (the
        factory lives in :mod:`repro.launch.kernel`).  Fail at spec time,
        not at first dispatch on a warm engine."""
        # lazy: the factory (and its arg list) lives with the executor it
        # validates; repro.launch.kernel does not import this module back
        from repro.launch.kernel import KERNEL_ARGS
        ea = self.executor_args
        unknown = set(ea) - set(KERNEL_ARGS)
        if unknown:
            raise ValueError(f"unknown device-kernel executor_args: "
                             f"{sorted(unknown)}; known: "
                             f"{sorted(KERNEL_ARGS)}")
        mode = ea.get("mode", "classifier")
        if mode not in ("classifier", "decode"):
            raise ValueError(f"device-kernel mode {mode!r} not in "
                             "('classifier', 'decode')")
        for key in ("block_rows", "block_v"):
            v = ea.get(key, 8)
            if isinstance(v, bool) or not isinstance(v, int) or v < 1:
                raise ValueError(f"device-kernel {key!r} must be an "
                                 f"integer >= 1, got {v!r}")
        lbs = ea.get("len_buckets")
        if lbs is not None:
            if (not isinstance(lbs, (list, tuple)) or not lbs
                    or any(isinstance(b, bool) or not isinstance(b, int)
                           or b < 1 for b in lbs)
                    or list(lbs) != sorted(set(lbs))):
                raise ValueError(
                    "device-kernel 'len_buckets' must be a strictly "
                    f"ascending list of integers >= 1, got {lbs!r}")
        lm = ea.get("len_marginal")
        if lm is not None and not 0 <= float(lm) <= 1:
            raise ValueError("device-kernel 'len_marginal' must be in "
                             "[0, 1]")

    def slo_class(self, name: Optional[str]) -> Optional[SLOClass]:
        if name is None:
            name = self.default_slo
        if name is None:
            return None
        try:
            return SLOClass.from_dict(name, self.slo_classes[name])
        except KeyError:
            raise KeyError(f"unknown SLO class {name!r}; defined: "
                           f"{sorted(self.slo_classes)}") from None


# ---------------------------------------------------------------------------
# results / metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceResponse:
    """What a resolved ``ResponseHandle`` yields (executor-agnostic: the
    oracle executor has no predictions, so ``prediction`` may be None)."""
    sample: int
    prediction: Optional[int]
    confidence: float
    depth: int
    missed: bool
    latency: float
    deadline: float
    slo: Optional[str] = None
    rejected: bool = False
    tid: int = -1


@dataclasses.dataclass(frozen=True)
class StageExit:
    """One anytime exit: stage ``depth`` finished in time at service time
    ``t`` with this (prediction, confidence)."""
    depth: int
    prediction: Optional[int]
    confidence: float
    t: float


@dataclasses.dataclass
class ServiceMetrics(SimResult):
    """``SimResult`` plus the service-level dimensions: per-SLO-class
    breakdown, admission-control counts, cancellations, and the resolved
    component keys.  ``to_json`` exports the whole structure.

    ``miss_rate``/``accuracy`` keep the legacy semantics (a rejected
    request counts as a miss); ``admitted_miss_rate`` /
    ``admitted_accuracy`` score only what the service accepted — the
    overload-control question is whether *admitted* work meets its
    deadlines while rejects fail fast."""
    per_class: dict = dataclasses.field(default_factory=dict)
    per_tenant: dict = dataclasses.field(default_factory=dict)
    # model id -> {n, served, rejected, miss_rate, mean_depth,
    # mean_latency, accuracy, weighted_accuracy} — the multi-model zoo's
    # breakdown (empty when no request carried a model id); accuracy
    # fields are None when correctness is unmeasurable for that executor
    per_model: dict = dataclasses.field(default_factory=dict)
    rejected: int = 0
    capped: int = 0
    cancelled: int = 0
    admitted_miss_rate: float = 0.0
    admitted_accuracy: Optional[float] = None
    components: dict = dataclasses.field(default_factory=dict)
    # device-executor telemetry (empty for modeled/oracle executors):
    # measured per-stage host vs device seconds and hidden-state-cache
    # lifecycle counts (live/peak/evictions) — see DeviceExecutor
    executor_times: dict = dataclasses.field(default_factory=dict)
    executor_cache: dict = dataclasses.field(default_factory=dict)

    def to_json(self, *, per_request: bool = False, **kw) -> str:
        return json.dumps(self.to_dict(per_request=per_request), **kw)


# ---------------------------------------------------------------------------
# response futures
# ---------------------------------------------------------------------------

class ResponseHandle:
    """Future for one submitted request.

    * ``result(timeout)`` — block for the final ``ServiceResponse``
      (raises ``TimeoutError`` on timeout, ``CancelledError`` if
      cancelled).  On a virtual clock, call ``Service.drain()`` first.
    * ``stages()`` — iterate the request's anytime exits
      (:class:`StageExit`) as they land; the iterator ends when the
      request retires.  One-shot: exits are consumed.
    * ``cancel()`` — before admission: withdraws the request outright
      (``result()`` raises ``CancelledError``).  After admission (a live
      wall-clock service), the request's remaining *optional* stages are
      shed — the engine pulls the depth target in to the mandatory part
      and retires it at the next loop tick — and ``result()`` still
      returns the deepest in-time exit (the anytime contract survives
      cancellation).  Returns True when either took effect.

    Example — stream the anytime exits of one request:

    ```python
    import numpy as np
    from repro.serving import ServeSpec, Service
    from repro.serving.engine import Request

    rng = np.random.default_rng(1)
    conf = np.sort(rng.uniform(0.5, 1.0, (10, 3)), axis=1)
    correct = rng.uniform(size=(10, 3)) < conf
    spec = ServeSpec(source="live", default_slo="gold",
                     slo_classes={"gold": {"rel_deadline": 0.5}},
                     batching={"mode": "none",
                               "stage_times": [0.01] * 3})
    with Service.from_spec(spec, conf_table=conf,
                           correct_table=correct) as svc:
        handle = svc.submit(Request(inputs=None, sample=0))
        svc.drain()
        exits = list(handle.stages())        # each in-time (pred, conf)
        assert handle.result().depth == len(exits)
    ```
    """

    def __init__(self, service: "Service", request):
        self._service = service
        self._request = request
        self._event = threading.Event()
        self._stage_q: queue.Queue = queue.Queue()
        self._result: Optional[ServiceResponse] = None
        self._cancelled = False
        self._claimed = False          # the engine admitted the request
        self._lock = threading.Lock()  # cancel vs engine-claim exclusion
        self._error: Optional[BaseException] = None
        self._task = None

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            if self._claimed:
                task = self._task
            else:
                self._cancelled = True
                task = None
        if task is not None:
            # admitted: shed the remaining optional stages (deadline
            # pull-in) via the engine loop — wall-clock live only (a
            # virtual-clock drain() admits and runs synchronously)
            live = self._service._live
            if live is None:
                return False
            self._service._n_cancelled += 1
            live.core.request_pullin(task)
            return True
        self._service._n_cancelled += 1
        self._service._submitted.discard(self)
        self._event.set()
        self._stage_q.put(_SENTINEL)
        return True

    def result(self, timeout: Optional[float] = None) -> ServiceResponse:
        if not self._event.wait(timeout):
            raise TimeoutError("request not resolved within timeout "
                               "(virtual-clock services resolve at drain())")
        if self._cancelled:
            raise CancelledError()
        if self._error is not None:
            raise RuntimeError("serving engine failed before this request "
                               "resolved") from self._error
        return self._result

    def stages(self, timeout: Optional[float] = None):
        while True:
            try:
                item = self._stage_q.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError("no stage exit within timeout") from None
            if item is _SENTINEL:
                self._stage_q.put(_SENTINEL)   # keep the stream terminated
                return
            yield item

    # called from the engine (possibly a background thread) -------------
    def _push_stage(self, exit_: StageExit) -> None:
        self._stage_q.put(exit_)

    def _resolve(self, result: ServiceResponse) -> None:
        self._result = result
        self._stage_q.put(_SENTINEL)
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        """The engine died before this request resolved — unblock waiters."""
        if self._event.is_set():
            return
        self._error = exc
        self._stage_q.put(_SENTINEL)
        self._event.set()


# ---------------------------------------------------------------------------
# live source (Service.submit queue)
# ---------------------------------------------------------------------------

class LiveSource(RequestSource):
    """Thread-safe request intake for a wall-clock live service.

    ``has_pending`` stays true while the intake is open, so the engine
    loop keeps polling (at ``poll`` granularity) instead of exiting when
    the queue momentarily runs dry; ``close()`` (from ``drain``) lets the
    loop finish the backlog and fall through.
    """

    def __init__(self, task_factory, clock, poll: float = 0.002):
        self.task_factory = task_factory
        self.clock = clock
        self.poll = poll
        self._heap: list = []
        self._n = 0
        self._lock = threading.Lock()
        self._closed = False

    def push(self, offset: float, request) -> None:
        with self._lock:
            heapq.heappush(self._heap, (offset, self._n, request))
            self._n += 1

    def qsize(self) -> int:
        with self._lock:
            return len(self._heap)

    def close(self) -> None:
        self._closed = True

    def has_pending(self) -> bool:
        with self._lock:
            return bool(self._heap) or not self._closed

    def next_time(self) -> float:
        with self._lock:
            if self._heap:
                return self._heap[0][0]
        if self._closed:
            return math.inf
        return self.clock.now() + self.poll

    def pop(self, now: float):
        with self._lock:
            off, _, req = heapq.heappop(self._heap)
        req.arrival = off
        return self.task_factory(req, now)


# ---------------------------------------------------------------------------
# recorder: engine retirements -> handles + uniform records
# ---------------------------------------------------------------------------

class ServiceRecorder:
    """Wraps the runtime recorders: keeps the golden-parity aggregation
    (``TableRecorder``) / legacy ``Response`` list (``ResponseRecorder``)
    intact while resolving futures, streaming stage exits, and collecting
    the uniform per-request records ``ServiceMetrics`` is built from."""

    def __init__(self, service: "Service", inner, executor, streamer=None):
        self.service = service
        self.inner = inner
        self.executor = executor
        self.streamer = streamer       # MetricsStreamer (traffic.control)
        # durable-plane hook (repro.serving.plane.JournalObserver): its
        # terminal append must land, fsynced, before the handle resolves
        self.observer = service.resources.get("observer")
        self.records: list = []
        self.core = None               # set by Service._build

    # -- helpers -------------------------------------------------------
    def _pred_conf(self, task):
        pred, conf = None, task.last_confidence
        states = getattr(self.executor, "states", None)
        if states is not None:
            st = states.get(task.tid)
            if st is not None and st[2] is not None:
                pred, conf = st[2]
        return pred, (float(conf) if conf is not None else 0.0)

    # -- engine hooks ----------------------------------------------------
    def on_stage(self, task, now: float) -> None:
        if self.streamer is not None:
            self.streamer.tick(now)
        if self.observer is not None:
            self.observer.on_stage(task, now)
        h = self.service._handles.get(task.tid)
        if h is None:
            return
        pred, conf = self._pred_conf(task)
        h._push_stage(StageExit(depth=task.executed, prediction=pred,
                                confidence=conf, t=now))

    def on_retire(self, task, now: float, rejected: bool = False) -> None:
        pred, conf = self._pred_conf(task)
        if self.inner is not None:
            self.inner.on_retire(task, now, rejected)
        missed = task.executed == 0
        slo = self.service._slo_names.get(task.tid)
        # latency from *request* arrival where known (stream/live modes);
        # closed-loop tasks are admitted at issue time, so task.arrival is
        # already the true arrival
        t0 = self.service._req_arrivals.pop(task.tid, task.arrival)
        latency = now - t0
        tenant, rid = self.service._req_meta.pop(task.tid, (None, None))
        rec = dict(
            tid=task.tid, sample=task.sample, client=task.client, slo=slo,
            depth=task.executed, missed=missed, conf=conf, prediction=pred,
            arrival=task.arrival, deadline=task.deadline, offset=t0,
            rel_deadline=self.service._req_rels.pop(task.tid, None),
            depth_cap=task.depth_cap, tenant=tenant, request_id=rid,
            latency=latency, rejected=rejected, weight=task.weight,
            model=getattr(task, "model", None))
        tracer = self.core.tracer if self.core is not None else None
        if tracer is not None:
            # injects queue_wait / host_time / device_time / decision into
            # the row (emit-only-when-set) and closes the RequestTrace
            tracer.finalize(task, now, rejected, t0, rec)
        self.records.append(rec)
        if self.observer is not None:
            # the WAL's terminal record, fsynced before _resolve below —
            # an outcome a caller has seen is always on disk
            self.observer.on_retire(rec, now)
        if self.streamer is not None:
            self.streamer.observe(rec, now)
        self.service._slo_names.pop(task.tid, None)
        h = self.service._handles.pop(task.tid, None)
        if h is not None:
            h._resolve(ServiceResponse(
                sample=task.sample, prediction=pred, confidence=conf,
                depth=task.executed, missed=missed, latency=latency,
                deadline=task.deadline, slo=slo, rejected=rejected,
                tid=task.tid))
            # resolved handles no longer need failure fanout — prune so a
            # long-lived live service does not grow without bound
            self.service._submitted.discard(h)

    # -- aggregation -----------------------------------------------------
    def _base_fields(self, core) -> dict:
        if isinstance(self.inner, TableRecorder):
            d = dataclasses.asdict(self.inner.result(core))
            # aggregates keep the golden-parity TableRecorder math, but the
            # per-request rows are the uniform service records (offset /
            # rel_deadline / slo / depth_cap — what trace replay needs)
            d["per_request"] = self.records
            return d
        recs = self.records
        n = len(recs)
        labels = self.service.resources.get("labels")
        ok = [r for r in recs if not r["missed"]]

        def _correct(r):
            p = r.get("prediction")
            return p is not None and p == labels[r["sample"]]
        # prediction correctness needs a ``labels`` resource; without it
        # this executor cannot measure accuracy — report None, not a
        # plausible-looking 0.0
        acc = (sum(_correct(r) for r in recs) / n) if n and labels is not None \
            else None
        busy = getattr(self.executor, "total_busy", 0.0)
        sched = core.policy.sched_time
        denom, hdenom = busy + sched, busy + core.host_serial
        makespan = core.makespan
        return dict(
            accuracy=acc,
            miss_rate=(sum(r["missed"] for r in recs) / n) if n else 0.0,
            mean_depth=(sum(r["depth"] for r in ok) / len(ok)) if ok else 0.0,
            mean_conf=(sum(r["conf"] for r in ok) / len(ok)) if ok else 0.0,
            overhead_frac=sched / denom if denom else 0.0,
            n_requests=n, per_request=recs, makespan=makespan,
            throughput=len(ok) / makespan if makespan > 0 else 0.0,
            sched_charged=core.sched_charged, host_serial=core.host_serial,
            host_overhead_frac=core.host_serial / hdenom if hdenom else 0.0,
            n_dispatches=core.n_dispatches, presel_hits=core.presel_hits,
            presel_misses=core.presel_misses)

    def result(self, core) -> ServiceMetrics:
        per_class: dict = {}
        for r in self.records:
            if r["slo"] is None:
                continue
            c = per_class.setdefault(r["slo"], dict(
                n=0, missed=0, rejected=0, depth_sum=0, latency_sum=0.0))
            c["n"] += 1
            c["missed"] += int(r["missed"])
            c["rejected"] += int(r["rejected"])
            c["depth_sum"] += r["depth"]
            c["latency_sum"] += r["latency"]
        for name, c in per_class.items():
            n = c["n"]
            per_class[name] = dict(
                n=n, miss_rate=c["missed"] / n, rejected=c["rejected"],
                mean_depth=c["depth_sum"] / n,
                mean_latency=c["latency_sum"] / n)
        # backpressure rejects never became tasks: they appear in the
        # rejected counters (total and per class), not in n_requests
        for name, cnt in self.service._bp_per_class.items():
            entry = per_class.setdefault(name, dict(
                n=0, miss_rate=0.0, rejected=0, mean_depth=0.0,
                mean_latency=0.0))
            entry["rejected"] += cnt
        per_tenant: dict = {}
        for r in self.records:
            if r.get("tenant") is None:
                continue
            t = per_tenant.setdefault(r["tenant"], dict(
                n=0, served=0, missed=0, rejected=0, depth_sum=0,
                latency_sum=0.0))
            t["n"] += 1
            t["missed"] += int(r["missed"])
            t["rejected"] += int(r["rejected"])
            t["served"] += int(not r["rejected"] and not r["missed"])
            t["depth_sum"] += r["depth"]
            t["latency_sum"] += r["latency"]
        for name, t in per_tenant.items():
            n = t["n"]
            per_tenant[name] = dict(
                n=n, served=t["served"], rejected=t["rejected"],
                miss_rate=t["missed"] / n, mean_depth=t["depth_sum"] / n,
                mean_latency=t["latency_sum"] / n)
        # front-door quota rejects never became tasks: count them per
        # tenant the same way backpressure rejects count per class
        for name, cnt in self.service._tenant_rejects.items():
            entry = per_tenant.setdefault(name, dict(
                n=0, served=0, rejected=0, miss_rate=0.0, mean_depth=0.0,
                mean_latency=0.0))
            entry["rejected"] += cnt
        # per-model breakdown (repro.serving.zoo): correctness comes from
        # the TableRecorder's finished rows (matched by tid) or a
        # ``labels`` resource; None where neither can measure it
        correct_by_tid = {}
        if isinstance(self.inner, TableRecorder):
            correct_by_tid = {f["tid"]: f["correct"]
                              for f in self.inner.finished}
        labels = self.service.resources.get("labels")

        def _rec_correct(r):
            if r["tid"] in correct_by_tid:
                return bool(correct_by_tid[r["tid"]])
            if labels is not None and r.get("prediction") is not None:
                return bool(r["prediction"] == labels[r["sample"]])
            return None
        per_model: dict = {}
        for r in self.records:
            if r.get("model") is None:
                continue
            m = per_model.setdefault(r["model"], dict(
                n=0, served=0, missed=0, rejected=0, depth_sum=0,
                latency_sum=0.0, correct=0, measured=0, w_sum=0.0,
                w_correct=0.0))
            m["n"] += 1
            m["missed"] += int(r["missed"])
            m["rejected"] += int(r["rejected"])
            m["served"] += int(not r["rejected"] and not r["missed"])
            m["depth_sum"] += r["depth"]
            m["latency_sum"] += r["latency"]
            c = _rec_correct(r)
            if c is not None and not r["rejected"]:
                w = float(r.get("weight", 1.0))
                m["measured"] += 1
                m["correct"] += int(c)
                m["w_sum"] += w
                m["w_correct"] += w * int(c)
        for name, m in per_model.items():
            n = m["n"]
            per_model[name] = dict(
                n=n, served=m["served"], rejected=m["rejected"],
                miss_rate=m["missed"] / n, mean_depth=m["depth_sum"] / n,
                mean_latency=m["latency_sum"] / n,
                accuracy=(m["correct"] / m["measured"]
                          if m["measured"] else None),
                weighted_accuracy=(m["w_correct"] / m["w_sum"]
                                   if m["w_sum"] else None))
        adm_recs = [r for r in self.records if not r["rejected"]]
        admitted_miss = (sum(r["missed"] for r in adm_recs) / len(adm_recs)
                         if adm_recs else 0.0)
        admitted_acc = None
        if isinstance(self.inner, TableRecorder):
            fin = [f for f in self.inner.finished if not f["rejected"]]
            if fin:
                admitted_acc = sum(f["correct"] for f in fin) / len(fin)
        else:
            labels = self.service.resources.get("labels")
            if labels is not None and adm_recs:
                admitted_acc = sum(
                    r.get("prediction") is not None
                    and r["prediction"] == labels[r["sample"]]
                    for r in adm_recs) / len(adm_recs)
        adm = core.admission
        spec = self.service.spec
        ex = core.executor
        dts = getattr(ex, "device_time_stats", None)
        cst = getattr(ex, "cache_stats", None)
        return ServiceMetrics(
            executor_times=dts() if dts is not None else {},
            executor_cache=cst() if cst is not None else {},
            **self._base_fields(core), per_class=per_class,
            per_tenant=per_tenant, per_model=per_model,
            rejected=(adm.rejected if adm is not None else 0)
            + self.service._n_bp_rejected,
            capped=(adm.capped if adm is not None else 0)
            + self.service._n_shed,
            cancelled=self.service._n_cancelled,
            admitted_miss_rate=admitted_miss,
            admitted_accuracy=admitted_acc,
            components=dict(policy=spec.policy, executor=spec.executor,
                            clock=spec.clock, source=spec.source))


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Built:
    core: EngineCore
    recorder: ServiceRecorder
    clock: Any
    source: Any


class Service:
    """Engine lifecycle behind one admission point (see module docstring).

    Components are rebuilt fresh on every :meth:`run` (so repeated runs do
    not leak policy state across workloads); component *instances* passed
    as resources (``policy=``, ``executor=``, ``clock=``, ``source=``,
    ``admission=``) are reused as-is, skipping the registry.

    Example — live mode on a virtual clock (submissions buffer, ``drain``
    replays them discrete-event and resolves every handle):

    ```python
    import numpy as np
    from repro.serving import ServeSpec, Service
    from repro.serving.engine import Request

    rng = np.random.default_rng(0)
    conf = np.sort(rng.uniform(0.5, 1.0, (10, 3)), axis=1)
    correct = rng.uniform(size=(10, 3)) < conf
    spec = ServeSpec(source="live", default_slo="gold",
                     slo_classes={"gold": {"rel_deadline": 0.5}},
                     batching={"mode": "none",
                               "stage_times": [0.01] * 3})
    with Service.from_spec(spec, conf_table=conf,
                           correct_table=correct) as svc:
        h = svc.submit(Request(inputs=None, sample=3))
        metrics = svc.drain()
        assert h.result().sample == 3 and metrics.n_requests == 1
    ```
    """

    def __init__(self, spec: ServeSpec, resources: dict):
        self.spec = spec.validate()
        self.resources = resources
        self.policy = None              # base policy of the last build
        self.executor = None
        self.clock = None
        self.zoo = None                 # ModelZoo of the last build
        self.responses: list = []       # device-mode legacy Response list
        self.snapshots: list = []       # streamed metrics of the last run
        self._handles: dict = {}
        self._slo_names: dict = {}
        self._req_arrivals: dict = {}   # tid -> request (stream) arrival
        self._req_rels: dict = {}       # tid -> relative deadline as issued
        self._n_cancelled = 0
        self._n_bp_rejected = 0         # backpressure: rejected at submit()
        self._n_shed = 0                # backpressure: depth shed at submit()
        self._bp_per_class: dict = {}   # slo name -> backpressure rejects
        self._req_meta: dict = {}       # tid -> (tenant, request_id)
        self._tenant_rejects: dict = {}  # tenant -> front-door quota rejects
        self._closed = False
        self._live: Optional[_Built] = None
        self._live_error: Optional[BaseException] = None
        self._live_realtime: Optional[bool] = None
        self._submitted: set = set()    # unresolved live handles (failure
                                        # fanout; pruned on retire)
        self._thread: Optional[threading.Thread] = None
        self._buffer: list = []         # virtual-clock live submissions
        self._last: Optional[ServiceMetrics] = None
        self.obs = None                 # Tracer of the latest build
        # intake-side audit rows (quota/bound rejects, sheds) raised before
        # or outside the engine loop — drained into the tracer at build
        # time and again when the run finishes
        self._pending_audit: list = []

    @classmethod
    def from_spec(cls, spec: ServeSpec, resources: dict = None,
                  **kw) -> "Service":
        return cls(spec, {**(resources or {}), **kw})

    # -- batching resolution -------------------------------------------
    def _resolve_batching(self):
        b = dict(self.spec.batching or {})
        tm = self.resources.get("time_model")
        self.zoo = None
        if self.spec.models:
            # multi-model serving: the zoo's blended ZooTimeModel replaces
            # the batching-derived table (its per-model dispatch is what
            # the batcher/admission/batch_wcet resolve); ``batching`` keys
            # other than the table — mode/max_batch/charge_formation —
            # still apply
            from repro.serving.zoo import ModelZoo
            zoo = self.resources.get("zoo")
            if zoo is None:
                zoo = ModelZoo.from_spec(self.spec.models)
            self.zoo = zoo
            if tm is None:
                tm = zoo.time_model
            if b.get("mode") == "none":
                return tm, 1, False
            return tm, b.get("max_batch"), bool(b.get("charge_formation",
                                                      True))
        mode = b.get("mode")
        if mode is None:
            mode = "bucketed" if (tm is not None or b.get("buckets")
                                  or b.get("times")) else "none"
        if tm is None:
            stage_times = b.get("stage_times")
            if stage_times is None:
                stage_times = self.resources.get("stage_times")
            if stage_times is None and b.get("times") is None:
                raise ValueError(
                    "batching needs 'stage_times' (spec or resource), "
                    "explicit 'times' rows, or a 'time_model' resource")
            if mode == "none":
                tm = BatchTimeModel.linear(
                    tuple(float(x) for x in stage_times), (1,))
            elif b.get("times") is not None:
                if not b.get("buckets"):
                    raise ValueError("batching 'times' rows need a matching "
                                     "'buckets' list")
                tm = BatchTimeModel(
                    buckets=tuple(int(x) for x in b["buckets"]),
                    times=tuple(tuple(float(t) for t in row)
                                for row in b["times"]))
            else:
                tm = BatchTimeModel.linear(
                    tuple(float(x) for x in stage_times),
                    buckets=tuple(b.get("buckets", DEFAULT_BUCKETS)),
                    marginal=float(b.get("marginal", 0.15)))
        if mode == "none":
            return tm, 1, False
        return tm, b.get("max_batch"), bool(b.get("charge_formation", True))

    # -- component build -----------------------------------------------
    def _component(self, kind: str, name: str, args: dict,
                   ctx: BuildContext):
        inst = self.resources.get(kind)
        if inst is not None:
            return inst
        return resolve(kind, name)(args, ctx)

    def _build(self, stream=None) -> _Built:
        spec = self.spec
        tm, max_batch, charge_formation = self._resolve_batching()
        ctx = BuildContext(spec=spec, resources=self.resources,
                           time_model=tm, max_batch=max_batch)
        policy = self._component("policy", spec.policy, spec.policy_args, ctx)
        ctx.policy = policy
        clock = self._component("clock", spec.clock, spec.clock_args, ctx)
        ctx.clock = clock
        executor = self._component("executor", spec.executor,
                                   spec.executor_args, ctx)
        ctx.executor = executor
        if ctx.time_model is not tm:
            # an executor factory may refine the time model (device-sharded
            # swaps in the dp-scaled bucket set); everything downstream —
            # batcher, admission, §II-B deadline adjustment — prices with it
            tm = ctx.time_model
        admission = self.resources.get("admission")
        if admission is None \
                and (spec.admission.get("mode") not in (None, "off")
                     or spec.admission.get("forecast")):
            cls = AdmissionController
            if self.zoo is not None:
                # price each request against its own model's tables
                from repro.serving.zoo import ZooAdmissionController
                cls = ZooAdmissionController
            if spec.admission.get("forecast"):
                # predictive variant: a fitted arrival process tightens
                # caps / rejects ahead of the forecast burst
                from repro.serving.adaptive import predictive_admission
                admission = predictive_admission(tm, spec.admission,
                                                 base_cls=cls)
            else:
                admission = cls(
                    tm, mode=spec.admission["mode"],
                    headroom=float(spec.admission.get("headroom", 1.0)))
        eff_mb = min(max_batch or tm.max_batch, tm.max_batch)
        ctx.task_factory = self._make_task_factory(executor, tm, eff_mb)
        ctx.stream = stream
        if spec.source == "live" and (stream is not None
                                      or not clock.realtime):
            # buffered live mode: drain() replays the buffered submissions
            # as a (discrete-event) stream
            source = StreamSource(stream or [], ctx.task_factory)
        else:
            source = self._component("source", spec.source, spec.source_args,
                                     ctx)
        self.responses = []
        ztabs = self.resources.get("zoo_tables") if self.zoo is not None \
            else None
        if hasattr(executor, "pop_state"):
            inner = ResponseRecorder(executor, self.responses)
        elif ztabs and all("conf" in d and "correct" in d
                           for d in ztabs.values()):
            # per-model oracle aggregation (repro.serving.zoo)
            from repro.serving.zoo import ZooTableRecorder
            inner = ZooTableRecorder(
                {m: d["conf"] for m, d in ztabs.items()},
                {m: d["correct"] for m, d in ztabs.items()},
                conf_table=self.resources.get("conf_table"),
                correct_table=self.resources.get("correct_table"))
        elif "conf_table" in self.resources \
                and "correct_table" in self.resources:
            inner = TableRecorder(self.resources["conf_table"],
                                  self.resources["correct_table"])
        else:
            inner = None
        streamer = None
        if spec.metrics_interval > 0:
            # local import: the traffic subsystem layers on top of Service
            from repro.serving.traffic.control import MetricsStreamer
            streamer = MetricsStreamer(spec.metrics_interval,
                                       self.resources.get("on_metrics"))
        recorder = ServiceRecorder(self, inner, executor, streamer=streamer)
        tracer = None
        if spec.trace and spec.trace.get("enabled", True):
            from repro.serving.obs import Tracer
            tracer = Tracer.from_config(spec.trace)
            tracer.time_model = tm
            tracer.ingest_pending(self._pending_audit)
        self.obs = tracer
        pol = as_batch_policy(policy, tm, max_batch=max_batch,
                              charge_formation=charge_formation,
                              dp=getattr(executor, "dp", 1))
        core = EngineCore(pol, clock, executor, source, recorder,
                          admission=admission,
                          pipeline_depth=spec.pipeline_depth,
                          dispatch_overhead=spec.dispatch_overhead,
                          policy_cost=spec.policy_cost, max_batch=eff_mb,
                          tracer=tracer)
        recorder.core = core
        if streamer is not None:
            streamer.bind(core, source,
                          inner if isinstance(inner, TableRecorder) else None,
                          service=self)
        # telemetry handles on the latest build (policy.sched_time, custom
        # executor counters, ...)
        self.policy, self.executor, self.clock = policy, executor, clock
        return _Built(core=core, recorder=recorder, clock=clock,
                      source=source)

    def _make_task_factory(self, executor, tm, eff_mb):
        spec = self.spec
        # §II-B deadline adjustment: host overhead + the non-preemptible
        # region, priced at the largest batch this service dispatches.
        # At pipeline_depth <= 2 that region is one batched stage (the
        # legacy engines' rule); at depth >= 3 the executor queues up to
        # depth-1 windows behind the running one, so a newly urgent task
        # can be blocked for that many worst-case stages before it runs
        worst = max(tm.wcet(s, eff_mb) for s in range(tm.num_stages))
        adj = spec.host_overhead + worst * max(1, spec.pipeline_depth - 1)
        cfg = self.resources.get("cfg")
        mandatory = cfg.mandatory_stages if cfg is not None \
            else int(spec.source_args.get("mandatory_stages", 1))
        observer = self.resources.get("observer")  # durable-plane journal
        zoo = self.zoo

        def factory(request, now):
            handle = getattr(request, "_handle", None)
            if handle is not None:
                # claim the request under the handle lock so a concurrent
                # cancel() either wins outright or fails — never both
                with handle._lock:
                    if handle._cancelled:
                        return None
                    handle._claimed = True
            slo = spec.slo_class(getattr(request, "slo", None))
            rel = request.rel_deadline
            if rel is None:
                if slo is None or slo.rel_deadline is None:
                    raise ValueError(
                        "request has no rel_deadline and its SLO class "
                        "defines none")
                rel = slo.rel_deadline
            model = getattr(request, "model", None)
            zm = zoo.model(model) if (zoo is not None
                                      and model is not None) else None
            # per-model stage costs and mandatory depth: the FPTAS,
            # feasibility checks and §II-E swaps all read Task.stage_times,
            # so a zoo task plans against *its own* model's solo WCETs.
            # The §II-B adjustment stays the blended worst case — the
            # non-preemptible region may hold any model's batch.
            task = Task(arrival=now,
                        deadline=request.arrival + rel - adj,
                        stage_times=(zm.time_model.single_times()
                                     if zm is not None
                                     else tm.single_times()),
                        mandatory=zm.mandatory if zm is not None
                        else mandatory,
                        sample=request.sample, client=request.client,
                        seq_len=getattr(request, "seq_len", None),
                        model=model)
            if slo is not None:
                task.weight = slo.utility_weight
                if slo.depth_cap is not None:
                    task.depth_cap = max(task.mandatory, slo.depth_cap)
                self._slo_names[task.tid] = slo.name
            if zm is not None and zm.weight != 1.0:
                # model value composes multiplicatively with the SLO
                # weight (like tenants below): the FPTAS objective sees
                # model worth x class importance
                task.weight = task.weight * zm.weight
            tenant = getattr(request, "tenant", None)
            rid = getattr(request, "request_id", None)
            if tenant is not None or rid is not None:
                self._req_meta[task.tid] = (tenant, rid)
            if tenant is not None:
                # tenant priority composes multiplicatively with the SLO
                # class weight, so the FPTAS utility objective sees it
                tw = float(spec.tenants.get(tenant, {}).get("weight", 1.0))
                if tw != 1.0:
                    task.weight = task.weight * tw
            if getattr(request, "_shed", False):
                # backpressure shed-optional: admitted, but only the
                # mandatory part survives (traffic.control semantics)
                task.depth_cap = task.mandatory
            if hasattr(executor, "register"):
                executor.register(task, request)
            # latency is measured from *request* arrival (the stream
            # offset), not admission time — a request queued behind a long
            # device window still pays its wait (legacy Response semantics)
            self._req_arrivals[task.tid] = request.arrival
            self._req_rels[task.tid] = rel
            if handle is not None:
                self._handles[task.tid] = handle
                handle._task = task
            if observer is not None:
                observer.on_admit(task, request, now)
            return task
        return factory

    # -- batch mode ----------------------------------------------------
    def run(self, stream=None) -> ServiceMetrics:
        """Drive the configured source to completion and return metrics.

        ``stream``: (offset_seconds, Request) iterable for
        ``source="stream"`` (may instead be passed as the ``requests``
        resource); ignored by ``closed-loop``."""
        if self._closed:
            raise RuntimeError("service is closed")
        if self.spec.source == "live":
            raise RuntimeError("live services are driven by submit()/"
                               "drain(), not run()")
        if stream is None:
            stream = self.resources.get("requests")
        if stream is not None:
            stream = list(stream)       # StreamSource sorts by offset itself
        built = self._build(stream)
        if stream:
            warmup = getattr(built.core.executor, "warmup", None)
            if warmup is not None:
                # compile before the clock starts (deadlines are ms-scale)
                warmup(min(stream, key=lambda p: p[0])[1].inputs)
        built.core.run()
        self._finish_streamer(built)
        self._finish_obs(built)
        self._last = built.recorder.result(built.core)
        self._reset_run_counters()
        return self._last

    # -- live mode -----------------------------------------------------
    def _ensure_live(self) -> _Built:
        if self._live is None:
            self._live = self._build()
            if self._live.clock.realtime:
                self._live.clock.start()
                self._thread = threading.Thread(
                    target=self._run_live, daemon=True,
                    name="repro-serving-live")
                self._thread.start()
        return self._live

    def _run_live(self) -> None:
        """Engine-thread body: an engine failure must not strand waiters
        blocked in ``result()`` — fan the error out to every outstanding
        handle and surface it again at ``drain()``."""
        try:
            self._live.core.run()
        except BaseException as exc:        # noqa: BLE001 — fanout, re-raised
            self._live_error = exc
            for h in list(self._submitted):   # snapshot: cancel() mutates
                h._fail(exc)

    def _source_is_live(self) -> bool:
        """Whether this spec's source accepts submissions: ``"live"``, a
        source *resource*, registered factory, or source class carrying a
        truthy ``live`` attribute (e.g. the durable plane's front door)."""
        if self.spec.source == "live":
            return True
        inst = self.resources.get("source")
        target = inst if inst is not None \
            else resolve("source", self.spec.source)
        return bool(getattr(target, "live", False))

    def submit(self, request, slo: Optional[str] = None,
               at: Optional[float] = None, *,
               tenant: Optional[str] = None,
               request_id: Optional[str] = None) -> ResponseHandle:
        """Admit one request (``source="live"`` or any live-capable
        source, e.g. ``"frontdoor"``).  ``slo`` picks the SLO class
        (``spec.default_slo`` otherwise); ``at`` is the virtual arrival
        offset for discrete-event services (defaults to 0); ``tenant`` /
        ``request_id`` label the request for the durable plane
        (``repro.serving.plane``).

        With a bounded intake (``source_args={"bound": N, "overflow":
        ...}``; see ``repro.serving.traffic.control``), an over-bound
        submission either returns an immediately-resolved *rejected*
        handle (``"reject"``) or is admitted with its optional stages
        shed (``"shed-optional"``)."""
        if self._closed:
            raise RuntimeError("service is closed")
        if not self._source_is_live():
            raise RuntimeError("submit() needs a live-capable source "
                               "(spec.source='live'/'frontdoor', or a "
                               "source with live=True; got "
                               f"{self.spec.source!r})")
        if self._live_error is not None:
            raise RuntimeError("serving engine failed while live") \
                from self._live_error
        if tenant is not None:
            request.tenant = tenant
        if request_id is not None:
            request.request_id = request_id
        # fail fast on what the engine thread would otherwise die on:
        # unknown class names, unknown zoo models, and no deadline from
        # any source
        m = getattr(request, "model", None)
        if m is not None and self.spec.models and m not in self.spec.models:
            raise ValueError(f"unknown model {m!r}; defined: "
                             f"{sorted(self.spec.models)}")
        cls = self.spec.slo_class(slo if slo is not None
                                  else getattr(request, "slo", None))
        if request.rel_deadline is None and \
                (cls is None or cls.rel_deadline is None):
            raise ValueError("request has no rel_deadline and its SLO class "
                             "defines none")
        request.slo = slo if slo is not None else getattr(request, "slo",
                                                          None)
        handle = ResponseHandle(self, request)
        bound = self.spec.source_args.get("bound")
        if bound is not None and self._intake_depth() >= int(bound):
            t_sub = 0.0 if at is None else float(at)
            detail = {"bound": int(bound),
                      "intake_depth": self._intake_depth()}
            if self.spec.source_args.get("overflow",
                                         "reject") == "reject":
                return self._reject_overflow(handle, request, cls,
                                             rule="intake-bound",
                                             detail=detail, t=t_sub)
            request._shed = True
            self._n_shed += 1
            self._audit_intake("intake-shed", t_sub, detail, request,
                               cls.name if cls is not None else None,
                               kind="shed")
        request._handle = handle
        self._submitted.add(handle)
        if self._is_realtime():
            live = self._ensure_live()
            live.source.push(live.clock.now() if at is None else at, request)
        else:
            self._buffer.append((0.0 if at is None else float(at), request))
        return handle

    def _intake_depth(self) -> int:
        """Pending (queued, not yet engine-admitted) live submissions."""
        if not self._is_realtime():
            return len(self._buffer)
        return self._ensure_live().source.qsize()

    def _reject_overflow(self, handle: ResponseHandle, request,
                         cls: Optional[SLOClass], *,
                         rule: str = "intake-bound", detail: dict = None,
                         t: float = 0.0) -> ResponseHandle:
        """Bounded-intake fail-fast: resolve the handle rejected without
        the request ever reaching the engine.  ``rule``/``detail`` name
        the decision for the obs audit log (the front door routes its
        tenant-quota rejects here with its own rule)."""
        self._n_bp_rejected += 1
        name = cls.name if cls is not None else None
        if name is not None:
            self._bp_per_class[name] = self._bp_per_class.get(name, 0) + 1
        self._audit_intake(rule, t, detail, request, name, kind="reject")
        handle._resolve(ServiceResponse(
            sample=request.sample, prediction=None, confidence=0.0,
            depth=0, missed=True, latency=0.0, deadline=0.0, slo=name,
            rejected=True))
        return handle

    def _audit_intake(self, rule: str, t: float, detail: Optional[dict],
                      request, slo: Optional[str], *, kind: str) -> None:
        """Record an intake-side scheduler decision (reject/shed before
        the engine ever saw the request) in the obs audit log.  Routed
        straight into the live tracer when one is running, buffered in
        ``_pending_audit`` otherwise (drained at build / run finish)."""
        if not (self.spec.trace and self.spec.trace.get("enabled", True)):
            return
        row = {"rule": rule, "t": float(t), "detail": detail or {},
               "kind": kind}
        rid = getattr(request, "request_id", None)
        if rid is not None:
            row["request_id"] = rid
        tenant = getattr(request, "tenant", None)
        if tenant is not None:
            row["tenant"] = tenant
        if slo is not None:
            row["slo"] = slo
        tracer = self._live.core.tracer if self._live is not None else None
        if tracer is not None:
            tracer.ingest_pending([row])
        else:
            self._pending_audit.append(row)

    def _is_realtime(self) -> bool:
        """Whether live submissions go to a background engine (wall clock)
        or buffer for drain() — decided from the actual clock the build
        will use (a clock *resource* overrides the spec key)."""
        if self._live_realtime is None:
            clock = self.resources.get("clock")
            if clock is None:
                ctx = BuildContext(spec=self.spec, resources=self.resources)
                clock = resolve("clock", self.spec.clock)(
                    self.spec.clock_args, ctx)
            self._live_realtime = bool(getattr(clock, "realtime", False))
        return self._live_realtime

    def drain(self) -> ServiceMetrics:
        """Stop intake, finish everything in flight, return final metrics.

        Idempotent and exception-safe: the live build is detached
        *before* anything can raise, so an engine failure surfaces here
        exactly once (outstanding handles were already resolved with the
        same error by the fanout) and a second ``drain()``/``close()``
        returns instead of raising again or hanging on a dead engine."""
        live, self._live = self._live, None
        if live is not None:
            live.source.close()
            if self._thread is not None:
                self._thread.join()
                self._thread = None
            err, self._live_error = self._live_error, None
            if err is not None:
                raise RuntimeError("serving engine failed while live") \
                    from err
            self._finish_streamer(live)
            self._finish_obs(live)
            self._last = live.recorder.result(live.core)
            self._reset_run_counters()
            return self._last
        if self._buffer:
            buf, self._buffer = self._buffer, []
            built = self._build(sorted(buf, key=lambda p: p[0]))
            try:
                built.core.run()
            except BaseException as exc:
                # same contract as the wall-clock path: no waiter is left
                # stranded on a handle whose engine died
                for h in list(self._submitted):
                    h._fail(exc)
                raise
            self._finish_streamer(built)
            self._finish_obs(built)
            self._last = built.recorder.result(built.core)
            self._reset_run_counters()
            return self._last
        return self._last if self._last is not None else self.metrics()

    def _finish_streamer(self, built: _Built) -> None:
        streamer = built.recorder.streamer
        if streamer is not None:
            streamer.flush(built.core.makespan)
            self.snapshots = list(streamer.snapshots)

    def _finish_obs(self, built: _Built) -> None:
        tracer = built.core.tracer
        if tracer is not None:
            tracer.ingest_pending(self._pending_audit)
            tracer.close()          # writes configured export files

    def _reset_run_counters(self) -> None:
        """Fresh-per-run semantics for the intake/backpressure counters on
        a reused Service: the metrics just returned keep this run's
        counts; the next ``run()``/``drain()`` starts from zero, matching
        ``DeviceExecutor.device_time_stats()`` / ``cache_stats()`` (and
        keeping ``MetricsStreamer`` window deltas from going stale)."""
        self._n_cancelled = 0
        self._n_bp_rejected = 0
        self._n_shed = 0
        self._bp_per_class = {}
        self._tenant_rejects = {}

    def close(self) -> None:
        """Graceful shutdown: drain, then refuse further work.

        Idempotent, and exception-safe against a failed engine: the
        failure already reached every outstanding handle (``result()``
        raises it), so close() completes the shutdown instead of
        re-raising — callers that want the error call ``drain()``."""
        if self._closed:
            return
        self._closed = True
        try:
            self.drain()
        except Exception:
            # the engine error was fanned out to the handles; shutdown
            # itself must still finish (context-manager exit paths)
            pass

    def __enter__(self) -> "Service":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- telemetry -----------------------------------------------------
    def metrics(self) -> ServiceMetrics:
        """Latest metrics: a live snapshot while serving, else the last
        completed run's result."""
        if self._live is not None:
            return self._live.recorder.result(self._live.core)
        if self._last is not None:
            return self._last
        return ServiceMetrics(
            accuracy=0.0, miss_rate=0.0, mean_depth=0.0, mean_conf=0.0,
            overhead_frac=0.0, n_requests=0, per_request=[],
            components=dict(policy=self.spec.policy,
                            executor=self.spec.executor,
                            clock=self.spec.clock, source=self.spec.source))
