"""Named traffic scenarios — the catalog the benchmarks and tests share.

A :class:`Scenario` is a JSON-able description of *offered load relative
to service capacity*: its arrival-process rates are load **factors**
scaled at build time by the nominal full-depth service rate
``1 / sum(stage_times)`` (the unbatched engine's best sustained
throughput when every request runs all stages).  A factor of 2.0 is the
"2x sustained overload" regime of the headline claim — impossible to
express with the closed-loop workload, which can never offer more than
the server completes.

Catalog (see README for the table):

==============  ============================================================
``steady``      Poisson at 0.6x capacity — the in-regime baseline.
``2x-overload`` Poisson at 2.0x capacity, sustained — the headline claim:
                admission/shedding holds deadline misses near zero with
                bounded accuracy loss; uncontrolled EDF collapses.
``flash-crowd`` 0.7x base with a 5x rectangular spike — transient
                overload; assert on windowed metrics, not aggregates.
``diurnal``     sinusoidal 0.3x–1.8x ramp — rankings under a moving
                operating point.
``model-mix``   two-model zoo (llm + vision) at 2.0x capacity — the
                cross-model shedding claim (``repro.serving.zoo``): each
                class stamps a model id into ``Request.model``.
==============  ============================================================

Every scenario shares one three-tier SLO mix (gold/silver/bronze:
descending deadline and utility weight), so per-class breakdowns compare
across scenarios.
"""
from __future__ import annotations

import dataclasses

from repro.serving.service import ServeSpec

# arrival-config keys that are load factors (scaled by the nominal rate);
# everything else (dwell times, spike instants, periods) is absolute seconds
_RATE_KEYS = ("rate", "rate_on", "rate_off", "base_rate", "peak_rate",
              "spike_rate")

#: shared SLO tiers: relative deadline (s), utility weight — the per-class
#: request mix every scenario draws from
SLO_CLASSES = {
    "gold": {"rel_deadline": 0.24, "utility_weight": 2.0},
    "silver": {"rel_deadline": 0.14, "utility_weight": 1.0},
    "bronze": {"rel_deadline": 0.07, "utility_weight": 0.5},
}

DEFAULT_MIX = ({"slo": "gold", "share": 0.2},
               {"slo": "silver", "share": 0.5},
               {"slo": "bronze", "share": 0.3})

#: two-model zoo mix (``repro.serving.zoo``): an expensive high-value
#: "llm" head and a cheap "vision" model sharing one device, split
#: across the SLO tiers — what the ``model-mix`` scenario stamps into
#: ``Request.model``
MODEL_MIX = ({"slo": "gold", "share": 0.15, "model": "llm"},
             {"slo": "silver", "share": 0.25, "model": "llm"},
             {"slo": "gold", "share": 0.15, "model": "vision"},
             {"slo": "silver", "share": 0.25, "model": "vision"},
             {"slo": "bronze", "share": 0.2, "model": "vision"})


def nominal_rate(stage_times) -> float:
    """Full-depth, singleton-batch service rate (requests/second)."""
    return 1.0 / float(sum(stage_times))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named load shape; ``arrival`` rates are load factors."""

    name: str
    description: str
    arrival: dict
    n_requests: int = 600
    mix: tuple = DEFAULT_MIX

    def scaled_arrival(self, stage_times) -> dict:
        nom = nominal_rate(stage_times)
        return {k: (v * nom if k in _RATE_KEYS else v)
                for k, v in self.arrival.items()}

    def source_args(self, stage_times, *, n_requests: int = None,
                    seed: int = 0) -> dict:
        return dict(arrival=self.scaled_arrival(stage_times),
                    mix=[dict(c) for c in self.mix],
                    n_requests=n_requests or self.n_requests, seed=seed)


SCENARIOS = {
    s.name: s for s in (
        Scenario("steady",
                 "Poisson at 0.6x capacity: everyone should do well",
                 {"kind": "poisson", "rate": 0.6}),
        Scenario("2x-overload",
                 "sustained 2x capacity: the admission-control claim",
                 {"kind": "poisson", "rate": 2.0}),
        Scenario("flash-crowd",
                 "0.7x base, 5x spike at t=2s for 1.5s: transient overload",
                 {"kind": "flash-crowd", "base_rate": 0.7, "spike_rate": 5.0,
                  "spike_at": 2.0, "spike_len": 1.5}),
        Scenario("diurnal",
                 "sinusoidal 0.3x-1.8x ramp, 8s period: moving load",
                 {"kind": "diurnal", "base_rate": 0.3, "peak_rate": 1.8,
                  "period": 8.0}),
        Scenario("model-mix",
                 "two-model zoo (llm + vision) at 2x capacity: "
                 "cross-model shedding under mixed overload",
                 {"kind": "poisson", "rate": 2.0}, mix=MODEL_MIX),
    )
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"no scenario named {name!r}; "
                       f"available: {sorted(SCENARIOS)}") from None


def scenario_spec(name: str, *, policy: str = "rtdeepiot",
                  policy_args: dict = None, admission: dict = None,
                  stage_times, n_requests: int = None, seed: int = 0,
                  metrics_interval: float = 0.0, **spec_kw) -> ServeSpec:
    """The scenario as a ready-to-run ``ServeSpec`` (oracle executor,
    virtual clock, ``traffic`` source, unbatched pricing) — resources
    (``conf_table``/``correct_table``) still come from the caller."""
    scen = get_scenario(name)
    return ServeSpec(
        policy=policy, policy_args=policy_args or {},
        executor="oracle", clock="virtual", source="traffic",
        source_args=scen.source_args(stage_times, n_requests=n_requests,
                                     seed=seed),
        batching={"mode": "none",
                  "stage_times": [float(x) for x in stage_times]},
        admission=admission or {}, slo_classes=dict(SLO_CLASSES),
        metrics_interval=metrics_interval, **spec_kw)
