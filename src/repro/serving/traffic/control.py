"""Overload control: bounded intake backpressure + streamed metrics.

**Backpressure** (enforced by ``Service.submit`` — config lives in
``spec.source_args`` for ``source="live"``)::

    {"bound": 32, "overflow": "reject" | "shed-optional"}

* ``bound`` — max pending intake (queued, not yet admitted by the
  engine).  Below it, submissions flow untouched.
* ``"reject"`` — an over-bound ``submit()`` returns an *immediately
  resolved* rejected ``ResponseHandle`` (fail fast: the caller can retry
  elsewhere); the request never reaches the engine.  Counted in
  ``ServiceMetrics.rejected`` and the per-class ``rejected`` breakdown.
* ``"shed-optional"`` — the request is admitted but its depth is pinned
  to the mandatory part through the admission-control channel
  (``Task.depth_cap``, which every policy's depth assignment clamps
  against): under pressure the queue sheds *optional* work instead of
  whole requests — the imprecise-computation answer to overload.
  Counted in ``ServiceMetrics.capped``.

**Metrics streaming**: a :class:`MetricsStreamer` turns retirements into
periodic :class:`ServiceSnapshot` rows — *windowed* miss rate, accuracy,
mean depth, queue depth, utilization — delivered to a callback, so
scenarios can assert on transient behavior (the flash-crowd spike, the
recovery after it) instead of end-of-run aggregates only.  Enable with
``ServeSpec(metrics_interval=0.5)`` + an ``on_metrics`` callable
resource.  Snapshots are emitted as serving events cross interval
boundaries (event-driven, so a virtual clock streams them too).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.service import \
    _OVERFLOW_MODES as OVERFLOW_MODES  # noqa: F401 — public re-export


@dataclasses.dataclass(frozen=True)
class ServiceSnapshot:
    """One streamed metrics window ``(t - interval, t]``."""

    t: float                    # service time at emission
    n: int                      # requests retired in the window
    miss_rate: float            # misses / n (rejected count as misses)
    accuracy: Optional[float]   # oracle-table runs only, else None
    mean_depth: float           # over non-missed retirements
    queue_depth: int            # source arrivals still pending
    active: int                 # tasks currently in the engine
    utilization: float          # device-busy fraction of the window
    rejected: int               # admission + backpressure rejects
    capped: int                 # depth-capped (incl. shed-optional)
    # pending-but-not-admitted intake: source queue + the facade's
    # virtual-clock submit buffer (uniform across sources)
    intake_depth: int = 0
    # tenant -> {"queued": source backlog, "n": retired this window}
    # (multi-tenant front door, repro.serving.plane)
    per_tenant: dict = dataclasses.field(default_factory=dict)
    # device-executor telemetry (zero for modeled executors): host/device
    # seconds spent this window and hidden-state-cache residents now
    host_time: float = 0.0
    device_time: float = 0.0
    cache_live: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class MetricsStreamer:
    """Windowed snapshot emitter driven by recorder events.

    ``observe(record, now)`` is called per retirement, ``tick(now)`` on
    any other serving event; whenever ``now`` crosses the next interval
    boundary the window is aggregated, handed to ``callback``, and reset.
    """

    def __init__(self, interval: float, callback):
        if interval <= 0:
            raise ValueError("metrics_interval must be > 0")
        self.interval = float(interval)
        self.callback = callback
        self.snapshots: list = []
        self._window: list = []
        self._next_t = self.interval
        self._last_t = 0.0
        self._last_busy = 0.0
        self._last_host = 0.0
        self._last_dev = 0.0
        self._last_rejected = 0
        self._last_capped = 0
        # bound by ServiceRecorder once the engine exists
        self.core = None
        self.source = None
        self.inner = None           # TableRecorder when oracle-backed
        self.service = None         # backpressure counters live here

    def bind(self, core, source, inner, service=None) -> None:
        self.core = core
        self.source = source
        self.inner = inner
        self.service = service

    # ------------------------------------------------------------------
    def observe(self, record: dict, now: float) -> None:
        self._window.append(record)
        self.tick(now)

    def tick(self, now: float) -> None:
        if now >= self._next_t:
            self._emit(now)

    def flush(self, now: float) -> None:
        """End of run: emit whatever the last partial window holds."""
        if self._window or now > self._last_t:
            self._emit(now)

    # ------------------------------------------------------------------
    def _counts(self) -> tuple:
        # with the obs layer on, its registry is the single accumulation
        # point (admission rejects/caps + intake rejects/sheds land there
        # as they happen) — read it instead of re-deriving the split
        tracer = getattr(self.core, "tracer", None) if self.core else None
        reg = tracer.registry if tracer is not None else None
        if reg is not None:
            return (int(reg.counter("requests_rejected").value),
                    int(reg.counter("requests_capped").value))
        adm = getattr(self.core, "admission", None) if self.core else None
        rejected = adm.rejected if adm is not None else 0
        capped = adm.capped if adm is not None else 0
        if self.service is not None:
            rejected += self.service._n_bp_rejected
            capped += self.service._n_shed
        return rejected, capped

    def _emit(self, now: float) -> None:
        w = self._window
        n = len(w)
        missed = sum(1 for r in w if r["missed"])
        ok = [r for r in w if not r["missed"]]
        acc = None
        if self.inner is not None and hasattr(self.inner, "finished"):
            tids = {r["tid"] for r in w}
            fin = [f for f in self.inner.finished if f["tid"] in tids]
            if fin:
                acc = sum(f["correct"] for f in fin) / len(fin)
        ex = self.core.executor if self.core is not None else None
        busy = getattr(ex, "total_busy", 0.0)
        dts = getattr(ex, "device_time_stats", None)
        times = dts() if dts is not None else {}
        host_t = float(times.get("host_time", 0.0))
        dev_t = float(times.get("device_time", 0.0))
        cst = getattr(ex, "cache_stats", None)
        span = max(now - self._last_t, 1e-12)
        rejected, capped = self._counts()
        qsize = self.source.qsize() if self.source is not None else 0
        intake = qsize
        if self.service is not None:
            intake += len(self.service._buffer)
        per_tenant: dict = {}
        if self.source is not None and hasattr(self.source, "tenant_depths"):
            for t, d in self.source.tenant_depths().items():
                per_tenant[t] = dict(queued=d, n=0)
        for r in w:
            if r.get("tenant") is not None:
                entry = per_tenant.setdefault(r["tenant"],
                                              dict(queued=0, n=0))
                entry["n"] += 1
        snap = ServiceSnapshot(
            t=now, n=n, miss_rate=(missed / n) if n else 0.0, accuracy=acc,
            mean_depth=(sum(r["depth"] for r in ok) / len(ok)) if ok else 0.0,
            queue_depth=qsize,
            active=len(self.core._active) if self.core is not None else 0,
            utilization=min(1.0, (busy - self._last_busy) / span),
            rejected=rejected - self._last_rejected,
            capped=capped - self._last_capped,
            intake_depth=intake, per_tenant=per_tenant,
            host_time=host_t - self._last_host,
            device_time=dev_t - self._last_dev,
            cache_live=int(cst()["live"]) if cst is not None else 0)
        self.snapshots.append(snap)
        if self.callback is not None:
            self.callback(snap)
        self._window = []
        self._last_t = now
        self._last_busy = busy
        self._last_host, self._last_dev = host_t, dev_t
        self._last_rejected, self._last_capped = rejected, capped
        while self._next_t <= now:
            self._next_t += self.interval
