"""Seeded open-loop arrival processes (paper §IV's missing other half).

The paper evaluates the scheduler under one *closed-loop* K-client
workload — the server's own completions pace the offered load, so the
system can never be pushed past saturation.  Real services face the
opposite regime: arrivals keep coming on *their* schedule whether or not
the server keeps up (DeepRT's bursty admission-control evaluation; the
"Adaptive Scheduling for Edge-Assisted DNN Serving" observation that
policy rankings flip between steady and bursty traffic).  This module
provides the arrival half of that regime as composable, seeded processes:

* ``PoissonArrivals``    — homogeneous rate λ (steady traffic).
* ``MMPPArrivals``       — 2-state Markov-modulated Poisson process
  (on/off bursts: exponential dwell in a quiet and a burst state, Poisson
  arrivals at the state's rate).
* ``DiurnalArrivals``    — sinusoidal rate ramp between a trough and a
  peak over a configurable period (the day/night load curve, compressed).
* ``FlashCrowdArrivals`` — constant base rate with a rectangular spike
  (rate × ``spike_rate`` during ``[spike_at, spike_at + spike_len]``).

Every process is a pure function of the ``numpy`` Generator handed to
``sample`` — same seed, same arrival sequence, across processes and hosts
(tests/test_traffic.py pins this).  Time-varying processes sample by
Lewis–Shedler thinning against their rate bound, so one uniform draw pair
per candidate keeps the draw order reproducible.

Example — every kind builds from its JSON-able description, and the same
seed always reproduces the same offsets:

```python
import numpy as np
from repro.serving.traffic.generators import make_arrival_process

for kind, args in (("poisson", {"rate": 50.0}),
                   ("mmpp", {"rate_on": 120.0, "rate_off": 10.0,
                             "mean_on": 0.2, "mean_off": 0.8}),
                   ("diurnal", {"base_rate": 10.0, "peak_rate": 80.0,
                                "period": 4.0}),
                   ("flash-crowd", {"base_rate": 30.0, "spike_rate": 5.0,
                                    "spike_at": 1.0, "spike_len": 0.5})):
    p = make_arrival_process(kind, **args)
    offs = p.sample(np.random.default_rng(7), n=100)
    assert len(offs) == 100 and (np.diff(offs) >= 0).all()
    again = p.sample(np.random.default_rng(7), n=100)
    assert (offs == again).all()          # seeded determinism
```
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# registry of arrival kinds: name -> constructor (dataclass below)
ARRIVAL_KINDS: dict = {}


def register_arrival(kind: str):
    def deco(cls):
        ARRIVAL_KINDS[kind] = cls
        cls.kind = kind
        return cls
    return deco


def make_arrival_process(kind: str, **args) -> "ArrivalProcess":
    """Build an arrival process from its JSON-able description."""
    try:
        cls = ARRIVAL_KINDS[kind]
    except KeyError:
        raise KeyError(f"no arrival process registered under {kind!r}; "
                       f"available: {sorted(ARRIVAL_KINDS)}") from None
    return cls(**args)


class ArrivalProcess:
    """Base: a (possibly time-varying) rate λ(t) sampled into offsets."""

    kind = "base"

    @property
    def mean_rate(self) -> float:
        """Long-run average arrivals/second (tests check empirical rates
        against this)."""
        raise NotImplementedError

    def rate_at(self, t: float) -> float:
        """Instantaneous rate λ(t)."""
        raise NotImplementedError

    def rate_bound(self) -> float:
        """An upper bound on λ(t) — the thinning envelope."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    def sample(self, rng: np.random.Generator, *, n: int = None,
               horizon: float = None) -> np.ndarray:
        """Sorted arrival offsets: the first ``n`` arrivals, or every
        arrival in ``[0, horizon)`` (at least one bound required).

        Default implementation: thinning against ``rate_bound()``.
        """
        if n is None and horizon is None:
            raise ValueError("sample() needs n and/or horizon")
        lam = self.rate_bound()
        if lam <= 0:
            return np.empty(0)
        out, t = [], 0.0
        while (n is None or len(out) < n) \
                and (horizon is None or t < horizon):
            t += rng.exponential(1.0 / lam)
            if horizon is not None and t >= horizon:
                break
            if rng.uniform() * lam <= self.rate_at(t):
                out.append(t)
        return np.asarray(out)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind
        return d


@register_arrival("poisson")
@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate`` per second."""

    rate: float

    @property
    def mean_rate(self) -> float:
        return self.rate

    def rate_at(self, t: float) -> float:
        return self.rate

    def rate_bound(self) -> float:
        return self.rate

    def sample(self, rng, *, n=None, horizon=None) -> np.ndarray:
        # exact gap sampling (no thinning rejections to replay)
        if n is None and horizon is None:
            raise ValueError("sample() needs n and/or horizon")
        if self.rate <= 0:
            return np.empty(0)
        if n is not None:
            t = np.cumsum(rng.exponential(1.0 / self.rate, size=n))
            return t if horizon is None else t[t < horizon]
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / self.rate)
            if t >= horizon:
                return np.asarray(out)
            out.append(t)


@register_arrival("mmpp")
@dataclasses.dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process (on/off bursts).

    Dwell times in the quiet (``rate_off``) and burst (``rate_on``)
    states are exponential with means ``mean_off`` / ``mean_on`` seconds;
    within a state, arrivals are Poisson at that state's rate.  The
    process starts quiet.
    """

    rate_on: float
    rate_off: float
    mean_on: float = 0.5
    mean_off: float = 1.5

    @property
    def mean_rate(self) -> float:
        tot = self.mean_on + self.mean_off
        return (self.rate_on * self.mean_on
                + self.rate_off * self.mean_off) / tot

    def rate_bound(self) -> float:
        return max(self.rate_on, self.rate_off)

    def sample(self, rng, *, n=None, horizon=None) -> np.ndarray:
        if n is None and horizon is None:
            raise ValueError("sample() needs n and/or horizon")
        out, t, on = [], 0.0, False
        while (n is None or len(out) < n) \
                and (horizon is None or t < horizon):
            dwell = rng.exponential(self.mean_on if on else self.mean_off)
            rate = self.rate_on if on else self.rate_off
            t_end = t + dwell
            while rate > 0:
                t += rng.exponential(1.0 / rate)
                if t >= t_end or (horizon is not None and t >= horizon):
                    break
                out.append(t)
                if n is not None and len(out) >= n:
                    break
            t = min(t, t_end) if rate > 0 else t_end
            on = not on
        return np.asarray(out[:n] if n is not None else out)

    def rate_at(self, t: float) -> float:    # pragma: no cover - not thinned
        raise NotImplementedError("MMPP rate is state-dependent")


@register_arrival("diurnal")
@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal ramp: λ(t) sweeps ``base_rate`` → ``peak_rate`` → back
    over each ``period`` seconds (trough at t = 0)."""

    base_rate: float
    peak_rate: float
    period: float = 10.0

    @property
    def mean_rate(self) -> float:
        return 0.5 * (self.base_rate + self.peak_rate)

    def rate_at(self, t: float) -> float:
        swing = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / self.period))
        return self.base_rate + (self.peak_rate - self.base_rate) * swing

    def rate_bound(self) -> float:
        return max(self.base_rate, self.peak_rate)


@register_arrival("flash-crowd")
@dataclasses.dataclass(frozen=True)
class FlashCrowdArrivals(ArrivalProcess):
    """Constant ``base_rate`` with a rectangular spike to ``spike_rate``
    during ``[spike_at, spike_at + spike_len]`` — the load a scheduler
    cannot have planned for."""

    base_rate: float
    spike_rate: float
    spike_at: float = 1.0
    spike_len: float = 1.0

    @property
    def mean_rate(self) -> float:
        """Rate averaged over ``[0, spike_at + 2 * spike_len]`` (a
        representative window; the process is not periodic)."""
        span = self.spike_at + 2.0 * self.spike_len
        burst = self.spike_len * (self.spike_rate - self.base_rate)
        return self.base_rate + burst / span

    def rate_at(self, t: float) -> float:
        if self.spike_at <= t < self.spike_at + self.spike_len:
            return self.spike_rate
        return self.base_rate

    def rate_bound(self) -> float:
        return max(self.base_rate, self.spike_rate)
