"""Traffic subsystem: open-loop arrival generators, trace record/replay,
and overload control — ``repro.serving.traffic``.

Everything plugs into the serving runtime through the registry front door
(``register_source("traffic")`` / ``register_source("replay")``) and the
``Service`` facade (backpressure + metrics streaming) — no core-loop
changes.  Importing this package (``repro.serving`` does it) registers
the source keys.
"""
from repro.serving.traffic.control import (OVERFLOW_MODES, MetricsStreamer,
                                           ServiceSnapshot)
from repro.serving.traffic.generators import (ARRIVAL_KINDS, ArrivalProcess,
                                              DiurnalArrivals,
                                              FlashCrowdArrivals,
                                              MMPPArrivals, PoissonArrivals,
                                              make_arrival_process)
from repro.serving.traffic.mix import RequestMix, TrafficClass
from repro.serving.traffic.scenarios import (SCENARIOS, SLO_CLASSES, Scenario,
                                             get_scenario, nominal_rate,
                                             scenario_spec)
from repro.serving.traffic.source import TrafficSource
from repro.serving.traffic.trace import (TraceEvent, TraceRecorder,
                                         admission_signature,
                                         arrival_signature, load_trace,
                                         record_trace, replay_stream,
                                         verify_replay)

__all__ = ["ARRIVAL_KINDS", "ArrivalProcess", "PoissonArrivals",
           "MMPPArrivals", "DiurnalArrivals", "FlashCrowdArrivals",
           "make_arrival_process", "RequestMix", "TrafficClass",
           "TrafficSource", "TraceEvent", "TraceRecorder", "record_trace",
           "load_trace", "replay_stream", "arrival_signature",
           "admission_signature", "verify_replay", "MetricsStreamer",
           "ServiceSnapshot", "OVERFLOW_MODES", "SCENARIOS", "SLO_CLASSES",
           "Scenario", "get_scenario", "nominal_rate", "scenario_spec"]
