"""``register_source("traffic")`` — the open-loop front of the subsystem.

A ``TrafficSource`` composes a seeded arrival process with a per-class
request mix into a pre-materialized ``(offset, Request)`` stream and
feeds it through the engine's task factory, exactly like ``StreamSource``
— which is the point: arrivals keep their schedule regardless of
completions (unlike ``ClosedLoopSource``, whose clients wait for their
previous request), so sustained overload, bursts, flash crowds and
diurnal ramps are all expressible.

Registered from *outside* ``repro.serving.runtime`` — the registry
extension-point proof at subsystem scale: no core-loop changes.

``source_args`` (all JSON-able, so the whole scenario round-trips through
``ServeSpec``)::

    {"arrival": {"kind": "poisson", "rate": 80.0},   # generators.py kinds
     "mix": [{"slo": "gold", "share": 1.0}, ...],    # mix.py classes
     "n_requests": 500,          # and/or "horizon": seconds
     "seed": 0}

Resources: ``n_samples`` (or a ``conf_table`` whose first axis is the
sample count) sizes the sample draw; an optional ``traffic_inputs``
callable maps sample index -> input pytree for device executors.
"""
from __future__ import annotations

import numpy as np

from repro.serving.registry import register_source
from repro.serving.runtime.sources import StreamSource
from repro.serving.traffic.generators import (ArrivalProcess,
                                              make_arrival_process)
from repro.serving.traffic.mix import RequestMix


class TrafficSource(StreamSource):
    """Open-loop generated traffic behind the ``StreamSource`` contract."""

    def __init__(self, arrival: ArrivalProcess, mix: RequestMix,
                 task_factory, *, n_requests: int = None,
                 horizon: float = None, seed: int = 0):
        rng = np.random.default_rng(seed)
        offsets = arrival.sample(rng, n=n_requests, horizon=horizon)
        super().__init__(mix.stream(rng, offsets), task_factory)
        self.arrival = arrival
        self.mix = mix
        self.seed = seed

    @property
    def offsets(self) -> np.ndarray:
        return np.asarray([off for off, _ in self.pending])


@register_source("traffic")
def _make_traffic(args: dict, ctx):
    arrival_cfg = dict(args.get("arrival") or {"kind": "poisson", "rate": 1.0})
    arrival = make_arrival_process(arrival_cfg.pop("kind"), **arrival_cfg)
    n_samples = ctx.resources.get("n_samples")
    if n_samples is None:
        table = ctx.resources.get("conf_table")
        if table is None:
            raise KeyError("source='traffic' needs an 'n_samples' or "
                           "'conf_table' resource to size the sample draw")
        n_samples = int(np.asarray(table).shape[0])
    mix = RequestMix(args.get("mix") or [{}], n_samples,
                     inputs_fn=ctx.resources.get("traffic_inputs"))
    n_requests = args.get("n_requests")
    horizon = args.get("horizon")
    if n_requests is None and horizon is None:
        raise ValueError("source='traffic' needs 'n_requests' and/or "
                         "'horizon' in source_args")
    return TrafficSource(arrival, mix, ctx.task_factory,
                         n_requests=n_requests, horizon=horizon,
                         seed=int(args.get("seed", 0)))
