"""Trace record/replay: regression-grade load tests from recorded runs.

**Record**: :class:`TraceRecorder` captures a finished run's request
sequence — ``(offset, sample, slo, rel_deadline, client)`` straight from
``ServiceMetrics.per_request`` (which the :class:`ServiceRecorder` orders
by admission) — plus each request's observed outcome (depth, missed,
rejected, latency, deadline) into a JSONL trace: one header line, one
event line per request, sorted by admission order.

**Replay**: ``register_source("replay")`` re-injects a trace through the
engine's task factory as a plain request stream.  Under the virtual clock
with the same ``ServeSpec`` (same batching/time model, SLO classes,
admission config and policy), the engine is a deterministic function of
the arrival sequence — so a replay reproduces the original run's arrival
order *and* admission decisions bit-for-bit
(:func:`verify_replay` checks exactly that; the ``traffic`` benchmark
figure records the result as a claim).

Scope: bit-for-bit holds for factory-built sources (``traffic`` /
``stream`` / ``live``), where ``rel_deadline`` passes through the same
§II-B adjustment again.  Closed-loop traces replay with re-adjusted
deadlines (the legacy source applies no adjustment), which is useful for
load shape but not bit-exact.

Schema: a trace line is a
:class:`~repro.serving.plane.records.Record` (the codec shared with the
durable plane's write-ahead journal) with the default ``EVENT`` kind::

    {"type": "header", "version": 2, "n_events": N,
     "source": "...", "spec": {...}?}            # spec: optional ServeSpec
    {"offset": 0.0123, "sample": 42, "client": 0, "slo": "gold",
     "rel_deadline": 0.2,
     "outcome": {"depth": 2, "missed": false, "rejected": false,
                 "latency": 0.017, "deadline": 0.2023, "conf": 0.91,
                 "weight": 2.0}}

Version history: 1 — the same event lines, before the schema was unified
with the journal (no ``kind``/``tenant``/``request_id`` fields).
Version-1 traces load unchanged (``EVENT`` is the default kind), and
``EVENT`` rows without plane fields still serialize byte-identically to
version 1.
"""
from __future__ import annotations

import json

from repro.serving.engine import Request  # noqa: F401 — legacy re-export
from repro.serving.plane.records import RECORD_VERSION, Record
from repro.serving.registry import register_source
from repro.serving.runtime.sources import StreamSource

TRACE_VERSION = RECORD_VERSION

_OUTCOME_KEYS = ("depth", "missed", "rejected", "latency", "deadline",
                 "conf", "weight", "depth_cap")

#: one schema for traces and the journal (repro.serving.plane.records):
#: a trace event is a Record of the default ``EVENT`` kind
TraceEvent = Record


class TraceRecorder:
    """Collects :class:`TraceEvent` rows from finished runs.

    ``capture(metrics)`` pulls every request of a ``ServiceMetrics`` /
    ``SimResult`` (its ``per_request`` rows must be present — run the
    service, then capture); ``write(path)`` emits the JSONL file.
    """

    def __init__(self, source: str = "unknown", spec=None):
        self.source = source
        self.spec = spec            # optional ServeSpec (stored in header)
        self.events: list = []

    def capture(self, metrics) -> list:
        recs = sorted(metrics.per_request, key=lambda r: r["tid"])
        for r in recs:
            offset = float(r.get("offset", r["arrival"]))
            rel = r.get("rel_deadline")
            if rel is None:
                # closed-loop records: effective (already-adjusted) slack
                rel = float(r["deadline"]) - offset
            outcome = {k: r[k] for k in _OUTCOME_KEYS if k in r}
            self.events.append(TraceEvent(
                offset=offset, sample=int(r["sample"]),
                client=int(r.get("client", 0)), slo=r.get("slo"),
                rel_deadline=float(rel), outcome=outcome,
                tenant=r.get("tenant"), request_id=r.get("request_id"),
                model=r.get("model")))
        return self.events

    def header(self) -> dict:
        h = dict(type="header", version=TRACE_VERSION,
                 n_events=len(self.events), source=self.source)
        if self.spec is not None:
            h["spec"] = self.spec.to_dict()
        return h

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(json.dumps(self.header()) + "\n")
            for ev in self.events:
                f.write(ev.to_json() + "\n")
        return path


def record_trace(metrics, path: str, *, source: str = "unknown",
                 spec=None) -> TraceRecorder:
    """One-shot: capture ``metrics`` and write the JSONL trace."""
    rec = TraceRecorder(source=source, spec=spec)
    rec.capture(metrics)
    rec.write(path)
    return rec


def load_trace(path: str) -> tuple:
    """Parse a JSONL trace -> (header dict, [TraceEvent]).  Reads both
    version-1 (pre-unification) and version-2 files."""
    header, events = {}, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if d.get("type") == "header":
                header = d
            else:
                events.append(TraceEvent.from_dict(d))
    v = header.get("version")
    if v is not None and int(v) > TRACE_VERSION:
        raise ValueError(f"trace {path!r} is version {v}; this reader "
                         f"handles <= {TRACE_VERSION}")
    n = header.get("n_events")
    if n is not None and n != len(events):
        raise ValueError(f"trace {path!r} declares {n} events, "
                         f"found {len(events)}")
    return header, events


def replay_stream(events) -> list:
    """[(offset, Request)] re-materialized from trace events, in recorded
    admission order."""
    return [(ev.offset, ev.request()) for ev in events]


def arrival_signature(per_request) -> list:
    """The replay-comparable arrival sequence of a run: per admitted-order
    request, (offset, sample, slo, rel_deadline)."""
    recs = sorted(per_request, key=lambda r: r["tid"])
    return [(round(float(r.get("offset", r["arrival"])), 12), r["sample"],
             r.get("slo"), r.get("rel_deadline"), r.get("model"))
            for r in recs]


def admission_signature(per_request) -> list:
    """The replay-comparable admission/outcome sequence: per
    admitted-order request, (rejected, depth_cap, depth, missed)."""
    recs = sorted(per_request, key=lambda r: r["tid"])
    return [(bool(r["rejected"]), r.get("depth_cap"), r["depth"],
             bool(r["missed"])) for r in recs]


def verify_replay(original, replayed) -> dict:
    """Compare two runs' per_request rows: did the replay reproduce the
    original's arrival order and admission decisions bit-for-bit?"""
    arr_ok = arrival_signature(original) == arrival_signature(replayed)
    adm_ok = admission_signature(original) == admission_signature(replayed)
    return dict(arrival_order=arr_ok, admission_decisions=adm_ok,
                bitwise=arr_ok and adm_ok)


@register_source("replay")
def _make_replay(args: dict, ctx):
    """Trace replay.  ``source_args={"path": ...}`` or a ``trace``
    resource ([TraceEvent] or a parsed (header, events) pair)."""
    trace = ctx.resources.get("trace")
    if trace is None:
        path = args.get("path")
        if path is None:
            raise KeyError("source='replay' needs source_args={'path': ...} "
                           "or a 'trace' resource")
        _, events = load_trace(path)
    else:
        events = trace[1] if isinstance(trace, tuple) else trace
    return StreamSource(replay_stream(events), ctx.task_factory)
