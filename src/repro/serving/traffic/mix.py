"""Per-class request mixes: *what* arrives, composed with *when*.

An arrival process (:mod:`repro.serving.traffic.generators`) produces the
offsets; a :class:`RequestMix` stamps each offset into a concrete
:class:`~repro.serving.engine.Request` — which SLO class it belongs to
(deadline / utility weight / depth cap come from ``spec.slo_classes`` at
admission), which dataset sample it carries, and optionally an explicit
per-class relative deadline or deadline range overriding the SLO default.

Classes are drawn independently per request with probability proportional
to ``share`` and samples uniformly from ``[0, n_samples)`` — both from the
same seeded generator as the arrival offsets, so a traffic trace is one
deterministic function of (arrival args, mix args, seed).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serving.engine import Request


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One slice of the mix.

    ``slo`` names a ``spec.slo_classes`` tier (may be None when
    ``rel_deadline``/``rel_range`` is given here); ``share`` is the
    relative mix probability.  ``rel_deadline`` pins a fixed relative
    deadline; ``rel_range = (lo, hi)`` draws one per request U[lo, hi]
    (the paper's §IV deadline model).  When both are None the SLO class
    supplies the deadline at admission.  ``seq_range = (lo, hi)`` draws a
    ragged input length U{lo..hi} per request and stamps it into
    ``Request.seq_len`` — admission and batching then price the request
    by its length bucket (``LengthBucketTimeModel``), and same-stage
    co-runners batch only within a bucket.  ``model`` stamps a model-zoo
    id into ``Request.model`` — multi-model mixes route each class to its
    own model (``repro.serving.zoo``); ``None`` keeps the single-model
    path untouched.
    """

    slo: Optional[str] = None
    share: float = 1.0
    rel_deadline: Optional[float] = None
    rel_range: Optional[tuple] = None
    seq_range: Optional[tuple] = None
    model: Optional[str] = None

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficClass":
        rr = d.get("rel_range")
        sr = d.get("seq_range")
        return cls(slo=d.get("slo"), share=float(d.get("share", 1.0)),
                   rel_deadline=d.get("rel_deadline"),
                   rel_range=tuple(rr) if rr is not None else None,
                   seq_range=tuple(sr) if sr is not None else None,
                   model=d.get("model"))

    def to_dict(self) -> dict:
        d = {"slo": self.slo, "share": self.share}
        if self.rel_deadline is not None:
            d["rel_deadline"] = self.rel_deadline
        if self.rel_range is not None:
            d["rel_range"] = list(self.rel_range)
        if self.seq_range is not None:
            d["seq_range"] = list(self.seq_range)
        if self.model is not None:
            d["model"] = self.model
        return d


class RequestMix:
    """Stamp arrival offsets into concrete per-class requests.

    ``inputs_fn`` (optional) maps a sample index to the request's input
    pytree — required only by device executors; the oracle executor reads
    per-sample tables and ignores inputs.
    """

    def __init__(self, classes, n_samples: int, inputs_fn=None):
        self.classes = tuple(
            c if isinstance(c, TrafficClass) else TrafficClass.from_dict(c)
            for c in classes) or (TrafficClass(),)
        shares = np.asarray([c.share for c in self.classes], dtype=float)
        if (shares <= 0).any():
            raise ValueError("every TrafficClass.share must be > 0")
        self._probs = shares / shares.sum()
        self.n_samples = int(n_samples)
        self.inputs_fn = inputs_fn

    def make_request(self, rng: np.random.Generator, offset: float,
                     client: int) -> Request:
        ci = int(rng.choice(len(self.classes), p=self._probs))
        c = self.classes[ci]
        rel = c.rel_deadline
        if c.rel_range is not None:
            rel = float(rng.uniform(*c.rel_range))
        sample = int(rng.integers(self.n_samples))
        seq_len = None
        if c.seq_range is not None:
            lo, hi = c.seq_range
            seq_len = int(rng.integers(int(lo), int(hi) + 1))
        inputs = self.inputs_fn(sample) if self.inputs_fn is not None else None
        return Request(inputs=inputs, rel_deadline=rel, sample=sample,
                       client=client, arrival=float(offset), slo=c.slo,
                       seq_len=seq_len, model=c.model)

    def stream(self, rng: np.random.Generator, offsets) -> list:
        """The full open-loop stream: [(offset, Request)] in arrival order
        (``client`` numbers the arrivals)."""
        return [(float(off), self.make_request(rng, float(off), i))
                for i, off in enumerate(offsets)]

    def to_dicts(self) -> list:
        return [c.to_dict() for c in self.classes]
