"""Hand-rolled optimizers (optax is not available offline): AdamW with
decoupled weight decay, global-norm gradient clipping, and warmup-cosine
learning-rate schedules.  State is a pytree mirroring params, so it shards
identically to params under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    # bf16 moment states for the >300B MoE configs (fp32 AdamW for a 1T-param
    # model is 10 bytes/param — beyond 256x16GB by arithmetic, not sharding)
    state_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(self.state_dtype))
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return self.learning_rate

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                              for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, self.clip_norm / gn)
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        sdt = jnp.dtype(self.state_dtype)
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                        + (1 - b1) * g).astype(sdt),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(g)).astype(sdt),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(m, v, p):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            u = -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                       + self.weight_decay * p.astype(jnp.float32))
            return u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        prog = jnp.clip((step - warmup_steps) /
                        max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
