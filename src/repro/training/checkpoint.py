"""msgpack-based checkpointing for param/opt-state pytrees.

Arrays are serialized as (dtype, shape, raw bytes); the pytree structure is
reconstructed from a parallel JSON-able skeleton.  No flax/orbax available
offline — this is a minimal, self-contained equivalent with atomic writes.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(tree):
    leaves, treedef = jax.tree.flatten(tree)
    enc = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        enc.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                    "data": arr.tobytes()})
    return {"leaves": enc, "treedef": str(treedef)}


def save(path: str, tree, metadata: dict | None = None):
    payload = {"tree": _encode(tree), "meta": metadata or {}}
    blob = msgpack.packb(payload, use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    enc = payload["tree"]["leaves"]
    leaves, treedef = jax.tree.flatten(like)
    if len(enc) != len(leaves):
        raise ValueError(f"checkpoint has {len(enc)} leaves, "
                         f"expected {len(leaves)}")
    out = []
    for e, ref in zip(enc, leaves):
        arr = np.frombuffer(e["data"], dtype=np.dtype(e["dtype"]))
        arr = arr.reshape(e["shape"])
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(ref)}")
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), payload["meta"]
