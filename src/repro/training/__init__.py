from repro.training.optimizer import AdamW, warmup_cosine
from repro.training.loop import make_loss_fn, make_train_step, eval_exit_metrics
from repro.training.data import DifficultyDataset, lm_token_stream
from repro.training import checkpoint

__all__ = ["AdamW", "warmup_cosine", "make_loss_fn", "make_train_step",
           "eval_exit_metrics", "DifficultyDataset", "lm_token_stream",
           "checkpoint"]
