"""Synthetic data pipelines.

1. `DifficultyDataset` — the paper-analog classification workload.  The
   paper's key premise is that *required network depth is data-dependent*
   ("a picture of an empty blue sky will need far fewer layers … compared to
   complex cluttered images").  We synthesize that property structurally
   with a **terminal-marked pointer-chase** task: each sample is a sequence
   of (value, pointer, terminal-flag) cells; cell 0 starts a pointer path of
   per-sample length L ending at a terminal-flagged cell, and the label is
   that terminal's value.  Decoy terminals off the path force actual chain
   tracing.  A transformer resolves chains by pointer *doubling* (reach 2^k
   after k layers), so L controls the depth needed per sample — the
   depth/utility heterogeneity the scheduler exploits.  Additive feature
   noise adds a second, orthogonal difficulty axis.

2. `lm_token_stream` — an order-2 Markov token stream for generic LM
   training examples (learnable structure, nonzero achievable loss).

Both are pure-numpy/JAX, deterministic given a seed, and stream batches
without materializing more than one epoch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.model import FEATURE_DIM


@dataclasses.dataclass
class DifficultyDataset:
    """Terminal-marked pointer-chase classification with per-sample
    chain-length difficulty, sampled in three bands so each anytime stage
    unlocks a distinct slice of inputs (the paper's easy-sky /
    cluttered-image spectrum, made structural)."""
    num_classes: int = 10
    seq_len: int = 16
    feature_dim: int = FEATURE_DIM
    noise: float = 0.1
    band_probs: tuple = (0.4, 0.3, 0.3)
    bands: tuple = ((1, 2), (3, 5), (7, 11))   # chain-length per band
    # cap: seq_len-1-L must leave >=3 off-path cells for decoy terminals
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        sub = self.feature_dim // 4          # 4 sub-embeddings of this width
        self.pos_emb = rng.normal(size=(self.seq_len, sub)).astype(np.float32)
        self.val_emb = rng.normal(size=(self.num_classes, sub)).astype(np.float32)
        self.term_emb = rng.normal(size=(2, sub)).astype(np.float32)

    def sample(self, n: int, seed: int):
        """Terminal-marked chains: cell 0 starts a pointer path of per-sample
        length L ending at a terminal-flagged cell; label = terminal value.
        Returns dict(inputs={"features"}, labels, difficulty=L)."""
        rng = np.random.default_rng(seed)
        S, C = self.seq_len, self.num_classes
        vals = rng.integers(0, C, size=(n, S))
        band = rng.choice(len(self.bands), size=n, p=self.band_probs)
        lens = np.array([rng.integers(self.bands[b][0], self.bands[b][1] + 1)
                         for b in band])
        ptrs = rng.integers(0, S, size=(n, S))
        term = np.zeros((n, S), np.int64)
        labels = np.zeros(n, np.int64)
        for i in range(n):                    # build one path per sample
            L = int(lens[i])
            perm = 1 + rng.permutation(S - 1)
            path = np.concatenate([[0], perm[:L]])
            for a, b in zip(path[:-1], path[1:]):
                ptrs[i, a] = b
            end = path[-1]
            ptrs[i, end] = end
            term[i, end] = 1
            # decoy terminals off the path: flagged self-loops that are NOT
            # reachable from cell 0 — the network must trace the chain, not
            # just read "the flagged cell"
            decoys = perm[L:L + 3]
            for dcell in decoys:
                ptrs[i, dcell] = dcell
                term[i, dcell] = 1
            # remaining distractors must not self-loop (fake terminals)
            for j in range(S):
                if term[i, j] == 0 and ptrs[i, j] == j:
                    ptrs[i, j] = (j + 1) % S
            labels[i] = vals[i, end]
        sub = self.feature_dim // 4
        x = np.zeros((n, S, self.feature_dim), np.float32)
        x[:, :, :sub] = self.pos_emb[None]
        x[:, :, sub:2 * sub] = self.val_emb[vals]
        x[:, :, 2 * sub:3 * sub] = self.pos_emb[ptrs]
        x[:, :, 3 * sub:] = self.term_emb[term]
        x += self.noise * rng.normal(size=x.shape).astype(np.float32)
        return {
            "inputs": {"features": x},
            "labels": labels.astype(np.int32),
            "difficulty": lens.astype(np.float32),
        }

    def batches(self, n_total: int, batch_size: int, seed: int):
        data = self.sample(n_total, seed)
        for i in range(0, n_total - batch_size + 1, batch_size):
            sl = slice(i, i + batch_size)
            yield {"inputs": {"features": data["inputs"]["features"][sl]},
                   "labels": data["labels"][sl]}


def lm_token_stream(vocab: int, seed: int = 0, order: int = 2,
                    branching: int = 4):
    """Infinite order-`order` Markov stream over `vocab` tokens."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each context allows `branching` tokens
    n_ctx = min(vocab ** order, 65536)
    allowed = rng.integers(0, vocab, size=(n_ctx, branching))
    probs = rng.dirichlet(np.ones(branching), size=n_ctx)

    def gen(batch: int, seq: int, step_seed: int):
        r = np.random.default_rng((seed, step_seed))
        out = np.zeros((batch, seq + 1), np.int64)
        out[:, :order] = r.integers(0, vocab, size=(batch, order))
        ctx_mult = np.array([vocab ** i for i in range(order)])
        for t in range(order, seq + 1):
            ctx = (out[:, t - order:t] * ctx_mult).sum(1) % n_ctx
            choice = np.array([r.choice(branching, p=probs[c]) for c in ctx])
            out[:, t] = allowed[ctx, choice]
        return {"inputs": {"tokens": out[:, :-1].astype(np.int32)},
                "labels": out[:, 1:].astype(np.int32)}

    return gen
