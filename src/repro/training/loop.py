"""Training loop: deep-supervision multi-exit loss + train-step factory.

The paper's anytime networks are trained so every stage's exit head produces
both an intermediate classification and a confidence (§III-A: "we must train
the network to generate both the intermediate results after each stage, and
the confidence estimates").  Deep supervision — a weighted sum of
cross-entropies over all exits — is exactly that training signal; confidence
comes for free as (calibrated) max-softmax of each exit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import exits as exits_lib
from repro.models import forward
from repro.models.model import apply_layer, Sig

MTP_WEIGHT = 0.3


def _xent(logits, labels):
    """Mean cross-entropy. logits: (..., V); labels: (...) int32."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def _exit_loss(cfg, logits, labels):
    if cfg.modality == "features":
        return _xent(logits, labels)                     # (B,V) vs (B,)
    if cfg.modality == "audio_stub":
        # logits (B,S,ncb,V); labels (B,ncb,S)
        return _xent(logits, labels.transpose(0, 2, 1))
    if cfg.modality == "vision_stub":
        # next-token loss on text positions only
        n_text = labels.shape[1]
        return _xent(logits[:, -n_text:], labels)
    return _xent(logits, labels)                         # (B,S,V) vs (B,S)


def _mtp_loss(cfg, params, out, batch, ctx):
    """DeepSeek-style one-depth multi-token prediction: predict t+2 from the
    final hidden state combined with the embedding of the (known) t+1 label."""
    labels = batch["labels"]                             # (B,S) = token t+1
    h = out.h_final                                      # (B,S,d)
    emb = jnp.take(params["embed"]["tok"], labels, axis=0)
    z = jnp.concatenate([h, emb.astype(h.dtype)], -1) @ params["mtp"]["proj"]
    S = z.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    z, _, _ = apply_layer(cfg, Sig("attn", False), params["mtp"]["block"], z,
                          mode="train", positions=positions, ctx=ctx)
    lg = exits_lib.apply_exit(
        cfg, {**params["mtp"]["exit"], **params["exit_shared"]}, z, ctx=ctx)
    # target at position t is token t+2 = labels shifted by one
    return _xent(lg[:, :-1], labels[:, 1:])


def make_loss_fn(cfg, *, exit_weights: Optional[tuple] = None, ctx=None,
                 q_chunk: int = 1024, aux_exit_stride: int = 1):
    """Returns loss_fn(params, batch) -> scalar.

    batch = {"inputs": <modality inputs>, "labels": <target ids>}.
    aux_exit_stride > 1 subsamples supervision positions for the non-final
    exits (§Perf: at 256k vocab the three exit heads otherwise cost more
    training FLOPs than the 96-layer backbone; deep supervision tolerates
    sparse positions).
    """
    n_stages = cfg.num_stages

    def loss_fn(params, batch):
        out = forward(cfg, params, batch["inputs"], ctx=ctx, mode="train",
                      q_chunk=q_chunk, aux_exit_stride=aux_exit_stride)
        w = exit_weights or tuple(1.0 for _ in out.logits)
        w = jnp.asarray(w, jnp.float32)
        w = w / w.sum()
        total = jnp.zeros((), jnp.float32)
        labels = batch["labels"]
        for s, (ws, lg) in enumerate(zip(w, out.logits)):
            lb = labels
            if (s < len(out.logits) - 1 and lg.ndim >= 3
                    and cfg.modality in ("text", "vision_stub")
                    and lb.shape[-1] != lg.shape[1]):
                lb = labels[:, ::aux_exit_stride]   # forward already strided h
            total += ws * _exit_loss(cfg, lg, lb)
        total += out.aux
        if cfg.mtp and "mtp" in params and cfg.modality == "text":
            total += MTP_WEIGHT * _mtp_loss(cfg, params, out, batch, ctx)
        return total

    return loss_fn


def make_train_step(cfg, optimizer, *, ctx=None, exit_weights=None,
                    q_chunk: int = 1024, donate: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Not jitted here — callers jit with their shardings."""
    loss_fn = make_loss_fn(cfg, exit_weights=exit_weights, ctx=ctx,
                           q_chunk=q_chunk)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if ctx is not None:
            grads = jax.tree.map(
                lambda g: g, grads)  # pjit inserts the psums via sharding
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u)
                              .astype(p.dtype), params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def eval_exit_metrics(cfg, params, dataset, *, batch_size: int = 64,
                      temperature: float = 1.0):
    """Per-stage accuracy + mean confidence + per-sample records.

    dataset: dict with "inputs" pytree (leading axis N) and "labels".
    Returns dict with per-stage arrays: correct (N, n_stages) bool,
    confidence (N, n_stages) — the joint curves the scheduler consumes.
    """
    import numpy as np

    fwd = jax.jit(functools.partial(forward, cfg, mode="train",
                                    conf_temperature=temperature),
                  static_argnames=())
    labels = dataset["labels"]
    N = labels.shape[0]
    n_stages = cfg.num_stages
    correct = np.zeros((N, n_stages), bool)
    confs = np.zeros((N, n_stages), np.float32)
    for i in range(0, N, batch_size):
        sl = slice(i, min(N, i + batch_size))
        inputs = jax.tree.map(lambda x: x[sl], dataset["inputs"])
        out = fwd(params, inputs)
        for s, (lg, cf) in enumerate(zip(out.logits, out.confidences)):
            pred = np.asarray(jnp.argmax(lg, -1))
            correct[sl, s] = pred == np.asarray(labels[sl])
            confs[sl, s] = np.asarray(cf)
    return {"correct": correct, "confidence": confs}
