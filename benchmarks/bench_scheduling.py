"""Scheduling benchmarks — one per paper table/figure (paper §IV).

Figures reproduced (CPU-scale analog of CIFAR-10/ImageNet ResNet-3-stage):
  fig3_5   utility-heuristic comparison (Exp/Max/Lin vs Oracle) across
           K, D_u, D_l sweeps                     [paper Fig. 3–5]
  fig6_7   scheduler comparison (RTDeepIoT vs EDF/LCF/RR): accuracy +
           deadline-miss rate vs K                [paper Fig. 6–7]
  fig8_11  accuracy + miss rate vs D_u and D_l    [paper Fig. 8–11]
  fig12    reward-quantization Δ sweep            [paper Fig. 12]
  fig13    scheduler overhead vs K                [paper Fig. 13]
  batch    continuous stage-level micro-batching: goodput (completed
           requests/s), miss rate and accuracy vs offered load, batched
           (repro.serving.batch) vs unbatched engine [extension]

All rows print as CSV (name,metric,value triples per configuration) and are
also returned as dicts for EXPERIMENTS.md generation.  Inputs: the trained
anytime classifier's oracle tables (artifacts/oracle_tables.npz, produced by
examples/train_multiexit.py) + profiled stage WCETs.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import EDF, LCF, RR, RTDeepIoT, Workload, make_predictor, simulate
from repro.serving.batch.admission import AdmissionController
from repro.serving.batch.batcher import DEFAULT_BUCKETS, BatchTimeModel
from repro.serving.batch.simulator import simulate_batched

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# stage WCETs: paper-like magnitudes (~ms-scale stages vs 10-300 ms
# deadlines), proportional to our anytime stages' 1/2/3-layer depths.  (The
# wall-clock engine profiles real stage times itself; see
# examples/serve_anytime.py.)
DEFAULT_STAGE_TIMES = (0.004, 0.007, 0.010)

DEFAULTS = dict(n_clients=20, d_lo=0.01, d_hi=0.3, n_requests=600)


def load_tables():
    path = os.path.join(ART, "oracle_tables.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} missing — run examples/train_multiexit.py first")
    z = np.load(path)
    return z["confidence"], z["correct"], z

def _stage_times():
    # simulation figures always use the paper-analog times; the wall-clock
    # engine (examples/serve_anytime.py) profiles real ones separately
    return DEFAULT_STAGE_TIMES


def _mk_policy(name, conf, delta=0.1):
    prior = conf.mean(0)
    if name in ("exp", "max", "lin"):
        return RTDeepIoT(make_predictor(name, prior_curve=prior), delta=delta)
    if name == "oracle":
        return RTDeepIoT(make_predictor("oracle", oracle_table=conf),
                         delta=delta)
    return {"edf": EDF, "lcf": LCF, "rr": RR}[name]()


def _run(policy_name, conf, correct, *, delta=0.1, charge_overhead=False,
         **wl_kwargs):
    wl = Workload(**{**DEFAULTS, **wl_kwargs})
    pol = _mk_policy(policy_name, conf, delta)
    res = simulate(pol, wl, _stage_times(), conf, correct,
                   charge_overhead=charge_overhead)
    return res


def _emit(rows, fig, key, policy, res):
    rows.append(dict(figure=fig, config=key, policy=policy,
                     accuracy=round(res.accuracy, 4),
                     miss_rate=round(res.miss_rate, 4),
                     mean_depth=round(res.mean_depth, 3),
                     overhead=round(res.overhead_frac, 4),
                     throughput=round(res.throughput, 2)))
    print(f"{fig},{key},{policy},acc={res.accuracy:.4f},"
          f"miss={res.miss_rate:.4f},depth={res.mean_depth:.2f},"
          f"ovh={res.overhead_frac:.4f},thr={res.throughput:.1f}")


def fig3_5_utility_heuristics(conf, correct):
    """Exp vs Max vs Lin vs Oracle across K / D_u / D_l (paper Fig. 3–5)."""
    rows = []
    for k in (10, 20, 40):
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig3", f"K={k}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, n_clients=k))
    for du in (0.1, 0.3, 0.6):
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig4", f"Du={du}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, d_hi=du))
    for dl in (0.01, 0.05, 0.1):
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig5", f"Dl={dl}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, d_lo=dl))
    return rows


def fig6_7_scheduler_comparison(conf, correct):
    rows = []
    for k in (5, 10, 20, 40, 60):
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig6_7", f"K={k}", name,
                  _run(p, conf, correct, n_clients=k))
    return rows


def fig8_11_deadline_sweeps(conf, correct):
    rows = []
    for du in (0.1, 0.2, 0.3, 0.5):
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig8_9", f"Du={du}", name,
                  _run(p, conf, correct, d_hi=du))
    for dl in (0.01, 0.03, 0.06, 0.1):
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig10_11", f"Dl={dl}", name,
                  _run(p, conf, correct, d_lo=dl))
    return rows


def fig12_delta_sweep(conf, correct):
    """Reward quantization step Δ: accuracy vs scheduling granularity,
    with scheduler wall time charged to the simulated clock so too-fine Δ
    hurts exactly as in the paper."""
    rows = []
    for delta in (0.4, 0.2, 0.1, 0.05, 0.02, 0.005):
        res = _run("exp", conf, correct, delta=delta, charge_overhead=True)
        _emit(rows, "fig12", f"delta={delta}", "rtdeepiot", res)
    return rows


def fig_batch_throughput(conf, correct):
    """Batched vs unbatched serving across offered load (repro.serving.batch).

    Same closed-loop workload and policies on both paths; the batched path
    dispatches padded micro-batches priced by a linear BatchTimeModel
    (each extra item costs 15% of the single-item stage time — conservative
    vs. measured GPU batch scaling).  Goodput = completed requests/s."""
    rows = []
    tm = BatchTimeModel.linear(_stage_times(), DEFAULT_BUCKETS, marginal=0.15)
    speedups = {}
    for k in (16, 32, 64):
        wl_kwargs = dict(n_clients=k, n_requests=800)
        for p in ("exp", "edf"):
            name = "rtdeepiot" if p == "exp" else p
            res_u = _run(p, conf, correct, **wl_kwargs)
            _emit(rows, "batch", f"K={k}", name, res_u)
            wl = Workload(**{**DEFAULTS, **wl_kwargs})
            pol = _mk_policy(p, conf)
            res_b = simulate_batched(pol, wl, tm, conf, correct)
            _emit(rows, "batch", f"K={k}", f"batched-{name}", res_b)
            speedups[(k, name)] = (res_b.throughput
                                   / max(res_u.throughput, 1e-9),
                                   res_b.accuracy - res_u.accuracy)
            # admission-controlled variant: fail fast under overload
            pol = _mk_policy(p, conf)
            res_a = simulate_batched(pol, wl, tm, conf, correct,
                                     admission=AdmissionController(
                                         tm, mode="depth_cap"))
            _emit(rows, "batch", f"K={k}", f"batched-{name}-admit", res_a)
    for (k, name), (sp, dacc) in sorted(speedups.items()):
        print(f"batch,K={k},{name},speedup={sp:.2f}x,acc_delta={dacc:+.4f}")
    return rows, speedups


def fig13_overhead(conf, correct):
    rows = []
    for k in (5, 10, 20, 40):
        res = _run("exp", conf, correct, n_clients=k)
        _emit(rows, "fig13", f"K={k}", "rtdeepiot", res)
    return rows


def summarize_claims(all_rows):
    """Validate the paper's headline claims on our reproduction."""
    byfig = {}
    for r in all_rows:
        byfig.setdefault((r["figure"], r["config"]), {})[r["policy"]] = r
    gains, exp_vs_opt = [], []
    per_baseline = {b: [] for b in ("edf", "lcf", "rr")}
    miss_rt, miss_edf = [], []
    for (fig, cfgk), pol in byfig.items():
        if fig in ("fig6_7", "fig8_9", "fig10_11") and "rtdeepiot" in pol:
            base = max(pol[p]["accuracy"] for p in ("edf", "lcf", "rr")
                       if p in pol)
            gains.append(pol["rtdeepiot"]["accuracy"] - base)
            for b in per_baseline:
                if b in pol:
                    per_baseline[b].append(pol["rtdeepiot"]["accuracy"]
                                           - pol[b]["accuracy"])
            miss_rt.append(pol["rtdeepiot"]["miss_rate"])
            if "edf" in pol:
                miss_edf.append(pol["edf"]["miss_rate"])
        if fig.startswith("fig3") and "rtdeepiot-exp" in pol \
                and "rtdeepiot-oracle" in pol:
            exp_vs_opt.append(pol["rtdeepiot-oracle"]["accuracy"]
                              - pol["rtdeepiot-exp"]["accuracy"])
    claims = {
        "max_gain_over_best_baseline": max(gains) if gains else None,
        "mean_gain_over_best_baseline": float(np.mean(gains)) if gains else None,
        "mean_gain_over_edf": float(np.mean(per_baseline["edf"])),
        "max_gain_over_edf": float(np.max(per_baseline["edf"])),
        "mean_gain_over_lcf": float(np.mean(per_baseline["lcf"])),
        "mean_gain_over_rr": float(np.mean(per_baseline["rr"])),
        "rtdeepiot_mean_miss": float(np.mean(miss_rt)),
        "edf_mean_miss": float(np.mean(miss_edf)),
        "exp_within_of_oracle_mean": float(np.mean(exp_vs_opt))
        if exp_vs_opt else None,
    }
    print("CLAIMS:", claims)
    return claims


def batch_claims(speedups):
    """Headline check for the batched subsystem: at some offered load the
    batched engine sustains >= 3x unbatched goodput without giving up
    accuracy (>= unbatched - 1 point)."""
    qualifying = {f"K={k}/{name}": round(sp, 2)
                  for (k, name), (sp, dacc) in speedups.items()
                  if sp >= 3.0 and dacc >= -0.01}
    best = max(sp for sp, _ in speedups.values())
    claims = {"batch_best_speedup": round(best, 2),
              "batch_speedup_ge_3x_configs": qualifying,
              "batch_claim_met": bool(qualifying)}
    print("BATCH CLAIMS:", claims)
    return claims


def main():
    conf, correct, _ = load_tables()
    rows = []
    rows += fig3_5_utility_heuristics(conf, correct)
    rows += fig6_7_scheduler_comparison(conf, correct)
    rows += fig8_11_deadline_sweeps(conf, correct)
    rows += fig12_delta_sweep(conf, correct)
    rows += fig13_overhead(conf, correct)
    brows, speedups = fig_batch_throughput(conf, correct)
    rows += brows
    claims = summarize_claims(rows)
    claims.update(batch_claims(speedups))
    import json
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "scheduling_results.json"), "w") as f:
        json.dump({"rows": rows, "claims": claims}, f, indent=1)
    return rows, claims


if __name__ == "__main__":
    main()
