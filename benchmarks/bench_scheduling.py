"""Scheduling benchmarks — one per paper table/figure (paper §IV).

Figures reproduced (CPU-scale analog of CIFAR-10/ImageNet ResNet-3-stage):
  fig3_5   utility-heuristic comparison (Exp/Max/Lin vs Oracle) across
           K, D_u, D_l sweeps                     [paper Fig. 3–5]
  fig6_7   scheduler comparison (RTDeepIoT vs EDF/LCF/RR): accuracy +
           deadline-miss rate vs K                [paper Fig. 6–7]
  fig8_11  accuracy + miss rate vs D_u and D_l    [paper Fig. 8–11]
  fig12    reward-quantization Δ sweep            [paper Fig. 12]
  fig13    scheduler overhead vs K                [paper Fig. 13]
  batch    continuous stage-level micro-batching: goodput (completed
           requests/s), miss rate and accuracy vs offered load, batched
           (repro.serving.batch) vs unbatched engine [extension]
  async    pipelined async dispatch (repro.serving.runtime,
           pipeline_depth=2) vs synchronous batched dispatch: charged
           host-overhead fraction, goodput, accuracy, miss rate
           [extension; deterministic modeled host costs]
  traffic  open-loop traffic scenarios (repro.serving.traffic): steady /
           2x sustained overload / flash crowd / diurnal ramp, policies
           with and without admission control + shedding; includes the
           record/replay bit-for-bit regression check  [extension]
  sharded  the device-sharded executor (repro.launch.sharded): modeled
           goodput vs data-parallel mesh width under 2x overload scaled
           to each width, plus the end-to-end device-sharded run on the
           real anytime classifier through a traffic scenario with
           bit-for-bit parity against device-batched on a 1x1 mesh
           [extension]
  kernel   the device-kernel fast path (repro.launch.kernel): depth-3
           dispatch pipelining vs the async figure's charged host-cost
           floor, ragged length-bucket batching under 2x overload, the
           end-to-end Pallas-backed run on the real anytime classifier
           (fused exit-confidence bit-for-bit vs the unfused reference,
           ragged decode batching bitwise vs singletons)  [extension]
  plane    the durable request plane (repro.serving.plane): DRR vs FIFO
           tenant fairness under skewed overload, idempotent journaled
           submission, and bit-for-bit mid-stream crash recovery
           [extension]
  zoo      the multi-model zoo (repro.serving.zoo): cross-model
           preemption (rtdeepiot-zoo scope=global) vs per-model-siloed
           planning on the model-mix 2x-overload scenario, scored on
           weighted admitted accuracy, plus the single-member zoo spec's
           bit-for-bit parity against the plain device-batched path
           [extension]
  obs      the observability layer (repro.serving.obs): measured
           wall-clock overhead of full tracing on the batch figure's
           config (claim: < 5%), bitwise scheduling parity traced vs
           untraced, audit-log coverage of every shed/rejected request
           at 2x overload, and Chrome trace_event export validity
           [extension]

All rows print as CSV (name,metric,value triples per configuration) and are
also returned as dicts (``SimResult.to_dict`` rows) for EXPERIMENTS.md
generation.  Inputs: the trained anytime classifier's oracle tables
(artifacts/oracle_tables.npz, produced by examples/train_multiexit.py) +
profiled stage WCETs.

Every engine is built through the public serving API: a declarative
``ServeSpec`` (policy/executor/clock/source by registry key) run through
``repro.serving.Service``.

``--smoke`` runs every figure on tiny workloads (synthetic oracle tables
when the artifact is absent) without writing artifacts — the CI job that
keeps these code paths alive.
"""
from __future__ import annotations

import argparse
import dataclasses as _dc
import json
import os

import numpy as np

from repro.core import Workload
from repro.serving import ServeSpec, Service
from repro.serving.batch.batcher import DEFAULT_BUCKETS

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# stage WCETs: paper-like magnitudes (~ms-scale stages vs 10-300 ms
# deadlines), proportional to our anytime stages' 1/2/3-layer depths.  (The
# wall-clock engine profiles real stage times itself; see
# examples/serve_anytime.py.)
DEFAULT_STAGE_TIMES = (0.004, 0.007, 0.010)

DEFAULTS = dict(n_clients=20, d_lo=0.01, d_hi=0.3, n_requests=600)

# modeled host costs for the async figure: one policy invocation
# (selection / replan / §II-E hook) and one device submit — deterministic,
# so pipelined-vs-synchronous comparisons are reproducible
ASYNC_POLICY_COST = 5e-4
ASYNC_DISPATCH_OVERHEAD = 1e-4


def load_tables(smoke: bool = False):
    path = os.path.join(ART, "oracle_tables.npz")
    if not os.path.exists(path):
        if smoke:
            return (*synthetic_tables(), None)
        raise FileNotFoundError(
            f"{path} missing — run examples/train_multiexit.py first")
    z = np.load(path)
    return z["confidence"], z["correct"], z


def synthetic_tables(n=600, L=3, seed=0):
    """Oracle-shaped tables for smoke runs: monotone per-sample confidence
    curves whose correctness is confidence-consistent."""
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def _stage_times():
    # simulation figures always use the paper-analog times; the wall-clock
    # engine (examples/serve_anytime.py) profiles real ones separately
    return DEFAULT_STAGE_TIMES


def _policy_conf(name, delta=0.1):
    """Registry (policy, policy_args) for a figure's policy label."""
    if name in ("exp", "max", "lin", "oracle"):
        return "rtdeepiot", {"predictor": name, "delta": delta}
    return name, {}


def _spec(policy_name, *, delta=0.1, batched=False, admission=None,
          charge_overhead=False, dispatch_overhead=0.0, policy_cost=None,
          pipeline_depth=1) -> ServeSpec:
    """One place every figure's engine is declared: the ServeSpec."""
    pol, pargs = _policy_conf(policy_name, delta)
    batching = ({"buckets": list(DEFAULT_BUCKETS), "marginal": 0.15,
                 "stage_times": list(_stage_times())} if batched
                else {"mode": "none", "stage_times": list(_stage_times())})
    return ServeSpec(policy=pol, policy_args=pargs, executor="oracle",
                     clock="virtual", source="closed-loop",
                     batching=batching, admission=admission or {},
                     charge_overhead=charge_overhead,
                     dispatch_overhead=dispatch_overhead,
                     policy_cost=policy_cost, pipeline_depth=pipeline_depth)


def _serve(spec, conf, correct, **wl_kwargs):
    wl = Workload(**{**DEFAULTS, **wl_kwargs})
    return Service.from_spec(spec, workload=wl, conf_table=conf,
                             correct_table=correct).run()


def _run(policy_name, conf, correct, *, delta=0.1, charge_overhead=False,
         **wl_kwargs):
    return _serve(_spec(policy_name, delta=delta,
                        charge_overhead=charge_overhead),
                  conf, correct, **wl_kwargs)


def _emit(rows, fig, key, policy, res):
    row = {k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in res.to_dict().items() if not isinstance(v, dict)}
    rows.append(dict(figure=fig, config=key, policy=policy, **row))
    print(f"{fig},{key},{policy},acc={res.accuracy:.4f},"
          f"miss={res.miss_rate:.4f},depth={res.mean_depth:.2f},"
          f"ovh={res.overhead_frac:.4f},thr={res.throughput:.1f}")


def fig3_5_utility_heuristics(conf, correct, ks=(10, 20, 40),
                              dus=(0.1, 0.3, 0.6), dls=(0.01, 0.05, 0.1)):
    """Exp vs Max vs Lin vs Oracle across K / D_u / D_l (paper Fig. 3–5)."""
    rows = []
    for k in ks:
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig3", f"K={k}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, n_clients=k))
    for du in dus:
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig4", f"Du={du}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, d_hi=du))
    for dl in dls:
        for p in ("exp", "max", "lin", "oracle"):
            _emit(rows, "fig5", f"Dl={dl}", f"rtdeepiot-{p}",
                  _run(p, conf, correct, d_lo=dl))
    return rows


def fig6_7_scheduler_comparison(conf, correct, ks=(5, 10, 20, 40, 60)):
    rows = []
    for k in ks:
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig6_7", f"K={k}", name,
                  _run(p, conf, correct, n_clients=k))
    return rows


def fig8_11_deadline_sweeps(conf, correct, dus=(0.1, 0.2, 0.3, 0.5),
                            dls=(0.01, 0.03, 0.06, 0.1)):
    rows = []
    for du in dus:
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig8_9", f"Du={du}", name,
                  _run(p, conf, correct, d_hi=du))
    for dl in dls:
        for p in ("exp", "edf", "lcf", "rr"):
            name = "rtdeepiot" if p == "exp" else p
            _emit(rows, "fig10_11", f"Dl={dl}", name,
                  _run(p, conf, correct, d_lo=dl))
    return rows


def fig12_delta_sweep(conf, correct,
                      deltas=(0.4, 0.2, 0.1, 0.05, 0.02, 0.005)):
    """Reward quantization step Δ: accuracy vs scheduling granularity,
    with scheduler wall time charged to the simulated clock so too-fine Δ
    hurts exactly as in the paper."""
    rows = []
    for delta in deltas:
        res = _run("exp", conf, correct, delta=delta, charge_overhead=True)
        _emit(rows, "fig12", f"delta={delta}", "rtdeepiot", res)
    return rows


def fig_batch_throughput(conf, correct, ks=(16, 32, 64), n_requests=800):
    """Batched vs unbatched serving across offered load (repro.serving.batch).

    Same closed-loop workload and policies on both paths; the batched path
    dispatches padded micro-batches priced by a linear BatchTimeModel
    (each extra item costs 15% of the single-item stage time — conservative
    vs. measured GPU batch scaling).  Goodput = completed requests/s."""
    rows = []
    speedups = {}
    for k in ks:
        wl_kwargs = dict(n_clients=k, n_requests=n_requests)
        for p in ("exp", "edf"):
            name = "rtdeepiot" if p == "exp" else p
            res_u = _run(p, conf, correct, **wl_kwargs)
            _emit(rows, "batch", f"K={k}", name, res_u)
            res_b = _serve(_spec(p, batched=True), conf, correct, **wl_kwargs)
            _emit(rows, "batch", f"K={k}", f"batched-{name}", res_b)
            speedups[(k, name)] = (res_b.throughput
                                   / max(res_u.throughput, 1e-9),
                                   res_b.accuracy - res_u.accuracy)
            # admission-controlled variant: fail fast under overload
            res_a = _serve(_spec(p, batched=True,
                                 admission={"mode": "depth_cap"}),
                           conf, correct, **wl_kwargs)
            _emit(rows, "batch", f"K={k}", f"batched-{name}-admit", res_a)
    for (k, name), (sp, dacc) in sorted(speedups.items()):
        print(f"batch,K={k},{name},speedup={sp:.2f}x,acc_delta={dacc:+.4f}")
    return rows, speedups


def fig_async_dispatch(conf, correct, ks=(16, 32, 64), n_requests=1200):
    """Pipelined async dispatch vs synchronous batched dispatch
    (repro.serving.runtime, pipeline_depth=2 vs 1).

    Both paths run the same batched EngineCore with deterministic modeled
    host costs (one policy invocation = {ASYNC_POLICY_COST}s, one submit =
    {ASYNC_DISPATCH_OVERHEAD}s) charged to the virtual clock.  Synchronous
    dispatch serializes every host second with the device; the pipelined
    host pre-selects batch N+1 inside batch N's window (re-validating
    deadline feasibility at true dispatch time), so most host work hides
    behind device execution — charged host-overhead fraction drops at
    equal-or-better goodput/accuracy/miss."""
    rows = []
    comp = {}
    for k in ks:
        # 1200+ requests: accuracy deltas between the two dispatch modes
        # are schedule-chaos noise at small n; this concentrates them
        wl_kwargs = dict(n_clients=k, n_requests=n_requests)
        for p in ("exp", "edf"):
            name = "rtdeepiot" if p == "exp" else p
            kw = dict(batched=True, charge_overhead=True,
                      dispatch_overhead=ASYNC_DISPATCH_OVERHEAD,
                      policy_cost=ASYNC_POLICY_COST)
            res_s = _serve(_spec(p, pipeline_depth=1, **kw), conf, correct,
                           **wl_kwargs)
            _emit(rows, "async", f"K={k}", f"sync-{name}", res_s)
            res_a = _serve(_spec(p, pipeline_depth=2, **kw), conf, correct,
                           **wl_kwargs)
            _emit(rows, "async", f"K={k}", f"pipelined-{name}", res_a)
            comp[(k, name)] = dict(
                host_frac_sync=res_s.host_overhead_frac,
                host_frac_async=res_a.host_overhead_frac,
                acc_sync=res_s.accuracy, miss_sync=res_s.miss_rate,
                acc_delta=res_a.accuracy - res_s.accuracy,
                miss_delta=res_a.miss_rate - res_s.miss_rate,
                goodput_ratio=res_a.throughput / max(res_s.throughput, 1e-9),
                presel_hit_rate=res_a.presel_hits
                / max(res_a.presel_hits + res_a.presel_misses, 1))
    for (k, name), c in sorted(comp.items()):
        print(f"async,K={k},{name},host_frac {c['host_frac_sync']:.4f}->"
              f"{c['host_frac_async']:.4f},goodput x{c['goodput_ratio']:.2f},"
              f"acc{c['acc_delta']:+.4f},miss{c['miss_delta']:+.4f}")
    return rows, comp


def fig13_overhead(conf, correct, ks=(5, 10, 20, 40)):
    rows = []
    for k in ks:
        res = _run("exp", conf, correct, n_clients=k)
        _emit(rows, "fig13", f"K={k}", "rtdeepiot", res)
    return rows


# policy x overload-control variants run in every traffic scenario:
# (label, registry policy key, admission config)
TRAFFIC_VARIANTS = (
    ("edf", "edf", None),                               # uncontrolled
    ("rtdeepiot", "rtdeepiot", None),                   # planner only
    ("rtdeepiot-admit", "rtdeepiot", {"mode": "reject"}),
    ("rtdeepiot-shed", "rtdeepiot", {"mode": "depth_cap"}),
)


def fig_traffic(conf, correct, n_requests=1500, seed=0):
    """Open-loop traffic scenarios (repro.serving.traffic).

    Every scenario drives the same service through the registry's
    ``traffic`` source: seeded arrival process x gold/silver/bronze SLO
    mix, rates scaled to the nominal full-depth service rate.  The
    headline regime is ``2x-overload`` — load the closed-loop §IV
    workload cannot express: uncontrolled EDF collapses (deadline misses
    pile up), while RTDeepIoT behind admission control (reject) or
    shedding (depth_cap) keeps *admitted* misses near zero at bounded
    accuracy loss.

    Also performs the record/replay regression check: the
    ``rtdeepiot-admit`` 2x-overload run is captured as a trace and
    re-injected through ``register_source("replay")`` — arrival order and
    admission decisions must reproduce bit-for-bit under the virtual
    clock.
    """
    from repro.serving.traffic import (SCENARIOS, TraceRecorder,
                                       scenario_spec, verify_replay)
    rows = []
    comp = {}
    st = _stage_times()
    for scen in sorted(SCENARIOS):
        for label, pol, adm in TRAFFIC_VARIANTS:
            spec = scenario_spec(scen, policy=pol, admission=adm,
                                 stage_times=st, n_requests=n_requests,
                                 seed=seed)
            res = Service.from_spec(spec, conf_table=conf,
                                    correct_table=correct).run()
            _emit(rows, "traffic", scen, label, res)
            comp[(scen, label)] = res
    # record/replay round trip on the headline configuration
    spec = scenario_spec("2x-overload", policy="rtdeepiot",
                         admission={"mode": "reject"}, stage_times=st,
                         n_requests=n_requests, seed=seed)
    orig = comp[("2x-overload", "rtdeepiot-admit")]
    rec = TraceRecorder(source="traffic", spec=spec)
    rec.capture(orig)
    rspec = _dc.replace(spec, source="replay", source_args={})
    rep = Service.from_spec(rspec, conf_table=conf, correct_table=correct,
                            trace=rec.events).run()
    replay = verify_replay(orig.per_request, rep.per_request)
    print(f"traffic,replay,rtdeepiot-admit,arrival_order="
          f"{replay['arrival_order']},admission={replay['admission_decisions']}")
    return rows, comp, replay


def traffic_claims(comp, replay):
    """Headline check for the traffic subsystem: at 2x sustained overload
    RTDeepIoT + admission/shedding holds admitted deadline misses < 1%
    with bounded accuracy loss while uncontrolled EDF exceeds 20% —
    and a recorded trace replays bit-for-bit."""
    o = {label: comp[("2x-overload", label)]
         for label, _, _ in TRAFFIC_VARIANTS}
    steady_acc = comp[("steady", "rtdeepiot")].accuracy
    controlled = {"rtdeepiot-admit": o["rtdeepiot-admit"],
                  "rtdeepiot-shed": o["rtdeepiot-shed"]}
    ctl_miss = max(m.admitted_miss_rate for m in controlled.values())
    ctl_acc = min((m.admitted_accuracy if m.admitted_accuracy is not None
                   else m.accuracy) for m in controlled.values())
    claims = {
        "traffic_overload_edf_miss": round(o["edf"].miss_rate, 4),
        "traffic_overload_admitted_miss": {
            k: round(m.admitted_miss_rate, 4) for k, m in controlled.items()},
        "traffic_overload_served_frac": {
            k: round(1.0 - (m.rejected / max(m.n_requests, 1)), 4)
            for k, m in controlled.items()},
        "traffic_overload_admitted_accuracy": round(ctl_acc, 4),
        "traffic_steady_rtdeepiot_accuracy": round(steady_acc, 4),
        # "bounded accuracy loss": admitted work degrades depth, it does
        # not fall off a cliff — stays within 25% of the steady-state
        # accuracy while EDF's overall accuracy collapses below it
        "traffic_overload_acc_bounded":
            bool(ctl_acc >= 0.75 * steady_acc
                 and ctl_acc > o["edf"].accuracy),
        "traffic_replay_arrival_order": bool(replay["arrival_order"]),
        "traffic_replay_admission_decisions":
            bool(replay["admission_decisions"]),
        "traffic_claim_met": bool(
            o["edf"].miss_rate > 0.20 and ctl_miss < 0.01
            and ctl_acc >= 0.75 * steady_acc and replay["bitwise"]),
    }
    print("TRAFFIC CLAIMS:", claims)
    return claims


# per-dispatch cross-replica sync cost (seconds) charged by the modeled
# sharded sweep whenever dp > 1 — deliberately pessimistic vs ICI numbers
SHARDED_COLLECTIVE = 2e-4


def fig_sharded(conf, correct, dps=(1, 2, 4), n_requests=900,
                e2e_requests=40, seed=0):
    """The ``device-sharded`` executor (repro.launch.sharded), two parts.

    **Modeled dp sweep** — virtual clock, oracle executor priced by
    ``sharded_time_model(dp)``: each data-parallel width is offered the
    ``2x-overload`` traffic scenario scaled to *its own* capacity (2x of
    dp devices), with admission control on.  Goodput (completed
    requests/s) must scale near-linearly in dp while admitted misses stay
    near zero — the "server side actually scales with offered load" claim.

    **End-to-end 1x1-mesh run** — ``ServeSpec(executor="device-sharded")``
    on the real anytime classifier, driven by the ``steady`` traffic
    scenario through the registry (``repro.launch.serve`` registers the
    executor from outside the serving package).  On this host's
    single-device fallback mesh the results must match
    ``device-batched`` **bit-for-bit**; the per-request hidden-state
    cache must be fully evicted at drain.  This is the CI leg: the full
    sharded code path (mesh build, sharding constraints, dp-divisible
    buckets, state cache) runs everywhere.
    """
    from repro.launch.sharded import sharded_time_model
    from repro.serving.batch.batcher import BatchTimeModel
    from repro.serving.traffic import scenario_spec
    rows = []
    st = _stage_times()
    base_tm = BatchTimeModel.linear(st, DEFAULT_BUCKETS, marginal=0.15)
    goodput, admitted_miss = {}, {}
    for dp in dps:
        tm_dp = sharded_time_model(base_tm, dp,
                                   collective=SHARDED_COLLECTIVE)
        spec = scenario_spec("2x-overload", policy="rtdeepiot",
                             admission={"mode": "reject"}, stage_times=st,
                             n_requests=n_requests, seed=seed)
        # offered load scales with the provisioned width: every dp level
        # faces 2x of *its own* capacity, so goodput measures scaling,
        # not saturation against a fixed arrival rate
        spec.source_args["arrival"]["rate"] *= dp
        spec.batching = {}               # the time_model resource prices it
        res = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                                time_model=tm_dp).run()
        _emit(rows, "sharded", f"dp={dp}", "rtdeepiot-admit", res)
        goodput[dp] = res.throughput
        admitted_miss[dp] = res.admitted_miss_rate
    e2e = _sharded_e2e(rows, n_requests=e2e_requests, seed=seed)
    return rows, dict(goodput=goodput, admitted_miss=admitted_miss,
                      dps=tuple(dps)), e2e


def _sharded_e2e(rows, n_requests=40, seed=0):
    """Real-model leg of the sharded figure: device-sharded vs
    device-batched on the same traffic scenario stream, virtual clock."""
    import dataclasses

    import jax

    import repro.launch.serve  # noqa: F401 — registers device-sharded
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.traffic import scenario_spec

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pool = rng.normal(size=(48, 1, 16, 32)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab_size, size=48)
    st = (0.002, 0.003, 0.004)
    base = scenario_spec(
        "steady", policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        stage_times=st, n_requests=n_requests, seed=seed)
    base.batching = {"buckets": [1, 2, 4], "stage_times": list(st),
                     "marginal": 0.25}
    runs = {}
    for ex, ea in (("device-batched", {}),
                   ("device-sharded", {"dp": 2, "tp": 1})):
        spec = dataclasses.replace(base, executor=ex, executor_args=ea)
        svc = Service.from_spec(
            spec, cfg=cfg, params=params, n_samples=len(pool), labels=labels,
            traffic_inputs=lambda s: {"features": pool[s]})
        res = svc.run()
        _emit(rows, "sharded", "e2e", ex, res)
        runs[ex] = (svc, res)

    def key(recs):
        return [(r["sample"], r["prediction"], r["conf"], r["depth"],
                 r["missed"]) for r in recs]
    sx = runs["device-sharded"][0].executor
    parity = key(runs["device-batched"][1].per_request) \
        == key(runs["device-sharded"][1].per_request)
    print(f"sharded,e2e,parity,mesh={sx.dp}x{sx.tp},"
          f"fallback={sx.fallback},bitwise={parity}")
    return dict(mesh=[sx.dp, sx.tp], fallback=sx.fallback, parity=parity,
                cache=sx.cache_stats(), n_requests=n_requests,
                served=runs["device-sharded"][1].n_requests)


def sharded_claims(modeled, e2e):
    """Headline check for the sharded executor: goodput scales >= 0.6x
    linearly in dp at < 1% admitted misses under per-width 2x overload,
    and the end-to-end 1x1-mesh run matches device-batched bit-for-bit
    with a fully-evicted hidden-state cache."""
    dps = sorted(modeled["goodput"])
    g = modeled["goodput"]
    monotone = all(g[a] <= g[b] * 1.02 for a, b in zip(dps, dps[1:]))
    scaling = g[dps[-1]] / max(g[dps[0]], 1e-9)
    miss_max = max(modeled["admitted_miss"].values())
    cache_clean = e2e["cache"]["live"] == 0 \
        and e2e["cache"]["evictions"] >= e2e["n_requests"]
    # parity is bitwise only where both runs use one device — a real
    # multi-device mesh reorders float reductions
    parity_req = (not e2e["fallback"]) and e2e["mesh"] != [1, 1]
    claims = {
        "sharded_collective_s": SHARDED_COLLECTIVE,
        "sharded_goodput_by_dp": {str(d): round(g[d], 1) for d in dps},
        "sharded_scaling": round(scaling, 2),
        "sharded_admitted_miss_max": round(miss_max, 4),
        "sharded_e2e_mesh": e2e["mesh"],
        "sharded_e2e_parity_bitwise": bool(e2e["parity"]),
        "sharded_e2e_cache": e2e["cache"],
        "sharded_claim_met": bool(
            monotone and scaling >= 0.6 * dps[-1] and miss_max < 0.01
            and (e2e["parity"] or parity_req) and cache_clean
            and e2e["served"] == e2e["n_requests"]),
    }
    print("SHARDED CLAIMS:", claims)
    return claims


# ragged traffic for the kernel figure: per-SLO-tier seq_len ranges
# spanning the length buckets (gold = full-length, bronze = short)
KERNEL_LEN_BUCKETS = (16, 64, 256)
KERNEL_SEQ_RANGES = {"gold": (96, 256), "silver": (24, 64), "bronze": (2, 16)}


def fig_kernel(conf, correct, async_comp, *, n_requests=1200,
               ragged_requests=900, e2e_requests=40, seed=0):
    """The ``device-kernel`` fast path (repro.launch.kernel), three parts.

    **Deep-pipeline modeled leg** — the async figure's charged-host-cost
    comparison extended to ``pipeline_depth=3``: the executor enqueues a
    second device window behind the running one, so the next window's
    policy selection *and* submit overhead happen inside an open window
    instead of serializing when the device idles.  Charged host-overhead
    fraction must drop to or below the async figure's floor at
    accuracy/miss equal-or-better than synchronous dispatch.

    **Ragged length-bucket leg** — 2x-overload traffic whose requests
    carry ragged ``seq_len`` (per-tier ``seq_range`` in the mix), priced
    by a ``LengthBucketTimeModel``: admission and batching charge
    ``(stage, batch-bucket, len-bucket)`` WCETs and same-stage co-runners
    batch only within a length bucket.  Admitted misses must stay < 1%.

    **End-to-end kernel leg** — ``ServeSpec(executor="device-kernel")``
    on the real anytime classifier through the ``steady`` traffic
    scenario: predictions/depths must match ``device-batched`` exactly
    (confidences to 1e-6 — the fused epilogue computes the same
    max-softmax probability by a different formula), the fused
    exit-confidence epilogue must be *bit-for-bit* the unfused reference
    in interpret mode, a ``pipeline_depth=3`` run must stack device
    windows and drain its hidden-state cache, and co-batched ragged
    decode must be bitwise equal to singleton decode.
    """
    from repro.serving.batch.time_model import LengthBucketTimeModel
    from repro.serving.traffic import scenario_spec
    rows = []
    # -- deep-pipeline modeled leg: depth 3 over the async figure's grid
    kw = dict(batched=True, charge_overhead=True,
              dispatch_overhead=ASYNC_DISPATCH_OVERHEAD,
              policy_cost=ASYNC_POLICY_COST)
    deep = {}
    for (k, name) in sorted(async_comp):
        p = "exp" if name == "rtdeepiot" else name
        res = _serve(_spec(p, pipeline_depth=3, **kw), conf, correct,
                     n_clients=k, n_requests=n_requests)
        _emit(rows, "kernel", f"K={k}", f"deep-{name}", res)
        deep[(k, name)] = dict(host_frac_deep=res.host_overhead_frac,
                               acc_deep=res.accuracy,
                               miss_deep=res.miss_rate)
    # -- ragged length-bucket leg --------------------------------------
    st = _stage_times()
    lb_tm = LengthBucketTimeModel.linear(st, DEFAULT_BUCKETS, marginal=0.15,
                                         len_buckets=KERNEL_LEN_BUCKETS)
    # the scenario's 2x is relative to the *unbatched full-length*
    # capacity; the ragged mix costs roughly half of full-length and
    # bucket-16 batching amortizes another ~4x, so 8x the nominal rate is
    # what actually sustains ~2x of this engine's mixed-length capacity.
    # headroom=4 makes admission price the full multi-stage cost (not the
    # amortized batch estimate) — rejections absorb the overload instead
    # of deadline misses
    spec = scenario_spec("2x-overload", policy="rtdeepiot",
                         admission={"mode": "reject", "headroom": 4.0},
                         stage_times=st, n_requests=ragged_requests,
                         seed=seed)
    spec.source_args["arrival"]["rate"] *= 4
    spec.batching = {}       # the LengthBucketTimeModel resource prices it
    spec.source_args["mix"] = [
        dict(c, seq_range=list(KERNEL_SEQ_RANGES[c["slo"]]))
        for c in spec.source_args["mix"]]
    res = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                            time_model=lb_tm).run()
    _emit(rows, "kernel", "ragged-2x", "rtdeepiot-admit", res)
    ragged = dict(admitted_miss=res.admitted_miss_rate,
                  served_frac=1.0 - res.rejected / max(res.n_requests, 1),
                  rejected=res.rejected, mean_depth=res.mean_depth)
    e2e = _kernel_e2e(rows, n_requests=e2e_requests, seed=seed)
    e2e["decode"] = _kernel_decode_check()
    return rows, deep, ragged, e2e


def _kernel_e2e(rows, n_requests=40, seed=0):
    """Real-model leg of the kernel figure: device-kernel vs
    device-batched on the same traffic scenario stream, plus a depth-3
    run for window stacking, telemetry and cache drain."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    import repro.launch.serve  # noqa: F401 — registers device-kernel
    from repro.configs import get_config
    from repro.models import (exit_rows, exit_stats_fused,
                              exit_stats_unfused, init_params, stage_trunk)
    from repro.serving.traffic import scenario_spec

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pool = rng.normal(size=(48, 1, 16, 32)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab_size, size=48)
    st = (0.002, 0.003, 0.004)
    base = scenario_spec(
        "steady", policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        stage_times=st, n_requests=n_requests, seed=seed)
    base.batching = {"buckets": [1, 2, 4], "stage_times": list(st),
                     "marginal": 0.25}
    runs = {}
    for label, ex, depth in (("device-batched", "device-batched", 1),
                             ("device-kernel", "device-kernel", 1),
                             ("device-kernel-deep", "device-kernel", 3)):
        spec = dataclasses.replace(base, executor=ex, pipeline_depth=depth)
        svc = Service.from_spec(
            spec, cfg=cfg, params=params, n_samples=len(pool), labels=labels,
            traffic_inputs=lambda s: {"features": pool[s]})
        res = svc.run()
        _emit(rows, "kernel", "e2e", label, res)
        runs[label] = (svc, res)

    def key(res):
        return [(r["sample"], r["prediction"], r["depth"], r["missed"])
                for r in res.per_request]
    parity = key(runs["device-batched"][1]) == key(runs["device-kernel"][1])
    conf_close = bool(np.allclose(
        [r["conf"] for r in runs["device-kernel"][1].per_request],
        [r["conf"] for r in runs["device-batched"][1].per_request],
        rtol=1e-6))
    # fused epilogue vs unfused reference on the same trunk output — the
    # bit-for-bit claim (the kernel's online pass folds exactly once on a
    # single vocab block, so interpret mode reproduces the reference)
    h = stage_trunk(cfg, params, 0, {"features": jnp.asarray(pool[:8, 0])},
                    mode="train")
    rws = exit_rows(cfg, h)
    fused = exit_stats_fused(rws, params["exits"][0]["ln"],
                             params["exit_shared"]["w_out"],
                             eps=cfg.norm_eps)
    unfused = exit_stats_unfused(rws, params["exits"][0]["ln"],
                                 params["exit_shared"]["w_out"],
                                 eps=cfg.norm_eps)
    fused_bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(fused, unfused))
    dsvc, dres = runs["device-kernel-deep"]
    times = dres.executor_times
    print(f"kernel,e2e,parity,pred_depth={parity},conf_close={conf_close},"
          f"fused_bitwise={fused_bitwise},windows={dsvc.executor.max_inflight}")
    return dict(parity=bool(parity), conf_close=conf_close,
                fused_bitwise=bool(fused_bitwise),
                max_inflight=dsvc.executor.max_inflight,
                host_time=round(float(times.get("host_time", 0.0)), 4),
                device_time=round(float(times.get("device_time", 0.0)), 4),
                cache=dres.executor_cache, n_requests=n_requests,
                served=dres.n_requests)


def _kernel_decode_check():
    """Ragged decode batching exactness: co-batched decode at ragged
    cache positions through the Pallas route must be bitwise equal to
    running each request alone (the per-row slot-position map; the
    legacy jnp route shares row 0's and is only approximately equal)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.launch.kernel import KernelDecodeStageFns
    from repro.launch.mesh import make_serving_mesh
    from repro.models import (ParallelCtx, concat_decode_caches,
                              init_decode_cache, init_params)
    cfg = ModelConfig(name="bench-decode", arch_type="dense", source="bench",
                      num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      head_dim=16, d_ff=64, vocab_size=16, period=("attn",),
                      ffn_type="swiglu", modality="text", causal=True,
                      num_stages=2, mandatory_stages=1, stage_ends=(1, 2),
                      dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ctx = ParallelCtx(mesh=make_serving_mesh(1, 1), decode_attn="kernel")
    fns = KernelDecodeStageFns(cfg, (1, 2, 4), ctx)
    rng = np.random.default_rng(0)
    S, positions, states = 8, [2, 5, 7], []
    for pos in positions:           # warm each request's cache to pos
        cache = init_decode_cache(cfg, 1, S)
        for p in range(pos):
            h = jnp.array([int(rng.integers(cfg.vocab_size))], jnp.int32)
            for s in range(cfg.num_stages):
                h, c, _pred, _conf = fns.fn(s)(
                    params, h, cache[s], jnp.full((1,), p, jnp.int32))
                cache[s] = c
        states.append({"h": jnp.array([int(rng.integers(cfg.vocab_size))],
                                      jnp.int32),
                       "cache": cache,
                       "cur_pos": jnp.full((1,), pos, jnp.int32)})
    h_b = jnp.concatenate([st["h"] for st in states])
    cur_b = jnp.concatenate([st["cur_pos"] for st in states])
    outs_b = []
    for s in range(cfg.num_stages):
        cache_b = concat_decode_caches([st["cache"][s] for st in states])
        h_b, _c, pred_b, conf_b = fns.fn(s)(params, h_b, cache_b, cur_b)
        outs_b.append((h_b, pred_b, conf_b))
    bitwise = True
    for i, st in enumerate(states):
        h = st["h"]
        for s in range(cfg.num_stages):
            h, _c, pred, conf = fns.fn(s)(params, h, st["cache"][s],
                                          st["cur_pos"])
            h_bs, pred_b, conf_b = outs_b[s]
            bitwise &= np.array_equal(np.asarray(h), np.asarray(h_bs[i:i + 1]))
            bitwise &= (int(pred[0]) == int(pred_b[i])
                        and float(conf[0]) == float(conf_b[i]))
    print(f"kernel,decode,ragged,positions={positions},bitwise={bitwise}")
    return dict(bitwise=bool(bitwise), positions=positions)


def kernel_claims(deep, ragged, e2e, async_comp):
    """Headline check for the kernel fast path: depth-3 dispatch holds
    charged host-overhead at or below the async figure's floor at
    accuracy/miss equal-or-better than synchronous dispatch; the fused
    exit epilogue is bit-for-bit the unfused reference; ragged traffic
    batched via length buckets keeps admitted misses < 1%; co-batched
    ragged decode is bitwise equal to singleton decode."""
    floor = min(c["host_frac_async"] for c in async_comp.values())
    qualifying = {}
    for (k, name), d in deep.items():
        c = async_comp[(k, name)]
        if (d["host_frac_deep"] <= floor
                and d["acc_deep"] >= c["acc_sync"]
                and d["miss_deep"] <= c["miss_sync"]):
            qualifying[f"K={k}/{name}"] = round(d["host_frac_deep"], 4)
    by_k = {}
    for (k, name) in deep:
        by_k.setdefault(k, []).append(f"K={k}/{name}" in qualifying)
    full_ks = sorted(k for k, oks in by_k.items() if all(oks))
    dec = e2e["decode"]
    claims = {
        "kernel_async_floor_host_frac": round(floor, 4),
        "kernel_deep_host_frac": {
            f"K={k}/{n}": round(d["host_frac_deep"], 4)
            for (k, n), d in sorted(deep.items())},
        "kernel_deep_qualifying_configs": qualifying,
        "kernel_deep_fully_qualifying_K": full_ks,
        "kernel_len_buckets": list(KERNEL_LEN_BUCKETS),
        "kernel_ragged_admitted_miss": round(ragged["admitted_miss"], 4),
        "kernel_ragged_served_frac": round(ragged["served_frac"], 4),
        "kernel_e2e_parity_pred_depth": bool(e2e["parity"]),
        "kernel_e2e_conf_allclose": bool(e2e["conf_close"]),
        "kernel_fused_exit_bitwise": bool(e2e["fused_bitwise"]),
        "kernel_e2e_windows": e2e["max_inflight"],
        "kernel_e2e_times": {"host_time": e2e["host_time"],
                             "device_time": e2e["device_time"]},
        "kernel_e2e_cache": e2e["cache"],
        "kernel_decode_ragged_bitwise": bool(dec["bitwise"]),
        "kernel_claim_met": bool(
            full_ks and ragged["admitted_miss"] < 0.01
            and ragged["rejected"] > 0 and e2e["parity"]
            and e2e["conf_close"] and e2e["fused_bitwise"]
            and dec["bitwise"] and e2e["cache"]["live"] == 0
            and e2e["served"] == e2e["n_requests"]),
    }
    print("KERNEL CLAIMS:", claims)
    return claims


# durable plane fairness scenario (repro.serving.plane): ~2x sustained
# overload from a heavy background tenant against a light premium tenant
# submitting at its fair share, 10:1 tenant weight skew in the light
# tenant's favor.  EDF executes optional stages of admitted work, so the
# admission headroom prices the full 3-stage cost (~5x the amortized
# mandatory-only estimate) — that is what keeps admitted misses ~0.
PLANE_HEAVY_N = 190
PLANE_HEAVY_SPAN = 2.0
PLANE_LIGHT_N = 8
PLANE_LIGHT_PERIOD = 0.25
PLANE_REL_DEADLINE = 0.08


def _plane_spec(discipline):
    return ServeSpec(
        policy="edf", executor="oracle", clock="virtual",
        source="frontdoor",
        source_args={"discipline": discipline, "run_queue": 2},
        tenants={"light": {"weight": 10.0}, "heavy": {"weight": 1.0}},
        admission={"mode": "reject", "headroom": 5.0},
        default_slo="gold",
        slo_classes={"gold": {"rel_deadline": PLANE_REL_DEADLINE}},
        batching={"mode": "none", "stage_times": list(_stage_times())})


def fig_plane(conf, correct):
    """Durable request plane (repro.serving.plane): DRR fairness vs a
    global-FIFO front door under tenant-skewed overload, idempotent
    journaled submission, and mid-stream crash recovery."""
    import shutil
    import tempfile
    import time as _time

    from repro.serving import (DurableQueue, FrontDoor, Journal, recover,
                               verify_recovery)
    from repro.serving.engine import Request

    rows, data = [], {}
    # -- fairness: DRR vs FIFO release order under tenant skew ----------
    for disc in ("drr", "fifo"):
        svc = Service.from_spec(_plane_spec(disc), conf_table=conf,
                                correct_table=correct)
        for i in range(PLANE_HEAVY_N):
            svc.submit(Request(None, sample=i % conf.shape[0],
                               tenant="heavy", request_id=f"h{i}"),
                       at=i * (PLANE_HEAVY_SPAN / PLANE_HEAVY_N))
        for i in range(PLANE_LIGHT_N):
            svc.submit(Request(None, sample=(7 * i) % conf.shape[0],
                               tenant="light", request_id=f"l{i}"),
                       at=i * PLANE_LIGHT_PERIOD)
        res = svc.drain()
        _emit(rows, "plane", "tenant-skew", disc, res)
        data[disc] = dict(
            light_served_frac=res.per_tenant["light"]["served"]
            / PLANE_LIGHT_N,
            heavy_served_frac=res.per_tenant["heavy"]["served"]
            / PLANE_HEAVY_N,
            admitted_miss=res.admitted_miss_rate)
        print(f"plane,tenant-skew,{disc},"
              f"light={data[disc]['light_served_frac']:.2f},"
              f"heavy={data[disc]['heavy_served_frac']:.2f},"
              f"amiss={data[disc]['admitted_miss']:.4f}")

    # -- idempotency + crash recovery through the journal ---------------
    spec = _plane_spec("drr")
    workdir = tempfile.mkdtemp(prefix="plane-bench-")
    try:
        ref_dir = os.path.join(workdir, "ref")
        crash_dir = os.path.join(workdir, "crash")
        n = 60
        dedup_ok = True

        def durable_run(d):
            nonlocal dedup_ok
            with Journal(d, spec=spec, fsync_every=1) as j:
                svc = Service.from_spec(spec, conf_table=conf,
                                        correct_table=correct)
                door = FrontDoor(svc, journal=j)
                hs = {}
                for i in range(n):
                    rid = f"r{i:03d}"
                    hs[rid] = door.submit(
                        Request(None, sample=i % conf.shape[0]),
                        tenant="light" if i % 5 == 0 else "heavy",
                        request_id=rid, at=i * 0.01)
                dup = door.submit(Request(None, sample=0), tenant="heavy",
                                  request_id="r001", at=0.5)
                dedup_ok &= (dup is hs["r001"]
                             and j.counts["SUBMIT"] == n)
                return svc.drain()

        ref = durable_run(ref_dir)
        durable_run(crash_dir)
        # crash: drop every journaled terminal after the 10th
        seg = os.path.join(crash_dir, "wal-000000.jsonl")
        kept, n_term = [], 0
        with open(seg) as f:
            for line in f:
                if '"kind": "RETIRE"' in line or '"kind": "REJECT"' in line:
                    n_term += 1
                    if n_term > 10:
                        continue
                kept.append(line)
        with open(seg, "w") as f:
            f.writelines(kept)
        t0 = _time.perf_counter()
        res = recover(crash_dir, conf_table=conf, correct_table=correct)
        dt = _time.perf_counter() - t0
        rep = verify_recovery(ref.per_request, res)
        _emit(rows, "plane", "recovery", "drr", res.metrics)
        data["recovery"] = dict(
            bitwise=bool(rep["bitwise"]),
            delivered_once=bool(rep["delivered_once"]),
            overlap_consistent=bool(rep["overlap_consistent"]),
            recovered=bool(rep["recovered"]),
            n_pre=res.report["n_pre_delivered"],
            n_redelivered=res.report["n_redelivered"],
            recover_seconds=round(dt, 3))
        data["idempotent_dedup"] = bool(dedup_ok)
        print(f"plane,recovery,drr,bitwise={rep['bitwise']},"
              f"once={rep['delivered_once']},"
              f"pre={res.report['n_pre_delivered']},"
              f"redone={res.report['n_redelivered']},t={dt:.3f}s")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return rows, data


def plane_claims(data):
    """Headline check for the durable plane: at ~2x overload with a 10:1
    tenant weight skew, DRR keeps the light tenant at >= 90% of its fair
    share while the FIFO front door starves it to <= 60%, both below 1%
    admitted misses; duplicate request_ids are provably idempotent and a
    mid-stream crash recovers bit-for-bit with exactly-once delivery."""
    drr, fifo, rec = data["drr"], data["fifo"], data["recovery"]
    claims = {
        "plane_drr_light_served_frac": round(drr["light_served_frac"], 4),
        "plane_fifo_light_served_frac": round(fifo["light_served_frac"], 4),
        "plane_admitted_miss": {
            "drr": round(drr["admitted_miss"], 4),
            "fifo": round(fifo["admitted_miss"], 4)},
        "plane_idempotent_dedup": bool(data["idempotent_dedup"]),
        "plane_recovery": rec,
        "plane_claim_met": bool(
            drr["light_served_frac"] >= 0.9
            and fifo["light_served_frac"] <= 0.6
            and drr["admitted_miss"] <= 0.01
            and fifo["admitted_miss"] <= 0.01
            and data["idempotent_dedup"] and rec["recovered"]),
    }
    print("PLANE CLAIMS:", claims)
    return claims


# the two-model zoo the zoo figure serves: an expensive high-weight "llm"
# head next to a cheap "vision" model on one device (3 anytime stages
# each — the oracle tables' depth axis)
ZOO_MODELS = {
    "llm": {"stage_times": [0.006, 0.010, 0.014], "marginal": 0.15,
            "weight": 2.0},
    "vision": {"stage_times": [0.003, 0.005, 0.007], "marginal": 0.15},
}


def _zoo_mix_stage_times():
    """Capacity anchor for the ``model-mix`` scenario: the mix-weighted
    mean per-stage times, so the scenario's 2.0x factor is 2x of the
    *blended* full-depth capacity (anchoring on either model alone would
    under- or over-state the overload)."""
    from repro.serving.traffic.scenarios import MODEL_MIX
    L = len(ZOO_MODELS["llm"]["stage_times"])
    tot = sum(c["share"] for c in MODEL_MIX)
    return tuple(
        sum(c["share"] * ZOO_MODELS[c["model"]]["stage_times"][s]
            for c in MODEL_MIX) / tot
        for s in range(L))


def _zoo_tables(conf, correct):
    """Per-model oracle tables: llm reads the trained tables as-is,
    vision a sample-rolled view — per-sample curves differ across models
    while confidence/correctness stay consistent within each."""
    roll = conf.shape[0] // 3
    return {"llm": {"conf": conf, "correct": correct},
            "vision": {"conf": np.roll(conf, roll, axis=0),
                       "correct": np.roll(correct, roll, axis=0)}}


def _zoo_weighted(res, ztabs):
    """Weighted admitted accuracy with the paper's utility-accrual
    semantics (a missed deadline earns zero, whatever the late answer
    was); weights are the end-to-end ``Task.weight`` = SLO utility
    weight x model weight."""
    num = den = 0.0
    adm = miss = 0
    for r in res.per_request:
        if r["rejected"]:
            continue
        adm += 1
        miss += int(r["missed"])
        w = float(r.get("weight") or 1.0)
        den += w
        ok = (not r["missed"]) and r["depth"] >= 1 and bool(
            ztabs[r["model"]]["correct"][r["sample"], r["depth"] - 1])
        num += w * float(ok)
    return dict(weighted_acc=num / den if den else 0.0,
                admitted_miss=miss / adm if adm else 0.0, admitted=adm)


def fig_zoo(conf, correct, n_requests=600, e2e_requests=24, seed=0):
    """The multi-model zoo (repro.serving.zoo), two parts.

    **Cross-model preemption** — the ``model-mix`` scenario (2x of the
    blended two-model capacity) through ``policy="rtdeepiot-zoo"`` with
    admission on, ``scope="global"`` (one FPTAS over both models: sheds
    the globally least-valuable optional stages, whichever model owns
    them) vs ``scope="siloed"`` (each model planned independently against
    the full device — every silo believes it owns the machine, so the
    union plan overcommits).  Scored on weighted admitted accuracy.

    **Single-model parity** — a one-model zoo spec
    (``executor="zoo-device"`` + ``rtdeepiot-zoo``) on the real anytime
    classifier must reproduce the plain ``device-batched`` +
    ``rtdeepiot`` run **bit-for-bit**: the blended time model of a
    single-member zoo *is* that member's table, so the zoo machinery adds
    nothing but the model id.
    """
    from repro.serving.traffic import scenario_spec
    rows = []
    st = _zoo_mix_stage_times()
    ztabs = _zoo_tables(conf, correct)
    data = {"models": {m: dict(cfg) for m, cfg in ZOO_MODELS.items()}}
    for label, scope in (("zoo-global", "global"), ("zoo-siloed", "siloed")):
        spec = _dc.replace(
            scenario_spec(
                "model-mix", policy="rtdeepiot-zoo",
                policy_args={"predictor": "exp", "scope": scope},
                admission={"mode": "reject"}, stage_times=st,
                n_requests=n_requests, seed=seed, models=ZOO_MODELS),
            executor="zoo-oracle")
        res = Service.from_spec(spec, zoo_tables=ztabs,
                                n_samples=conf.shape[0]).run()
        _emit(rows, "zoo", "model-mix", label, res)
        data[scope] = _zoo_weighted(res, ztabs)
        data[scope]["per_model"] = res.per_model
        for m, pm in sorted(res.per_model.items()):
            print(f"zoo,model-mix/{m},{label},served={pm['served']},"
                  f"rejected={pm['rejected']},miss={pm['miss_rate']:.4f},"
                  f"depth={pm['mean_depth']:.2f},"
                  f"wacc={pm['weighted_accuracy']}")
        print(f"zoo,model-mix,{label},"
              f"wacc={data[scope]['weighted_acc']:.4f},"
              f"amiss={data[scope]['admitted_miss']:.4f},"
              f"admitted={data[scope]['admitted']}")
    e2e = _zoo_e2e(rows, n_requests=e2e_requests, seed=seed)
    return rows, data, e2e


def _zoo_e2e(rows, n_requests=24, seed=0):
    """Real-model leg of the zoo figure: a single-member zoo
    (zoo-device + rtdeepiot-zoo) vs the plain device-batched path on the
    same traffic stream, virtual clock, bit-for-bit."""
    import dataclasses

    import jax

    import repro.launch.serve  # noqa: F401 — registers zoo-device
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.traffic import scenario_spec

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pool = rng.normal(size=(48, 1, 16, 32)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab_size, size=48)
    st = (0.002, 0.003, 0.004)
    base = scenario_spec(
        "steady", policy="rtdeepiot",
        policy_args={"predictor": "exp", "prior_curve": [0.5, 0.7, 0.85]},
        stage_times=st, n_requests=n_requests, seed=seed)
    base.batching = {"buckets": [1, 2, 4], "stage_times": list(st),
                     "marginal": 0.25}
    # the zoo leg: same stream, every request tagged with the one model
    zspec = dataclasses.replace(
        base, executor="zoo-device", policy="rtdeepiot-zoo",
        models={"m": {"stage_times": list(st), "buckets": [1, 2, 4],
                      "marginal": 0.25}},
        source_args={**base.source_args,
                     "mix": [dict(c, model="m")
                             for c in base.source_args["mix"]]})
    common = dict(cfg=cfg, params=params, n_samples=len(pool),
                  labels=labels,
                  traffic_inputs=lambda s: {"features": pool[s]})
    runs = {}
    for name, spec, extra in (
            ("device-batched",
             dataclasses.replace(base, executor="device-batched"), {}),
            ("zoo-device", zspec,
             {"zoo_models": {"m": {"cfg": cfg, "params": params}}})):
        svc = Service.from_spec(spec, **common, **extra)
        res = svc.run()
        _emit(rows, "zoo", "e2e", name, res)
        runs[name] = (svc, res)

    def key(recs):
        return [(r["sample"], r["prediction"], r["conf"], r["depth"],
                 r["missed"]) for r in recs]
    zx = runs["zoo-device"][0].executor
    parity = key(runs["device-batched"][1].per_request) \
        == key(runs["zoo-device"][1].per_request)
    print(f"zoo,e2e,parity,bitwise={parity}")
    return dict(parity=parity, cache=zx.cache_stats(),
                n_requests=n_requests,
                served=runs["zoo-device"][1].n_requests)


def zoo_claims(data, e2e):
    """Headline check for the model zoo: under 2x mixed-model overload,
    global cross-model shedding scores >= per-model-siloed planning on
    weighted admitted accuracy at < 1% admitted misses, and a
    single-member zoo spec reproduces the device-batched path
    bit-for-bit with a fully-evicted state cache."""
    g, s = data["global"], data["siloed"]
    cache_clean = e2e["cache"]["live"] == 0 \
        and e2e["cache"]["evictions"] >= e2e["n_requests"]
    claims = {
        "zoo_models": sorted(data["models"]),
        "zoo_overload_weighted_admitted_acc": {
            "global": round(g["weighted_acc"], 4),
            "siloed": round(s["weighted_acc"], 4)},
        "zoo_overload_admitted_miss": {
            "global": round(g["admitted_miss"], 4),
            "siloed": round(s["admitted_miss"], 4)},
        "zoo_overload_admitted": {"global": g["admitted"],
                                  "siloed": s["admitted"]},
        "zoo_e2e_parity_bitwise": bool(e2e["parity"]),
        "zoo_e2e_cache": e2e["cache"],
        "zoo_claim_met": bool(
            g["weighted_acc"] >= s["weighted_acc"] - 1e-9
            and g["admitted_miss"] < 0.01
            and e2e["parity"] and cache_clean
            and e2e["served"] == e2e["n_requests"]),
    }
    print("ZOO CLAIMS:", claims)
    return claims


def fig_obs(conf, correct, *, k=32, n_requests=600, reps=3,
            overload_requests=300, write_trace=False):
    """Observability layer (repro.serving.obs): the acceptance bar is
    that full tracing is cheap enough to leave on — measured wall-clock
    overhead on the batch figure's config, plus the three correctness
    claims (bitwise parity, audit coverage at 2x overload, valid Chrome
    trace_event export)."""
    import time

    from repro.serving import validate_chrome_trace
    from repro.serving.traffic import scenario_spec

    rows = []
    wl_kwargs = dict(n_clients=k, n_requests=n_requests)
    base = _spec("exp", batched=True, admission={"mode": "depth_cap"})

    def run_once(trace):
        spec = _dc.replace(base, trace=dict(trace))
        t0 = time.perf_counter()
        res = _serve(spec, conf, correct, **wl_kwargs)
        return time.perf_counter() - t0, res

    # interleaved best-of-reps: tracing-on and -off alternate so drift
    # (thermal, allocator state) hits both arms equally
    best = {"off": float("inf"), "on": float("inf")}
    res_off = res_on = None
    for _ in range(reps):
        for label, trace in (("off", {}), ("on", {"enabled": True})):
            dt, res = run_once(trace)
            if dt < best[label]:
                best[label] = dt
            if label == "off":
                res_off = res
            else:
                res_on = res
    overhead = best["on"] / best["off"] - 1.0
    _emit(rows, "obs", f"K={k}", "batched-rtdeepiot", res_off)
    _emit(rows, "obs", f"K={k}", "batched-rtdeepiot-traced", res_on)
    print(f"obs,K={k},trace_overhead={overhead:+.4f} "
          f"(off={best['off']:.3f}s on={best['on']:.3f}s)")

    def _sig(res):
        obs_keys = ("queue_wait", "host_time", "device_time", "decision",
                    "tid")
        per = [tuple(sorted((kk, vv) for kk, vv in r.items()
                            if kk not in obs_keys))
               for r in res.per_request]
        return (res.accuracy, res.miss_rate, res.mean_depth, res.mean_conf,
                res.makespan, res.throughput, res.n_dispatches, per)

    bitwise = _sig(res_on) == _sig(res_off)

    # audit coverage: every rejected/capped request at 2x overload has an
    # audit entry naming the rule that fired
    spec = scenario_spec("2x-overload", stage_times=_stage_times(),
                         n_requests=overload_requests,
                         admission={"mode": "reject", "headroom": 3.0},
                         trace={"enabled": True})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    svc.run()
    audited = {row["tid"] for row in svc.obs.audit_log}
    degraded = [tr for tr in svc.obs.traces.values()
                if tr.rejected or tr.depth_cap is not None]
    coverage = (sum(1 for tr in degraded if tr.tid in audited)
                / len(degraded)) if degraded else 0.0
    print(f"obs,2x-overload,degraded={len(degraded)},"
          f"audit_rows={len(svc.obs.audit_log)},coverage={coverage:.3f}")

    doc = svc.obs.chrome_trace()
    problems = validate_chrome_trace(doc)
    if write_trace:
        os.makedirs(ART, exist_ok=True)
        path = os.path.join(ART, "obs_trace.json")
        with open(path, "w") as f:
            json.dump(doc, f)
        print(f"obs,chrome_trace,{path},{len(doc['traceEvents'])} events")
    data = dict(overhead=overhead, bitwise=bitwise, coverage=coverage,
                chrome_problems=problems, n_degraded=len(degraded))
    return rows, data


def fig_adaptive(conf, correct, n_requests=600, seed=11):
    """Adaptive control (repro.serving.adaptive), three parts.

    **Workload identification** — record "yesterday's" flash-crowd run,
    fit every arrival kind from the per_request offsets, and check
    :func:`fit_report` names ``flash-crowd`` as the best explanation.

    **Predictive vs reactive admission** — replay "today" (same process,
    different seed) twice: a reactive static ``depth_cap`` controller vs
    the same controller armed with yesterday's fitted process as a
    forecast.  The forecast sheds optional stages *before* the spike
    lands, so the predictive arm takes strictly fewer admitted deadline
    misses at equal-or-better admitted accuracy.

    **Learned curves vs the oracle table** — ``rtdeepiot-adaptive``
    (FPTAS against an :class:`OnlineCurveEstimator` fed by observed
    stage exits) warms its tables on one steady run, then a measured
    run on fresh traffic must land within 2% of the oracle-predictor
    policy's accuracy.

    Runs at full size even under ``--smoke``: all seven runs are
    virtual-clock and the claims' margins don't survive shrinking (a
    spike-truncated record reads as MMPP, not flash-crowd).
    """
    from repro.serving.adaptive import OnlineCurveEstimator, fit_report
    from repro.serving.traffic import scenario_spec
    rows = []
    st = _stage_times()
    data = {}

    def scen_run(name, *, policy="rtdeepiot", pargs=None, admission=None,
                 run_seed=0, **res):
        spec = scenario_spec(name, policy=policy,
                             policy_args=pargs
                             if pargs is not None else {"predictor": "exp"},
                             admission=admission or {}, stage_times=st,
                             n_requests=n_requests, seed=run_seed)
        return Service.from_spec(spec, conf_table=conf,
                                 correct_table=correct, **res).run()

    # -- yesterday: record, fit, identify -------------------------------
    rec = scen_run("flash-crowd", admission={"mode": "depth_cap"},
                   run_seed=seed)
    fit = fit_report([r["offset"] for r in rec.per_request])
    data["fit"] = {"best": fit["best"], "scores": fit["scores"],
                   "n_arrivals": fit["n_arrivals"],
                   "params": fit["fits"][fit["best"]]}
    print(f"adaptive,fit,best={fit['best']},"
          + ",".join(f"{k}={v}" for k, v in sorted(fit["scores"].items())))
    # horizon 0.1: long lookahead over-caps the pre-spike lull and costs
    # admitted accuracy on the trained tables; 0.1 still clears the spike
    forecast = {"process": fit["fits"][fit["best"]], "horizon": 0.1}

    # -- today: reactive vs forecast-armed admission --------------------
    arms = {}
    for label, adm in (("reactive", {"mode": "depth_cap"}),
                       ("predictive", {"mode": "depth_cap",
                                       "forecast": forecast})):
        res = scen_run("flash-crowd", admission=adm, run_seed=seed + 1)
        _emit(rows, "adaptive", "flash-crowd", label, res)
        n_admitted = res.n_requests - res.rejected
        arms[label] = {
            "admitted_misses": int(round(res.admitted_miss_rate
                                         * n_admitted)),
            "admitted_accuracy": res.admitted_accuracy,
            "capped": res.capped}
        print(f"adaptive,flash-crowd,{label},"
              f"admitted_misses={arms[label]['admitted_misses']},"
              f"admitted_acc={arms[label]['admitted_accuracy']:.4f},"
              f"capped={arms[label]['capped']}")
    data["admission"] = arms

    # -- learned curves vs the oracle table -----------------------------
    oracle = scen_run("steady", pargs={"predictor": "oracle"},
                      run_seed=seed + 11)
    _emit(rows, "adaptive", "steady", "rtdeepiot-oracle", oracle)
    est = OnlineCurveEstimator(num_stages=conf.shape[1],
                               prior=[0.5, 0.7, 0.85])
    warmup = scen_run("steady", policy="rtdeepiot-adaptive", pargs={},
                      run_seed=seed + 10, curve_estimator=est)
    _emit(rows, "adaptive", "steady-warmup", "rtdeepiot-adaptive", warmup)
    warm = scen_run("steady", policy="rtdeepiot-adaptive", pargs={},
                    run_seed=seed + 11, curve_estimator=est)
    _emit(rows, "adaptive", "steady", "rtdeepiot-adaptive", warm)
    data["curves"] = {"oracle_acc": oracle.accuracy,
                      "adaptive_acc": warm.accuracy,
                      "n_observed": est.n_observed,
                      "learned_curve": [round(float(x), 4)
                                        for x in est.curve()]}
    print(f"adaptive,steady,curves,oracle={oracle.accuracy:.4f},"
          f"adaptive={warm.accuracy:.4f},n_observed={est.n_observed}")
    return rows, data


def adaptive_claims(data):
    """Headline check for adaptive control: the fitted report identifies
    the flash-crowd workload, forecast-armed admission takes strictly
    fewer admitted deadline misses than the reactive controller at
    equal-or-better admitted accuracy, and the learned-curve policy
    lands within 2% of the oracle-table policy after one warm-up run."""
    adm, cur = data["admission"], data["curves"]
    claims = {
        "adaptive_fit_best": data["fit"]["best"],
        "adaptive_admitted_misses": {
            "reactive": adm["reactive"]["admitted_misses"],
            "predictive": adm["predictive"]["admitted_misses"]},
        "adaptive_admitted_accuracy": {
            "reactive": round(adm["reactive"]["admitted_accuracy"], 4),
            "predictive": round(adm["predictive"]["admitted_accuracy"], 4)},
        "adaptive_oracle_gap": round(cur["adaptive_acc"]
                                     - cur["oracle_acc"], 4),
        "adaptive_learned_curve": cur["learned_curve"],
        "adaptive_claim_met": bool(
            data["fit"]["best"] == "flash-crowd"
            and adm["predictive"]["admitted_misses"]
            < adm["reactive"]["admitted_misses"]
            and adm["predictive"]["admitted_accuracy"]
            >= adm["reactive"]["admitted_accuracy"] - 1e-9
            and cur["adaptive_acc"] >= cur["oracle_acc"] - 0.02),
    }
    print("ADAPTIVE CLAIMS:", claims)
    return claims


def obs_claims(data, gate_overhead=True):
    """Headline check for the observability layer: full tracing costs
    < 5% wall clock on the batch figure, schedules bit-for-bit
    identically, audits every degraded request, and exports a valid
    Chrome trace_event document.  ``gate_overhead=False`` drops the
    overhead bound from the verdict — the smoke leg's runs are too
    short (~0.1s) for the wall-clock fraction to be signal; the
    ``--only obs`` leg measures it at full size and asserts it."""
    claims = {
        "obs_trace_overhead_frac": round(data["overhead"], 4),
        "obs_bitwise_identical": bool(data["bitwise"]),
        "obs_audit_coverage": round(data["coverage"], 4),
        "obs_chrome_trace_valid": not data["chrome_problems"],
        "obs_claim_met": bool(
            (not gate_overhead or data["overhead"] < 0.05)
            and data["bitwise"] and data["coverage"] == 1.0
            and not data["chrome_problems"]),
    }
    print("OBS CLAIMS:", claims)
    return claims


def summarize_claims(all_rows):
    """Validate the paper's headline claims on our reproduction."""
    byfig = {}
    for r in all_rows:
        byfig.setdefault((r["figure"], r["config"]), {})[r["policy"]] = r
    gains, exp_vs_opt = [], []
    per_baseline = {b: [] for b in ("edf", "lcf", "rr")}
    miss_rt, miss_edf = [], []
    for (fig, cfgk), pol in byfig.items():
        if fig in ("fig6_7", "fig8_9", "fig10_11") and "rtdeepiot" in pol:
            base = max(pol[p]["accuracy"] for p in ("edf", "lcf", "rr")
                       if p in pol)
            gains.append(pol["rtdeepiot"]["accuracy"] - base)
            for b in per_baseline:
                if b in pol:
                    per_baseline[b].append(pol["rtdeepiot"]["accuracy"]
                                           - pol[b]["accuracy"])
            miss_rt.append(pol["rtdeepiot"]["miss_rate"])
            if "edf" in pol:
                miss_edf.append(pol["edf"]["miss_rate"])
        if fig.startswith("fig3") and "rtdeepiot-exp" in pol \
                and "rtdeepiot-oracle" in pol:
            exp_vs_opt.append(pol["rtdeepiot-oracle"]["accuracy"]
                              - pol["rtdeepiot-exp"]["accuracy"])
    claims = {
        "max_gain_over_best_baseline": max(gains) if gains else None,
        "mean_gain_over_best_baseline": float(np.mean(gains)) if gains else None,
        "mean_gain_over_edf": float(np.mean(per_baseline["edf"])),
        "max_gain_over_edf": float(np.max(per_baseline["edf"])),
        "mean_gain_over_lcf": float(np.mean(per_baseline["lcf"])),
        "mean_gain_over_rr": float(np.mean(per_baseline["rr"])),
        "rtdeepiot_mean_miss": float(np.mean(miss_rt)),
        "edf_mean_miss": float(np.mean(miss_edf)),
        "exp_within_of_oracle_mean": float(np.mean(exp_vs_opt))
        if exp_vs_opt else None,
    }
    print("CLAIMS:", claims)
    return claims


def batch_claims(speedups):
    """Headline check for the batched subsystem: at some offered load the
    batched engine sustains >= 3x unbatched goodput without giving up
    accuracy (>= unbatched - 1 point)."""
    qualifying = {f"K={k}/{name}": round(sp, 2)
                  for (k, name), (sp, dacc) in speedups.items()
                  if sp >= 3.0 and dacc >= -0.01}
    best = max(sp for sp, _ in speedups.values())
    claims = {"batch_best_speedup": round(best, 2),
              "batch_speedup_ge_3x_configs": qualifying,
              "batch_claim_met": bool(qualifying)}
    print("BATCH CLAIMS:", claims)
    return claims


def async_claims(comp):
    """Headline check for pipelined dispatch: strictly lower charged
    host-overhead fraction than synchronous batched dispatch at
    equal-or-better accuracy and miss rate, K >= 16."""
    qualifying = {}
    for (k, name), c in comp.items():
        if (c["host_frac_async"] < c["host_frac_sync"]
                and c["acc_delta"] >= 0.0 and c["miss_delta"] <= 0.0):
            qualifying[f"K={k}/{name}"] = dict(
                host_frac=f"{c['host_frac_sync']:.4f}->"
                          f"{c['host_frac_async']:.4f}",
                goodput_ratio=round(c["goodput_ratio"], 3))
    reduction = [c["host_frac_sync"] - c["host_frac_async"]
                 for c in comp.values()]
    # claim met only where a whole load level qualifies: some K >= 16 at
    # which EVERY measured policy shows the improvement
    by_k = {}
    for (k, name) in comp:
        by_k.setdefault(k, []).append(f"K={k}/{name}" in qualifying)
    full_ks = sorted(k for k, oks in by_k.items() if k >= 16 and all(oks))
    claims = {
        "async_policy_cost": ASYNC_POLICY_COST,
        "async_dispatch_overhead": ASYNC_DISPATCH_OVERHEAD,
        "async_mean_host_frac_reduction": float(np.mean(reduction)),
        "async_qualifying_configs": qualifying,
        "async_fully_qualifying_K": full_ks,
        "async_claim_met": bool(full_ks),
    }
    print("ASYNC CLAIMS:", claims)
    return claims


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workloads, synthetic tables if artifact "
                         "missing, no artifact writes (CI job)")
    ap.add_argument("--only", choices=("plane", "zoo", "obs", "adaptive"),
                    default=None,
                    help="run a single figure and merge its rows/claims "
                         "into artifacts/scheduling_results.json")
    args = ap.parse_args(argv)

    if args.only is not None:
        # partial regen: these figures need no trained artifact —
        # synthetic tables are deterministic and the claims are about
        # scheduling, not accuracy
        path = os.path.join(ART, "oracle_tables.npz")
        if os.path.exists(path):
            z = np.load(path)
            conf, correct = z["confidence"], z["correct"]
        else:
            conf, correct = synthetic_tables()
        if args.only == "plane":
            rows, pdata = fig_plane(conf, correct)
            claims = plane_claims(pdata)
        elif args.only == "obs":
            # the overhead claim is about the batch figure's regime, so
            # measure at full size; best-of-5 keeps the minimum stable
            # against scheduler noise on shared CI runners
            rows, odata = fig_obs(conf, correct, reps=5)
            claims = obs_claims(odata)
        elif args.only == "adaptive":
            rows, adata = fig_adaptive(conf, correct)
            claims = adaptive_claims(adata)
        else:
            rows, zdata, ze2e = fig_zoo(conf, correct)
            claims = zoo_claims(zdata, ze2e)
        os.makedirs(ART, exist_ok=True)
        out = os.path.join(ART, "scheduling_results.json")
        blob = {"rows": [], "claims": {}}
        if os.path.exists(out):
            with open(out) as f:
                blob = json.load(f)
        blob["rows"] = [r for r in blob.get("rows", [])
                        if r.get("figure") != args.only] + rows
        blob.setdefault("claims", {}).update(claims)
        with open(out, "w") as f:
            json.dump(blob, f, indent=1)
        return rows, claims

    conf, correct, _ = load_tables(smoke=args.smoke)
    if args.smoke:
        DEFAULTS["n_requests"] = 80
        DEFAULTS["n_clients"] = 8
        rows = []
        rows += fig3_5_utility_heuristics(conf, correct, ks=(8,), dus=(0.3,),
                                          dls=(0.01,))
        rows += fig6_7_scheduler_comparison(conf, correct, ks=(8, 24))
        rows += fig8_11_deadline_sweeps(conf, correct, dus=(0.2,),
                                        dls=(0.03,))
        rows += fig12_delta_sweep(conf, correct, deltas=(0.2, 0.05))
        rows += fig13_overhead(conf, correct, ks=(8,))
        brows, speedups = fig_batch_throughput(conf, correct, ks=(24,),
                                               n_requests=200)
        rows += brows
        arows, comp = fig_async_dispatch(conf, correct, ks=(16,),
                                         n_requests=200)
        rows += arows
        trows, tcomp, replay = fig_traffic(conf, correct, n_requests=150)
        rows += trows
        srows, smodeled, se2e = fig_sharded(conf, correct, n_requests=150,
                                            e2e_requests=12)
        rows += srows
        krows, kdeep, kragged, ke2e = fig_kernel(
            conf, correct, comp, n_requests=200, ragged_requests=150,
            e2e_requests=12)
        rows += krows
        prows, pdata = fig_plane(conf, correct)
        rows += prows
        zrows, zdata, ze2e = fig_zoo(conf, correct, n_requests=150,
                                     e2e_requests=12)
        rows += zrows
        orows, odata = fig_obs(conf, correct, k=16, n_requests=150,
                               reps=2, overload_requests=150)
        rows += orows
        adrows, adata = fig_adaptive(conf, correct)
        rows += adrows
        claims = summarize_claims(rows)
        claims.update(batch_claims(speedups))
        claims.update(async_claims(comp))
        claims.update(traffic_claims(tcomp, replay))
        claims.update(sharded_claims(smodeled, se2e))
        claims.update(kernel_claims(kdeep, kragged, ke2e, comp))
        claims.update(plane_claims(pdata))
        claims.update(zoo_claims(zdata, ze2e))
        # smoke runs are ~0.1s — too short for the overhead fraction to
        # be signal; the --only obs leg asserts it at full size
        claims.update(obs_claims(odata, gate_overhead=False))
        claims.update(adaptive_claims(adata))
        print(f"SMOKE OK: {len(rows)} rows")
        return rows, claims

    rows = []
    rows += fig3_5_utility_heuristics(conf, correct)
    rows += fig6_7_scheduler_comparison(conf, correct)
    rows += fig8_11_deadline_sweeps(conf, correct)
    rows += fig12_delta_sweep(conf, correct)
    rows += fig13_overhead(conf, correct)
    brows, speedups = fig_batch_throughput(conf, correct)
    rows += brows
    arows, comp = fig_async_dispatch(conf, correct)
    rows += arows
    trows, tcomp, replay = fig_traffic(conf, correct)
    rows += trows
    srows, smodeled, se2e = fig_sharded(conf, correct)
    rows += srows
    krows, kdeep, kragged, ke2e = fig_kernel(conf, correct, comp)
    rows += krows
    prows, pdata = fig_plane(conf, correct)
    rows += prows
    zrows, zdata, ze2e = fig_zoo(conf, correct)
    rows += zrows
    orows, odata = fig_obs(conf, correct, write_trace=True)
    rows += orows
    adrows, adata = fig_adaptive(conf, correct)
    rows += adrows
    claims = summarize_claims(rows)
    claims.update(batch_claims(speedups))
    claims.update(async_claims(comp))
    claims.update(traffic_claims(tcomp, replay))
    claims.update(sharded_claims(smodeled, se2e))
    claims.update(kernel_claims(kdeep, kragged, ke2e, comp))
    claims.update(plane_claims(pdata))
    claims.update(zoo_claims(zdata, ze2e))
    claims.update(obs_claims(odata))
    claims.update(adaptive_claims(adata))
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "scheduling_results.json"), "w") as f:
        json.dump({"rows": rows, "claims": claims}, f, indent=1)
    return rows, claims


if __name__ == "__main__":
    main()
