"""Kernel microbenchmarks: interpret-mode wall time (CPU, correctness-scale)
plus the analytic VMEM working set per BlockSpec tile — the quantity that
determines whether a tile choice fits v5e VMEM (128 MiB/core budget split
across buffers).  Prints name,us_per_call,derived CSV.

``--smoke`` runs every kernel once at reduced shapes (single timing rep) —
the CI bench-smoke leg that keeps all five kernel dispatch paths alive
without the full-shape interpret-mode cost.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

#: --smoke shrinks the dominant shape axes and times a single rep; full
#: runs keep the VMEM-analysis shapes
SMOKE = False


def _shape(full, small):
    return small if SMOKE else full


def _reps():
    return 1 if SMOKE else 3


def _time(fn, *args, n=None):
    n = n or _reps()
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention import flash_attention_op
    B, H, KV, S, dh = 1, 4, 2, _shape(256, 64), 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, KV, S, dh))
    v = jax.random.normal(ks[2], (B, KV, S, dh))
    us = _time(lambda *a: flash_attention_op(*a, block_q=128, block_k=128), q, k, v)
    # VMEM per grid step: q tile + k tile + v tile + fp32 acc
    vmem = (128 * dh * 2) * 3 + 128 * dh * 4 + 2 * 128 * 4
    print(f"flash_attention,{us:.0f},vmem_tile_bytes={vmem}")


def bench_decode_attention():
    from repro.kernels.decode_attention import decode_attention_op
    B, H, KV, S, dh = 4, 8, 2, _shape(1024, 128), 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    kc = jax.random.normal(ks[1], (B, KV, S, dh))
    vc = jax.random.normal(ks[2], (B, KV, S, dh))
    sp = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.full((B,), S - 1)
    us = _time(lambda *a: decode_attention_op(*a, block_k=256), q, kc, vc, sp, cur)
    vmem = 256 * dh * 2 * 2 + dh * 4 + 256 * 4
    print(f"decode_attention,{us:.0f},vmem_tile_bytes={vmem}")


def bench_exit_confidence():
    from repro.kernels.exit_confidence import exit_confidence_op
    N, d, V = 8, 256, _shape(32768, 2048)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    h = jax.random.normal(ks[0], (N, d))
    sc = 0.1 * jax.random.normal(ks[1], (d,))
    w = 0.3 * jax.random.normal(ks[2], (d, V))
    us = _time(lambda *a: exit_confidence_op(*a, block_rows=8, block_v=512),
               h, sc, w)
    vmem = 8 * d * 4 + d * 512 * 2 + 8 * 512 * 4
    print(f"exit_confidence,{us:.0f},vmem_tile_bytes={vmem}")


def bench_rmsnorm():
    from repro.kernels.rmsnorm import rmsnorm_op
    x = jax.random.normal(jax.random.PRNGKey(3), (_shape(1024, 128), 512))
    s = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (512,))
    us = _time(lambda *a: rmsnorm_op(*a, block_rows=256), x, s)
    print(f"rmsnorm,{us:.0f},vmem_tile_bytes={256 * 512 * 4}")


def bench_mlstm_chunk():
    from repro.kernels.mlstm_chunk import mlstm_chunk_op
    import jax.numpy as jnp
    B, H, L, dh = 2, 4, _shape(128, 32), 64
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, H, L, dh))
    k = jax.random.normal(ks[1], (B, H, L, dh))
    v = jax.random.normal(ks[2], (B, H, L, dh))
    ip = jax.random.normal(ks[3], (B, H, L))
    fp = jax.random.normal(ks[4], (B, H, L)) + 2
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.full((B, H), -1e30)
    us = _time(lambda *a: mlstm_chunk_op(*a)[0], q, k, v, ip, fp, C0, n0, m0)
    vmem = 3 * L * dh * 4 + L * L * 4 + dh * dh * 4
    print(f"mlstm_chunk,{us:.0f},vmem_tile_bytes={vmem}")


def main(argv=None):
    global SMOKE
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes, one timing rep (CI)")
    SMOKE = ap.parse_args(argv).smoke
    bench_flash_attention()
    bench_decode_attention()
    bench_exit_confidence()
    bench_rmsnorm()
    bench_mlstm_chunk()


if __name__ == "__main__":
    main()
