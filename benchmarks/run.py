"""Benchmark orchestrator: one entry per paper table/figure + the system
benches.  ``PYTHONPATH=src python -m benchmarks.run [--quick]``

Every scheduling engine is declared as a ``ServeSpec`` and run through
``repro.serving.Service`` (see docs/serving-api.md) — the scheduling
block covers the paper figures plus the ``batch`` / ``async`` /
``traffic`` / ``sharded`` serving-extension figures and records their
claims in ``artifacts/scheduling_results.json``.

Prints ``name,us_per_call,derived`` style CSV blocks per bench.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="ServeSpec-driven scheduling benches with fewer "
                         "requests per figure")
    ap.add_argument("--only", default=None,
                    choices=[None, "scheduling", "kernels", "roofline",
                             "ablations"])
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.only in (None, "scheduling"):
        print("== scheduling benchmarks (paper Figs. 3-13) ==")
        from benchmarks import bench_scheduling
        if args.quick:
            bench_scheduling.DEFAULTS["n_requests"] = 200
        try:
            bench_scheduling.main()
        except FileNotFoundError as e:
            print(f"SKIP scheduling: {e}", file=sys.stderr)
    if args.only in (None, "kernels"):
        print("== kernel microbenchmarks ==")
        from benchmarks import bench_kernels
        bench_kernels.main()
    if args.only in (None, "ablations"):
        print("== scheduler ablations (beyond paper) ==")
        from benchmarks import bench_ablations
        try:
            bench_ablations.main()
        except FileNotFoundError as e:
            print(f"SKIP ablations: {e}", file=sys.stderr)
    if args.only in (None, "roofline"):
        print("== roofline table (from dry-run artifacts) ==")
        from benchmarks import bench_roofline
        try:
            bench_roofline.main()
        except Exception as e:  # noqa: BLE001
            print(f"SKIP roofline: {e}", file=sys.stderr)
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
