"""Beyond-paper ablations of the RTDeepIoT scheduler (not in the paper):

  greedy     Eq. 7 greedy reassignment ON vs OFF (arrival-only planning)
  mandatory  ω = 1 vs 2 mandatory stages (service floor vs shedding freedom)
  miscalib   confidence miscalibration sensitivity: oracle tables with
             confidences sharpened/flattened (t = 0.5 / 2.0 in probability
             space) — how robust is utility-maximizing scheduling to a
             badly calibrated utility metric?
  replan     full DP recompute on every stage completion (upper bound the
             greedy heuristic approximates) — quantifies what Eq. 7 gives up

Prints name,value CSV rows; writes artifacts/ablation_results.json.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import RTDeepIoT, Workload, make_predictor
from repro.serving import ServeSpec, Service

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
WL = dict(n_clients=20, d_lo=0.01, d_hi=0.3, n_requests=500)
TIMES = (0.004, 0.007, 0.010)


def _tables():
    z = np.load(os.path.join(ART, "oracle_tables.npz"))
    return z["confidence"], z["correct"]


class RTDeepIoTNoGreedy(RTDeepIoT):
    """Arrival-only planning: stage completions never adjust depths."""

    def on_stage_done(self, active, task, now):
        self.invocations += 1


class RTDeepIoTFullReplan(RTDeepIoT):
    """Full DP recompute on every stage completion (greedy's upper bound)."""

    def on_stage_done(self, active, task, now):
        self._replan([t for t in active if t.deadline > now], now)


def run(policy, conf, correct, **wl):
    # the ablation policies are ad-hoc subclasses, so they ride as a
    # component *instance* resource; everything else is the declared spec
    spec = ServeSpec(executor="oracle", clock="virtual", source="closed-loop",
                     batching={"mode": "none", "stage_times": list(TIMES)})
    return Service.from_spec(spec, policy=policy,
                             workload=Workload(**{**WL, **wl}),
                             conf_table=conf, correct_table=correct).run()


def main():
    conf, correct = _tables()
    prior = conf.mean(0)
    rows = {}

    for k in (10, 20, 40):
        base = run(RTDeepIoT(make_predictor("exp", prior_curve=prior)),
                   conf, correct, n_clients=k)
        nog = run(RTDeepIoTNoGreedy(make_predictor("exp", prior_curve=prior)),
                  conf, correct, n_clients=k)
        full = run(RTDeepIoTFullReplan(make_predictor("exp",
                                                      prior_curve=prior)),
                   conf, correct, n_clients=k)
        rows[f"greedy_K{k}"] = dict(
            with_greedy=base.accuracy, without=nog.accuracy,
            full_replan=full.accuracy,
            full_replan_overhead=full.overhead_frac,
            greedy_overhead=base.overhead_frac)
        print(f"ablation:greedy,K={k},on={base.accuracy:.4f},"
              f"off={nog.accuracy:.4f},full_replan={full.accuracy:.4f},"
              f"ovh_greedy={base.overhead_frac:.4f},"
              f"ovh_full={full.overhead_frac:.4f}")

    for omega in (1, 2):
        res = run(RTDeepIoT(make_predictor("exp", prior_curve=prior)),
                  conf, correct, mandatory_stages=omega)
        rows[f"mandatory_{omega}"] = dict(acc=res.accuracy,
                                          miss=res.miss_rate,
                                          depth=res.mean_depth)
        print(f"ablation:mandatory,omega={omega},acc={res.accuracy:.4f},"
              f"miss={res.miss_rate:.4f},depth={res.mean_depth:.2f}")

    for t, tag in ((1.0, "calibrated"), (0.5, "overconfident"),
                   (2.0, "underconfident")):
        conf_t = np.clip(conf ** (1.0 / t), 0, 1)   # sharpen / flatten
        res = run(RTDeepIoT(make_predictor("exp",
                                           prior_curve=conf_t.mean(0))),
                  conf_t, correct)
        rows[f"calib_{tag}"] = dict(acc=res.accuracy, miss=res.miss_rate)
        print(f"ablation:calibration,{tag},acc={res.accuracy:.4f},"
              f"miss={res.miss_rate:.4f}")

    with open(os.path.join(ART, "ablation_results.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
