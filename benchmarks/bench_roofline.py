"""Roofline table — derives the three terms per (arch × shape × mesh) from
the dry-run artifacts (assignment §ROOFLINE ANALYSIS).

  compute    = probe_FLOPs_per_chip / 197 TFLOP/s          [seconds]
  memory     = probe_bytes_per_chip / 819 GB/s             [seconds]
  collective = probe_coll_bytes_per_chip / 50 GB/s ICI     [seconds]
               (collectives crossing the pod axis use 25 GB/s DCN — the
               multi-pod table notes the dominant-axis assumption)

cost_analysis() is per-device after SPMD partitioning (verified by
calibration), so probe totals are already per-chip.  MODEL_FLOPS uses
6·N·D (train) / 2·N·D (inference) with N_active for MoE; the ratio
MODEL_FLOPS / (HLO_FLOPs × chips) flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

PEAK = 197e12
HBM = 819e9
ICI = 50e9
HBM_CAP = 16e9


def model_flops(arch: str, shape_kind: str, tokens: int) -> float:
    from repro.configs import get_config
    from repro.models import count_params_analytic
    cfg = get_config(arch)
    n = count_params_analytic(cfg, active_only=cfg.moe is not None)
    per_tok = 6 * n if shape_kind == "train" else 2 * n
    return per_tok * tokens


def tokens_of(shape_name: str) -> int:
    from repro.configs import get_shape
    s = get_shape(shape_name)
    return s.global_batch * (1 if s.kind == "decode" else s.seq_len)


def load_records(pattern="*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(path) as f:
            rec = json.load(f)
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        rec["variant"] = parts[4] if len(parts) > 4 else (
            parts[3] if len(parts) > 3 and parts[3] not in
            ("alltoall", "gather") else "baseline")
        recs.append(rec)
    return recs


def roofline_row(rec):
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    probe = rec.get("probe", {}).get("totals")
    if probe is None:
        return None
    t_comp = probe["flops"] / PEAK
    t_mem = probe["bytes"] / HBM
    t_coll = probe["coll"] / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["kind"], tokens_of(rec["shape"]))
    hlo_total = probe["flops"] * chips
    mem = rec["memory"]
    # (t_mem_lb computed below from the same buffer stats)
    hbm_used = (mem["argument_bytes"] + mem["temp_bytes"]
                + mem["output_bytes"]) / HBM_CAP
    # memory-traffic LOWER bound from real buffer sizes (args read once,
    # outputs written once, temps written+read) — brackets the op-level
    # upper bound in t_memory_s
    t_mem_lb = (mem["argument_bytes"] + mem["output_bytes"]
                + 2 * mem["temp_bytes"]) / HBM
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "moe_impl": rec.get("moe_impl", "gather"),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_lb_s": t_mem_lb, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "hbm_frac": hbm_used,
        "fits": hbm_used <= 1.0,
        "swa_variant": rec.get("swa_variant", False),
        "n_micro": rec.get("n_micro"),
    }


def main(pattern="*.json"):
    rows = [r for r in (roofline_row(rec) for rec in load_records(pattern))
            if r is not None]
    hdr = ("arch,shape,mesh,variant,compute_s,memory_s,collective_s,"
           "dominant,useful_ratio,hbm_frac,fits")
    print(hdr)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                         r["variant"])):
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['variant']},"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['hbm_frac']:.2f},"
              f"{int(r['fits'])}")
    out = os.path.join(os.path.dirname(ART), "roofline_table.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
