"""Durable request plane: submit -> crash -> recover -> drain.

1. Stand up a multi-tenant service behind the durable plane
   (``repro.serving.plane``): a write-ahead :class:`Journal` plus a
   :class:`FrontDoor` (token-bucket quotas + deficit-round-robin fair
   queueing), so every accepted request is fsynced to disk *before* its
   handle exists and duplicate submits of one ``request_id`` are no-ops.
2. **Crash** before anything was served: drop the service on the floor
   without draining.  The journal is the only survivor.
3. **Recover**: :func:`repro.serving.plane.recover` rebuilds the exact
   engine from the journal header's ServeSpec and redoes every journaled
   SUBMIT under the virtual clock — delivering each request exactly once
   (pre-crash terminals are never re-delivered) and reproducing the
   admission decisions an uncrashed run would have made bit-for-bit.
4. Read the plane's health from the journal alone (``journal_stats`` —
   the same numbers ``tools/planectl.py`` prints).

Usage:
  PYTHONPATH=src python examples/durable_serving.py            # full demo
  PYTHONPATH=src python examples/durable_serving.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import warnings

# the examples must stay on the ServeSpec front door — escalate the legacy
# shims' warnings so a regression fails the examples-smoke CI job
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import numpy as np

from repro.serving import (FrontDoor, Journal, ServeSpec, Service,
                           journal_stats, recover, verify_recovery)
from repro.serving.engine import Request

STAGE_TIMES = (0.004, 0.007, 0.010)


def synthetic_tables(n=120, L=3, seed=0):
    """Oracle-shaped tables: monotone per-sample confidence curves with
    confidence-consistent correctness (same recipe as bench_scheduling)."""
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def plane_spec() -> ServeSpec:
    return ServeSpec(
        policy="edf", source="frontdoor",
        source_args={"discipline": "drr", "run_queue": 4},
        tenants={"gold": {"weight": 4.0, "rate": 500.0, "burst": 50},
                 "free": {"weight": 1.0, "rate": 200.0, "burst": 20}},
        admission={"mode": "reject", "headroom": 2.0},
        default_slo="std",
        slo_classes={"std": {"rel_deadline": 0.25}},
        batching={"mode": "none", "stage_times": list(STAGE_TIMES)})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (CI examples-smoke job)")
    args = ap.parse_args(argv)
    n = 40 if args.smoke else args.requests

    conf, correct = synthetic_tables()
    spec = plane_spec()
    journal_dir = tempfile.mkdtemp(prefix="plane-journal-")
    print(f"journal: {journal_dir}")

    # -- 1. durable, idempotent, multi-tenant submission ----------------
    journal = Journal(journal_dir, spec=spec, fsync_every=1)
    service = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    door = FrontDoor(service, journal=journal)
    handles = {}
    for i in range(n):
        rid = f"req-{i:04d}"
        handles[rid] = door.submit(
            Request(None, sample=i % conf.shape[0]),
            tenant="gold" if i % 3 == 0 else "free",
            request_id=rid, at=i * 0.01)
    # duplicate submit: same request_id -> the original handle back,
    # no second SUBMIT record
    dup = door.submit(Request(None, sample=0), tenant="gold",
                      request_id="req-0000", at=0.0)
    assert dup is handles["req-0000"], "duplicate must return same handle"
    assert journal.counts["SUBMIT"] == n, "duplicate must not re-journal"
    print(f"submitted {n} requests across 2 tenants "
          f"(+1 duplicate, deduplicated); journal has "
          f"{journal.counts['SUBMIT']} SUBMIT records")

    # -- 2. crash ------------------------------------------------------
    # the virtual-clock service had not run yet: no request was served,
    # no handle resolved.  Simulate the process dying here by abandoning
    # the service and journal objects without draining.
    del service, door, handles, dup
    journal.close()
    print("crashed before serving anything "
          "(journal is the only survivor)")

    # -- 3. recover ----------------------------------------------------
    # rebuild the spec'd engine from the journal header and redo every
    # journaled SUBMIT through the same DRR front door, virtual-clocked
    result = recover(journal_dir, conf_table=conf, correct_table=correct)
    print(f"recovered: {result.replayed} submits redone, "
          f"{result.report['n_redelivered']} newly delivered, "
          f"{result.report['n_pre_delivered']} already delivered pre-crash")
    assert result.delivered_once
    assert result.report["n_redelivered"] == n

    # the redo *is* the uncrashed run: a second recovery redelivers
    # nothing (every request is terminal in the journal now) and its
    # engine decisions reproduce bit-for-bit
    again = recover(journal_dir, conf_table=conf, correct_table=correct)
    rep = verify_recovery(result.metrics.per_request, again)
    assert rep["recovered"] and again.report["n_redelivered"] == 0, rep
    print(f"re-recovery: bitwise={rep['bitwise']} "
          f"delivered_once={rep['delivered_once']} redelivered=0")

    # -- 4. health from the journal alone ------------------------------
    stats = journal_stats(journal_dir)
    print(f"journal_stats: queue_depth={stats['queue_depth']} "
          f"records={stats['records']} segments={stats['segments']}")
    for tenant, c in sorted(stats["per_tenant"].items()):
        print(f"  {tenant}: submitted={c['submitted']} "
              f"retired={c['retired']} rejected={c['rejected']} "
              f"pending={c['pending']}")
    assert stats["queue_depth"] == 0, "recovery must drain the queue"

    met = result.metrics
    print(f"\nper-tenant outcome (recovered run): ")
    for tenant, row in sorted(met.per_tenant.items()):
        print(f"  {tenant}: n={row['n']} served={row['served']} "
              f"miss_rate={row['miss_rate']:.3f} "
              f"mean_depth={row['mean_depth']:.2f}")
    shutil.rmtree(journal_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
