"""Adaptive control end to end: learn the workload, then serve ahead of it.

1. **Record & identify** — run "yesterday's" flash-crowd scenario behind
   reactive admission control, then fit every arrival model
   (``repro.serving.adaptive.fit_report``) to the recorded offsets and
   let the BIC-penalized score name the workload.
2. **Predict** — re-serve "today" (same process, fresh seed) twice:
   reactive depth-cap admission vs the same controller armed with
   yesterday's fitted process (``admission={"forecast": ...}``).  The
   forecast sheds optional stages *before* the spike lands — strictly
   fewer admitted deadline misses at equal-or-better admitted accuracy.
3. **Learn the curves** — ``rtdeepiot-adaptive`` plans against an
   ``OnlineCurveEstimator`` fed by observed stage exits; after one
   warm-up run it lands within 2% of the oracle-table policy.
4. **Drive it live** — a wall-clock ``TrafficDriver`` paces requests
   sampled from the *fitted* process into ``Service.submit()``.

Usage:
  PYTHONPATH=src python examples/adaptive_serving.py           # full demo
  PYTHONPATH=src python examples/adaptive_serving.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import warnings

# the examples must stay on the ServeSpec front door — escalate the legacy
# shims' warnings so a regression fails the examples-smoke CI job
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import numpy as np

from repro.serving import ServeSpec, Service, scenario_spec
from repro.serving.adaptive import (OnlineCurveEstimator, TrafficDriver,
                                    fit_report)

STAGE_TIMES = (0.004, 0.007, 0.010)
N_REQUESTS = 600        # the fit needs the whole spike: a truncated
                        # flash-crowd trace reads as MMPP instead


def synthetic_tables(n=600, L=3, seed=0):
    """Oracle-shaped tables: monotone per-sample confidence curves with
    confidence-consistent correctness (same recipe as bench_scheduling)."""
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def flash_crowd(conf, correct, *, admission, seed, trace=None):
    spec = scenario_spec("flash-crowd", policy="rtdeepiot",
                         admission=admission, stage_times=STAGE_TIMES,
                         n_requests=N_REQUESTS, seed=seed,
                         trace=trace or {})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    return svc, svc.run()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the live-driver leg (CI job); the "
                         "virtual-clock legs already run full size")
    args = ap.parse_args(argv)
    conf, correct = synthetic_tables()

    # -- 1. record yesterday, fit the arrival process -------------------
    _, rec = flash_crowd(conf, correct, admission={"mode": "depth_cap"},
                         seed=11)
    report = fit_report([r["offset"] for r in rec.per_request])
    print(f"yesterday: {rec.n_requests} arrivals recorded, fits scored:")
    for kind in sorted(report["scores"], key=report["scores"].get,
                       reverse=True):
        tag = " <- best" if kind == report["best"] else ""
        print(f"  {kind:12s} {report['scores'][kind]:10.1f}{tag}")
    assert report["best"] == "flash-crowd"
    process = report["fits"][report["best"]]
    print(f"  fitted: base={process['base_rate']:.0f}/s "
          f"spike={process['spike_rate']:.0f}/s "
          f"at t={process['spike_at']:.2f}s "
          f"for {process['spike_len']:.2f}s")

    # -- 2. today: reactive vs forecast-armed admission -----------------
    arms = {}
    for label, adm in (
            ("reactive", {"mode": "depth_cap"}),
            ("predictive", {"mode": "depth_cap",
                            "forecast": {"process": process,
                                         "horizon": 0.1}})):
        svc, res = flash_crowd(conf, correct, admission=adm, seed=12,
                               trace={"enabled": True})
        n_admitted = res.n_requests - res.rejected
        misses = round(res.admitted_miss_rate * n_admitted)
        arms[label] = (misses, res.admitted_accuracy)
        why = sum(1 for r in svc.obs.audit_log
                  if r["rule"] == "forecast-capped")
        print(f"today/{label:10s} admitted_misses={misses:3d} "
              f"admitted_acc={res.admitted_accuracy:.3f} "
              f"capped={res.capped}"
              + (f" (forecast fired {why}x)" if why else ""))
    assert arms["predictive"][0] < arms["reactive"][0]
    assert arms["predictive"][1] >= arms["reactive"][1] - 1e-9

    # -- 3. learned curves vs the oracle table --------------------------
    def steady(policy, seed, **res):
        pargs = {"predictor": "oracle"} if policy == "rtdeepiot" else {}
        spec = scenario_spec("steady", policy=policy, policy_args=pargs,
                             stage_times=STAGE_TIMES,
                             n_requests=N_REQUESTS, seed=seed)
        return Service.from_spec(spec, conf_table=conf,
                                 correct_table=correct, **res).run()

    oracle = steady("rtdeepiot", 22)
    est = OnlineCurveEstimator(num_stages=conf.shape[1],
                               prior=[0.5, 0.7, 0.85])
    steady("rtdeepiot-adaptive", 21, curve_estimator=est)        # warm-up
    warm = steady("rtdeepiot-adaptive", 22, curve_estimator=est)
    curve = ", ".join(f"{c:.3f}" for c in est.curve())
    print(f"curves: oracle_acc={oracle.accuracy:.3f} "
          f"adaptive_acc={warm.accuracy:.3f} "
          f"({est.n_observed} exits observed, learned curve [{curve}])")
    assert warm.accuracy >= oracle.accuracy - 0.02

    # -- 4. live wall-clock driver off the fitted process ---------------
    n_live = 24 if args.smoke else 120
    live = ServeSpec(policy="edf", executor="oracle", clock="wall",
                     source="live",
                     batching={"mode": "none",
                               "stage_times": [0.001, 0.001, 0.001]},
                     slo_classes={"gold": {"rel_deadline": 2.0}},
                     default_slo="gold")
    with Service.from_spec(live, conf_table=conf,
                           correct_table=correct) as svc:
        drv = TrafficDriver(svc, arrival=dict(process),
                            n_samples=conf.shape[0], n_requests=n_live,
                            seed=7, speed=8.0).start()
        assert drv.join(timeout=60.0)
        met = svc.drain()
    print(f"live: drove {drv.submitted} requests sampled from the fitted "
          f"process at 8x (acc={met.accuracy:.3f}, "
          f"miss={met.miss_rate:.3f})")
    assert met.n_requests == n_live
    print("OK")


if __name__ == "__main__":
    main()
