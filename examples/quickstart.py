"""Quickstart: the imprecise-computation scheduling stack in 60 seconds.

1. Build a tiny anytime model (3 stages + exit heads + confidences).
2. Cast inference requests as imprecise-computation Tasks.
3. Plan depths with the FPTAS DP (Algorithm 1), then compare schedulers
   through the one serving front door: a declarative ``ServeSpec`` naming
   every component by registry key (policy / executor / clock / source),
   run by ``repro.serving.Service``.  Swapping the ``executor`` key —
   ``oracle`` here, ``device-batched`` / ``device-sharded`` in
   examples/serve_anytime.py — is the only change between simulation and
   real (sharded) serving; see docs/architecture.md and
   docs/serving-api.md.

Usage: PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import warnings

# the examples are the ServeSpec front door's showcase — escalate the
# legacy shims' warnings so a regression off it fails the examples-smoke
# CI job instead of slipping through silently
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import jax
import numpy as np

from repro.configs import get_config
from repro.core import DepthPlanner, Task, Workload, make_predictor
from repro.models import forward, init_params
from repro.serving import ServeSpec, Service

# --- 1. an anytime model: every stage yields (prediction, confidence) ------
cfg = get_config("anytime-classifier")
params = init_params(cfg, jax.random.PRNGKey(0))
x = {"features": jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))}
out = forward(cfg, params, x, mode="train")
print("per-stage confidences for 4 inputs:")
for s, c in enumerate(out.confidences):
    print(f"  stage {s}: {np.round(np.asarray(c), 3)}")

# --- 2. requests as imprecise computations ---------------------------------
planner = DepthPlanner(delta=0.1)
pred = make_predictor("exp", prior_curve=[0.5, 0.75, 0.875])
tasks = [
    Task(arrival=0.0, deadline=0.08, stage_times=(0.02,) * 3, sample=0),
    Task(arrival=0.0, deadline=0.10, stage_times=(0.02,) * 3, sample=1),
    Task(arrival=0.0, deadline=0.16, stage_times=(0.02,) * 3, sample=2),
]
plan = planner.plan(tasks, now=0.0, predictor=pred)
print("\nFPTAS depth assignment (Algorithm 1):",
      {t.tid: plan[t.tid] for t in tasks})

# --- 3. schedulers head-to-head under overload -----------------------------
# one front door for every engine: name the components in a ServeSpec
# (registry keys), hand the runtime objects to Service as resources
rng = np.random.default_rng(0)
conf = np.clip(rng.uniform(0.35, 0.75, (300, 1))
               + rng.uniform(0.05, 0.25, (300, 3)).cumsum(1), 0, 1)
correct = rng.uniform(size=(300, 3)) < conf
wl = Workload(n_clients=16, d_lo=0.02, d_hi=0.18, n_requests=400)
print("\npolicy       accuracy  miss_rate  mean_depth")
for policy in ("rtdeepiot", "edf", "lcf", "rr"):
    spec = ServeSpec(policy=policy, executor="oracle", clock="virtual",
                     source="closed-loop",
                     batching={"mode": "none", "stage_times": [0.02] * 3})
    r = Service.from_spec(spec, workload=wl, conf_table=conf,
                          correct_table=correct).run()
    print(f"{policy:12s} {r.accuracy:8.3f} {r.miss_rate:9.3f} "
          f"{r.mean_depth:10.2f}")
