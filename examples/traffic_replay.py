"""Traffic generation, overload control, and trace replay in one sitting.

1. Drive the serving stack with an open-loop **flash-crowd** scenario
   (repro.serving.traffic): Poisson base load with a 5x arrival spike the
   scheduler cannot have planned for, streamed windowed metrics showing
   the transient (queue depth, windowed miss rate, utilization).
2. Compare uncontrolled EDF against RTDeepIoT behind admission control —
   the imprecise-computation answer to overload (shed optional stages,
   reject what cannot meet its mandatory deadline).
3. **Record** the run into a JSONL trace and **replay** it through
   ``register_source("replay")``, verifying the replay reproduces the
   original arrival order and admission decisions bit-for-bit under the
   virtual clock — the regression-grade load test the ROADMAP asked for.

Usage:
  PYTHONPATH=src python examples/traffic_replay.py            # full demo
  PYTHONPATH=src python examples/traffic_replay.py --smoke    # CI-sized
  PYTHONPATH=src python examples/traffic_replay.py \
      --trace examples/data/mini_trace.jsonl                  # replay a
      # checked-in trace against its recorded ServeSpec (regression mode)

Traces pair with the synthetic oracle tables built here (seed 0), so a
checked-in trace replays identically on any host.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import warnings

# the examples must stay on the ServeSpec front door — escalate the legacy
# shims' warnings so a regression fails the examples-smoke CI job
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import numpy as np

from repro.serving import (ServeSpec, Service, load_trace, record_trace,
                           scenario_spec, verify_replay)

STAGE_TIMES = (0.004, 0.007, 0.010)
ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def synthetic_tables(n=120, L=3, seed=0):
    """Oracle-shaped tables: monotone per-sample confidence curves with
    confidence-consistent correctness (same recipe as bench_scheduling)."""
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def replay_checked_in(path: str) -> None:
    """Regression mode: replay a recorded trace against its stored spec
    and check the recorded outcomes reproduce."""
    header, events = load_trace(path)
    spec = ServeSpec.from_dict(header["spec"])
    spec = dataclasses.replace(spec, source="replay", source_args={})
    conf, correct = synthetic_tables()
    res = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                            trace=events).run()
    recorded = [(ev.outcome["rejected"], ev.outcome["depth"],
                 ev.outcome["missed"]) for ev in events]
    replayed = [(r["rejected"], r["depth"], r["missed"])
                for r in sorted(res.per_request, key=lambda r: r["tid"])]
    assert recorded == replayed, (
        "replay diverged from the recorded outcomes — scheduling behavior "
        "changed since this trace was recorded")
    print(f"replayed {len(events)} recorded requests from {path}: "
          f"outcomes reproduce bit-for-bit "
          f"(miss={res.miss_rate:.3f}, rejected={res.rejected})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=600)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scenario, no artifact writes (CI job)")
    ap.add_argument("--trace", default=None,
                    help="replay this JSONL trace (regression mode)")
    args = ap.parse_args(argv)
    if args.trace:
        replay_checked_in(args.trace)
        if not args.smoke:
            return
    n_requests = 80 if args.smoke else args.requests
    conf, correct = synthetic_tables()

    # -- 1. flash crowd with streamed windowed metrics ------------------
    print("flash-crowd scenario, RTDeepIoT + admission control "
          "(windowed metrics):")
    print(f"{'t':>6} {'n':>4} {'miss%':>6} {'queue':>6} {'util%':>6} "
          f"{'shed':>5} {'rej':>4}")

    def show(s):
        print(f"{s.t:6.2f} {s.n:4d} {100 * s.miss_rate:6.1f} "
              f"{s.queue_depth:6d} {100 * s.utilization:6.1f} "
              f"{s.capped:5d} {s.rejected:4d}")

    spec = scenario_spec("flash-crowd", policy="rtdeepiot",
                         admission={"mode": "depth_cap"},
                         stage_times=STAGE_TIMES, n_requests=n_requests,
                         metrics_interval=0.5)
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                            on_metrics=show)
    controlled = svc.run()

    # -- 2. the same crowd, uncontrolled EDF ----------------------------
    edf = Service.from_spec(
        scenario_spec("flash-crowd", policy="edf", stage_times=STAGE_TIMES,
                      n_requests=n_requests),
        conf_table=conf, correct_table=correct).run()
    print(f"\nuncontrolled edf:        miss={edf.miss_rate:.3f} "
          f"acc={edf.accuracy:.3f}")
    print(f"rtdeepiot + shedding:    miss={controlled.miss_rate:.3f} "
          f"acc={controlled.accuracy:.3f} capped={controlled.capped}")

    # -- 3. record -> replay, bit-for-bit -------------------------------
    if args.smoke:
        import tempfile
        trace_path = os.path.join(tempfile.mkdtemp(), "flash_crowd.jsonl")
    else:
        os.makedirs(os.path.join(ART, "traces"), exist_ok=True)
        trace_path = os.path.join(ART, "traces", "flash_crowd.jsonl")
    record_trace(controlled, trace_path, source="traffic", spec=spec)
    _, events = load_trace(trace_path)
    replayed = Service.from_spec(
        dataclasses.replace(spec, source="replay", source_args={},
                            metrics_interval=0.0),
        conf_table=conf, correct_table=correct, trace=events).run()
    v = verify_replay(controlled.per_request, replayed.per_request)
    print(f"\nrecorded {len(events)} requests -> {trace_path}")
    print(f"replay: arrival_order={v['arrival_order']} "
          f"admission_decisions={v['admission_decisions']} "
          f"bitwise={v['bitwise']}")
    assert v["bitwise"], "replay must reproduce the run bit-for-bit"
    print("OK")


if __name__ == "__main__":
    main()
