"""Model zoo: one device, two anytime models, cross-model preemption.

1. Declare a two-model zoo in one ``ServeSpec``: an expensive
   high-weight "llm" head and a cheap "vision" model, each with its own
   WCET table and oracle confidence tables (``repro.serving.zoo``).
2. Drive the registered ``model-mix`` traffic scenario (2x the blended
   capacity) through ``policy="rtdeepiot-zoo"`` twice: ``scope="global"``
   (one FPTAS across both models — sheds the globally least-valuable
   optional stages, whichever model owns them) vs ``scope="siloed"``
   (each model planned as if it owned the device — the union plan
   overcommits and admitted work misses).
3. Read the per-model breakdown from ``ServiceMetrics.per_model`` and
   score both runs on weighted admitted accuracy (a missed deadline
   earns zero, the paper's utility-accrual semantics).
4. Inspect the blended worst-case time model vs the per-model tables,
   and show the spec-time validation a malformed zoo fails with.

Numpy-only (``executor="zoo-oracle"``) — no jax, no trained artifact.

Usage:
  PYTHONPATH=src python examples/model_zoo.py            # full demo
  PYTHONPATH=src python examples/model_zoo.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses
import warnings

# the examples must stay on the ServeSpec front door — escalate the legacy
# shims' warnings so a regression fails the examples-smoke CI job
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import numpy as np

from repro.serving import ModelZoo, Service
from repro.serving.traffic import scenario_spec
from repro.serving.zoo import validate_models

#: the zoo: per-model stage WCETs (seconds) + scheduling contract.  llm
#: is ~2x the stage cost and 2x the utility weight of vision — the
#: trade the cross-model planner arbitrates under overload.
ZOO = {
    "llm": {"stage_times": [0.006, 0.010, 0.014], "weight": 2.0},
    "vision": {"stage_times": [0.003, 0.005, 0.007]},
}

#: capacity anchor for the scenario's 2.0x load factor: the model-mix
#: weighted mean per-stage times (0.4 llm / 0.6 vision — see
#: repro.serving.traffic.scenarios.MODEL_MIX)
MIX_STAGE_TIMES = tuple(
    0.4 * a + 0.6 * b for a, b in zip(ZOO["llm"]["stage_times"],
                                      ZOO["vision"]["stage_times"]))


def zoo_tables(n=240, L=3, seed=0):
    """Per-model oracle tables: monotone per-sample confidence curves
    with confidence-consistent correctness, one independent pair per
    model (same recipe as bench_scheduling's synthetic tables)."""
    out = {}
    for i, model in enumerate(sorted(ZOO)):
        rng = np.random.default_rng(seed + i)
        conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
        out[model] = {"conf": conf,
                      "correct": rng.uniform(size=(n, L)) < conf}
    return out


def weighted_admitted_accuracy(res, tables):
    """Weighted admitted accuracy, utility-accrual semantics: weight =
    SLO utility weight x model weight, a missed deadline earns zero."""
    num = den = 0.0
    for r in res.per_request:
        if r["rejected"]:
            continue
        w = float(r["weight"])
        den += w
        ok = (not r["missed"]) and r["depth"] >= 1 and bool(
            tables[r["model"]]["correct"][r["sample"], r["depth"] - 1])
        num += w * float(ok)
    return num / den if den else 0.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=600)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (CI examples-smoke job)")
    args = ap.parse_args(argv)
    n = 120 if args.smoke else args.requests

    tables = zoo_tables()

    # -- 1. + 2. the same zoo spec, global vs siloed planning -----------
    results = {}
    for scope in ("global", "siloed"):
        spec = dataclasses.replace(
            scenario_spec("model-mix", policy="rtdeepiot-zoo",
                          policy_args={"predictor": "exp", "scope": scope},
                          admission={"mode": "reject"},
                          stage_times=MIX_STAGE_TIMES, n_requests=n,
                          seed=0, models=ZOO),
            executor="zoo-oracle")
        results[scope] = Service.from_spec(
            spec, zoo_tables=tables,
            n_samples=tables["llm"]["conf"].shape[0]).run()

    # -- 3. per-model breakdown + the cross-model shedding payoff -------
    for scope, res in results.items():
        wacc = weighted_admitted_accuracy(res, tables)
        print(f"scope={scope}: admitted_miss={res.admitted_miss_rate:.4f} "
              f"weighted_admitted_acc={wacc:.4f}")
        for model, row in sorted(res.per_model.items()):
            print(f"  {model}: n={row['n']} served={row['served']} "
                  f"rejected={row['rejected']} miss={row['miss_rate']:.4f} "
                  f"mean_depth={row['mean_depth']:.2f}")
    g = weighted_admitted_accuracy(results["global"], tables)
    s = weighted_admitted_accuracy(results["siloed"], tables)
    assert set(results["global"].per_model) == set(ZOO)
    assert g >= s - 1e-9, (g, s)
    print(f"cross-model shedding holds its ground: global {g:.4f} >= "
          f"siloed {s:.4f} (siloed admitted-miss "
          f"{results['siloed'].admitted_miss_rate:.4f} vs global "
          f"{results['global'].admitted_miss_rate:.4f})")

    # -- 4a. blended worst case vs per-model pricing --------------------
    zoo = ModelZoo.from_spec(ZOO)
    tm = zoo.time_model
    print("stage-0 singleton WCET: "
          + "  ".join(f"{m}={tm.for_model(m).wcet(0, 1):.3f}s"
                      for m in zoo.names())
          + f"  blended(worst)={tm.wcet(0, 1):.3f}s")
    assert tm.wcet(0, 1) == max(tm.for_model(m).wcet(0, 1)
                                for m in zoo.names())

    # -- 4b. malformed zoos fail at spec time, not first dispatch -------
    try:
        validate_models({"a": {"stage_times": [0.01], "buckets": [1, 2]},
                         "b": {"stage_times": [0.01], "buckets": [1, 4]}})
    except ValueError as e:
        print(f"spec-time validation: {e}")
    else:
        raise AssertionError("mismatched buckets must be rejected")
    print("OK")


if __name__ == "__main__":
    main()
