"""Trace a request through the Fig. 2 serving loop (repro.serving.obs).

Runs the 2x-overload scenario with full tracing on, then answers the
two operator questions the observability layer exists for:

* *What happened to this request?* — its typed spans (``queued`` ->
  ``admitted`` -> ``batched``/``dispatch`` -> ``device-window`` ->
  ``stage-exit`` -> ``retire``/``expire``) with queue-wait / host /
  device time splits.
* *Why was this request degraded?* — the scheduler audit log names the
  admission rule that fired (``overload``, ``mandatory-infeasible``,
  ...) and the numbers behind it (slack, backlog, amortized WCET).

The run also writes the JSONL export and the Chrome ``trace_event``
JSON (open it at https://ui.perfetto.dev), and replays the same
questions through the offline CLI:

    PYTHONPATH=src python tools/planectl.py trace <export> <tid>
    PYTHONPATH=src python tools/planectl.py why   <export> <tid>
    PYTHONPATH=src python tools/planectl.py top   <export> --by queue_wait

Usage: PYTHONPATH=src python examples/trace_a_request.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import warnings

# the examples are the ServeSpec front door's showcase — escalate the
# legacy shims' warnings so a regression off it fails the examples-smoke
# CI job instead of slipping through silently
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import numpy as np

from repro.serving import Service, validate_chrome_trace
from repro.serving.traffic import scenario_spec

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STAGE_TIMES = [0.004, 0.007, 0.010]


def planectl(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "planectl.py"),
         *argv], capture_output=True, text=True, env=env)
    return proc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small run + assertions (CI job)")
    args = ap.parse_args(argv)
    n_requests = 150 if args.smoke else 300

    rng = np.random.default_rng(0)
    conf = np.sort(rng.uniform(0.3, 1.0, (600, 3)), axis=1)
    correct = rng.uniform(size=(600, 3)) < conf

    outdir = tempfile.mkdtemp(prefix="obs_demo_")
    export = os.path.join(outdir, "obs.jsonl")
    chrome = os.path.join(outdir, "trace.json")

    # 2x overload forces the admission controller to reject work, so the
    # audit log has decisions to explain; export paths are written when
    # the run finishes
    spec = scenario_spec("2x-overload", stage_times=STAGE_TIMES,
                         n_requests=n_requests,
                         admission={"mode": "reject", "headroom": 3.0},
                         trace={"enabled": True, "export": export,
                                "chrome": chrome})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    res = svc.run()
    obs = svc.obs
    print(f"2x-overload: {res.n_requests} requests, "
          f"miss_rate={res.miss_rate:.3f}, "
          f"{len(obs.audit_log)} audit rows, "
          f"{len(obs.windows)} device windows\n")

    served = next(tr for tr in obs.traces.values()
                  if not tr.rejected and not tr.missed)
    rejected = next(tr for tr in obs.traces.values() if tr.rejected)

    # -- what happened to a served request? -------------------------------
    print(f"trace of served request tid={served.tid} "
          f"(depth={served.depth}, latency={served.latency:.4f}s, "
          f"queue_wait={served.queue_wait:.4f}s, "
          f"device_time={served.device_time:.4f}s):")
    for s in served.spans:
        extra = f"  {json.dumps(s.attrs)}" if s.attrs else ""
        print(f"  {s.t0:8.4f} .. {s.t1:8.4f}  {s.name:<14}{extra}")

    # -- why was this one rejected? ---------------------------------------
    print(f"\nwhy was tid={rejected.tid} rejected? "
          f"decision={rejected.decision}")
    for row in obs.audit_for(rejected.tid):
        print(f"  t={row['t']:.4f}  rule={row['rule']}  "
              f"{json.dumps(row['detail'], sort_keys=True)}")

    # -- exports ----------------------------------------------------------
    doc = json.load(open(chrome))
    problems = validate_chrome_trace(doc)
    print(f"\nwrote {export}")
    print(f"wrote {chrome} ({len(doc['traceEvents'])} trace events, "
          f"{'valid' if not problems else problems}) — open in "
          f"https://ui.perfetto.dev")

    # -- same questions, offline, via planectl ----------------------------
    print("\n$ planectl why", export, rejected.tid)
    why = planectl("why", export, str(rejected.tid))
    print(why.stdout, end="")
    print("$ planectl top", export, "-n", "3")
    top = planectl("top", export, "-n", "3")
    print(top.stdout, end="")

    if args.smoke:
        assert len(obs.traces) == res.n_requests
        assert served.span_names()[0] == "queued"
        assert served.span_names()[-1] == "retire"
        audited = {row.get("tid") for row in obs.audit_log}
        assert rejected.tid in audited
        assert not problems
        tr_cli = planectl("trace", export, str(served.tid))
        assert tr_cli.returncode == 0 and "retire" in tr_cli.stdout
        assert why.returncode == 0 and "rule=" in why.stdout
        assert top.returncode == 0 and "total" in top.stdout
        print("\nSMOKE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
