"""End-to-end anytime serving driver (paper Fig. 2) — REAL model, wall clock.

Loads the trained anytime classifier, profiles per-stage WCETs (99th
percentile, paper §IV protocol) plus the host dispatch overhead, then
serves requests from K concurrent clients under uniform-random relative
deadlines with the RTDeepIoT scheduler vs. EDF, reporting accuracy / miss
rate / latency from actual jitted stage executions on this host.

Every engine is built through the public serving API: a declarative
``ServeSpec`` names the policy / executor / clock / source by registry key
(``device-single`` = unbatched per-stage dispatch, ``device-batched`` =
continuous micro-batching, ``pipeline_depth=2`` = pipelined async
dispatch, ``device-sharded`` = the batched engine across a ``(dp, tp)``
mesh with a 1x1 fallback on single-device hosts, ``device-kernel`` with
``--kernels`` = Pallas stage bodies with the fused exit-confidence
epilogue at ``pipeline_depth=3``), and
``repro.serving.Service`` owns the engine lifecycle; the model params /
stage fns / profiled time model ride along as resources.

Also writes artifacts/stage_times.npz so the simulation benchmarks use the
profiled WCETs.

Usage: PYTHONPATH=src python examples/serve_anytime.py [--requests 120]
       PYTHONPATH=src python examples/serve_anytime.py --smoke   # CI job
"""
from __future__ import annotations

import argparse
import os
import warnings

# the examples must stay on the ServeSpec front door — escalate the legacy
# shims' warnings so a regression fails the examples-smoke CI job
warnings.filterwarnings("error", message=r".*ServeSpec",
                        category=DeprecationWarning)

import jax
import numpy as np

import repro.launch.serve  # noqa: F401 — registers device-sharded

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (BatchedStageFns, ServeSpec, Service,
                           closed_loop_stream, make_stage_fns,
                           profile_batched_stages, profile_stages)
from repro.training import DifficultyDataset, checkpoint

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--d-lo", type=float, default=None,
                    help="min relative deadline (default: 1.2x one stage)")
    ap.add_argument("--d-hi", type=float, default=None,
                    help="max relative deadline (default: 6x one stage)")
    ap.add_argument("--buckets", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="pre-compiled batch-size buckets for the batched "
                         "engine")
    ap.add_argument("--dp", type=int, default=2,
                    help="data-parallel ways for the device-sharded engine "
                         "(falls back to a 1x1 mesh when the host has "
                         "fewer devices)")
    ap.add_argument("--kernels", action="store_true",
                    help="also run the kernel-backed fast path (executor "
                         "'device-kernel': Pallas stage bodies, fused "
                         "exit-confidence, pipeline_depth=3)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, few profiling runs, no artifact "
                         "writes (CI job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.clients, args.buckets = 8, 2, [1, 2]
    n_runs = 5 if args.smoke else 60

    cfg = get_config("anytime-classifier")
    ckpt_path = os.path.join(ART, "anytime_classifier.ckpt")
    if os.path.exists(ckpt_path):
        params, meta = checkpoint.load(ckpt_path,
                                       init_params(cfg, jax.random.PRNGKey(0)))
        print(f"loaded checkpoint ({meta.get('steps')} steps)")
    else:
        print("no checkpoint found — using random params "
              "(run examples/train_multiexit.py first for meaningful accuracy)")
        params = init_params(cfg, jax.random.PRNGKey(0))

    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(80 if args.smoke else 600, seed=999)

    # --- profile stages (paper §IV: WCET = upper CI over profiling runs) ---
    stage_fns = make_stage_fns(cfg)
    sample = jax.tree.map(lambda x: x[:1], test["inputs"])
    wcet, times, host_overhead = profile_stages(cfg, params, stage_fns,
                                                sample, n_runs=n_runs)
    print("stage WCETs (s):", np.round(wcet, 5),
          " means:", np.round(times.mean(1), 5),
          f" host_overhead={host_overhead*1e6:.1f}us")
    if not args.smoke:
        np.savez(os.path.join(ART, "stage_times.npz"), wcet=wcet,
                 samples=times, host_overhead=host_overhead)

    # --- profile *batched* stage WCETs for the micro-batching engine ------
    buckets = tuple(sorted(args.buckets))
    bfns = BatchedStageFns(cfg, buckets)
    time_model, bmat = profile_batched_stages(cfg, params, bfns, sample,
                                              n_runs=max(5, n_runs // 2))
    print("batched stage WCETs (s) [stage x bucket]:\n", np.round(bmat, 5))

    d_lo = args.d_lo or float(4.0 * wcet.max())
    d_hi = args.d_hi or float(14.0 * wcet.max())
    print(f"deadlines ~ U[{d_lo:.4f}, {d_hi:.4f}] s, {args.clients} clients")

    def report(name, svc):
        responses = svc.responses
        labels = np.asarray(test["labels"])
        correct = [r.prediction == labels[r.sample]
                   for r in responses if not r.missed]
        acc = float(np.sum(correct)) / max(1, len(responses))
        miss = float(np.mean([r.missed for r in responses]))
        depth = float(np.mean([r.depth for r in responses if not r.missed]
                              or [0]))
        lat = float(np.mean([r.latency for r in responses]))
        print(f"{name:18s} n={len(responses)} acc={acc:.3f} miss={miss:.3f} "
              f"mean_depth={depth:.2f} mean_latency={lat*1e3:.1f}ms "
              f"sched_overhead={svc.policy.sched_time:.3f}s")
        return dict(acc=acc, miss=miss, depth=depth)

    def stream():
        return closed_loop_stream(test["inputs"], test["labels"],
                                  n_clients=args.clients, d_lo=d_lo,
                                  d_hi=d_hi, n_requests=args.requests,
                                  seed=1)

    POLICIES = [("rtdeepiot", {"predictor": "exp",
                               "prior_curve": [.5, .7, .85]}),
                ("edf", {})]

    def spec_for(policy, policy_args, *, batched, pipelined=False,
                 sharded=False, kernel=False):
        if batched:
            batching = {}            # priced by the profiled time_model
        else:
            batching = {"mode": "none",
                        "stage_times": [float(x) for x in wcet]}
        executor = "device-kernel" if kernel else \
            ("device-sharded" if sharded else
             ("device-batched" if batched else "device-single"))
        return ServeSpec(
            policy=policy, policy_args=policy_args,
            executor=executor,
            executor_args={"dp": args.dp, "tp": 1} if sharded else {},
            clock="wall", source="stream", batching=batching,
            host_overhead=host_overhead,
            pipeline_depth=3 if kernel else (2 if pipelined else 1))

    results = {}
    for name, pargs in POLICIES:
        svc = Service.from_spec(spec_for(name, pargs, batched=False),
                                cfg=cfg, params=params, stage_fns=stage_fns)
        svc.run(stream())
        results[name] = report(name, svc)
    for name, pargs in POLICIES:
        svc = Service.from_spec(spec_for(name, pargs, batched=True),
                                cfg=cfg, params=params, stage_fns=bfns,
                                time_model=time_model)
        svc.run(stream())
        results[f"batched-{name}"] = report(f"batched-{name}", svc)
    # pipelined async dispatch (pipeline_depth=2): the host pre-selects the
    # next batch while the device executes the current one
    for name, pargs in POLICIES:
        svc = Service.from_spec(spec_for(name, pargs, batched=True,
                                         pipelined=True),
                                cfg=cfg, params=params, stage_fns=bfns,
                                time_model=time_model)
        svc.run(stream())
        results[f"pipelined-{name}"] = report(f"pipelined-{name}", svc)
    # sharded across a (dp, tp) mesh (executor "device-sharded", registered
    # by repro.launch.serve from outside the serving package); on a
    # single-device host the mesh falls back to 1x1, so this leg exercises
    # the full sharded path — mesh build, sharding constraints,
    # dp-divisible buckets, device-resident state cache — everywhere
    name, pargs = POLICIES[0]
    svc = Service.from_spec(spec_for(name, pargs, batched=True, sharded=True),
                            cfg=cfg, params=params, time_model=time_model)
    svc.run(stream())
    ex = svc.executor
    results[f"sharded-{name}"] = report(
        f"sharded{ex.dp}x{ex.tp}-{name}", svc)
    assert ex.cache_stats()["live"] == 0      # state evicted on retire
    # kernel-backed fast path (executor "device-kernel", also registered
    # by repro.launch.serve): jitted Pallas stage bodies with the fused
    # exit-confidence epilogue, dispatching pipeline_depth-1 = 2 stacked
    # device windows
    if args.kernels:
        name, pargs = POLICIES[0]
        svc = Service.from_spec(spec_for(name, pargs, batched=True,
                                         kernel=True),
                                cfg=cfg, params=params,
                                time_model=time_model)
        svc.run(stream())
        results[f"kernel-{name}"] = report(f"kernel-{name}", svc)
        kx = svc.executor
        kt = kx.device_time_stats()
        print(f"kernel telemetry: host={kt['host_time']:.3f}s "
              f"device={kt['device_time']:.3f}s "
              f"windows={kx.max_inflight} "
              f"cache={kx.cache_stats()}")
        assert kx.max_inflight == 2
        assert kx.cache_stats()["live"] == 0
    if args.smoke:
        assert all(len(r) == 3 for r in results.values())
        print(f"SMOKE OK: {len(results)} engine configs served "
              f"{args.requests} requests each")
    return results


if __name__ == "__main__":
    main()
