"""Train the anytime (multi-exit) classifier — paper §III-A analog.

Trains the 3-stage anytime-classifier with deep supervision on the synthetic
difficulty-varying dataset, temperature-calibrates each exit's confidence on
a validation split, evaluates per-stage accuracy, and writes:

  artifacts/anytime_classifier.ckpt     params checkpoint
  artifacts/oracle_tables.npz           per-test-sample (confidence, correct)
                                        per stage + stage accuracies

Usage: PYTHONPATH=src python examples/train_multiexit.py [--steps 400]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import (AdamW, DifficultyDataset, checkpoint,
                            eval_exit_metrics, make_train_step,
                            warmup_cosine)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def calibrate_temperature(cfg, params, val, stage: int, grid=None):
    """Post-hoc temperature scaling per exit (reliability of max-prob
    confidence — the paper's utility metric must be calibrated to be a
    probability of correctness)."""
    from repro.models import forward
    grid = grid or np.geomspace(0.25, 4.0, 17)
    out = jax.jit(lambda p, x: forward(cfg, p, x, mode="train").logits[stage]
                  )(params, val["inputs"])
    logits = np.asarray(out, np.float64)
    labels = np.asarray(val["labels"])
    best_t, best_nll = 1.0, np.inf
    for t in grid:
        lg = logits / t
        lse = np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1)) \
            + lg.max(-1)
        nll = float(np.mean(lse - lg[np.arange(len(labels)), labels]))
        if nll < best_nll:
            best_nll, best_t = nll, t
    return best_t


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--n-test", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="continue from artifacts/anytime_classifier.ckpt")
    args = ap.parse_args(argv)

    cfg = get_config("anytime-classifier")
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.resume:
        ckpt = os.path.join(ART, "anytime_classifier.ckpt")
        if os.path.exists(ckpt):
            params, meta = checkpoint.load(ckpt, params)
            print(f"resumed from {ckpt} ({meta.get('steps')} steps)")

    opt = AdamW(learning_rate=warmup_cosine(3e-3, 40, args.steps),
                weight_decay=0.01)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, exit_weights=(0.2, 0.3, 0.5)))

    print(f"training {cfg.name}: {args.steps} steps, batch {args.batch}")
    t0 = time.time()
    for step in range(args.steps):
        batch = ds.sample(args.batch, seed=10_000 + step)
        params, opt_state, metrics = step_fn(
            params, opt_state,
            {"inputs": batch["inputs"], "labels": batch["labels"]})
        if step % 50 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({time.time()-t0:.0f}s)")

    # --- calibration (validation split) --------------------------------
    val = ds.sample(1000, seed=777)
    temps = [calibrate_temperature(cfg, params, val, s)
             for s in range(cfg.num_stages)]
    print("calibration temperatures:", [round(t, 3) for t in temps])

    # --- oracle tables on the test split --------------------------------
    test = ds.sample(args.n_test, seed=999)
    # per-stage temperature applied via per-stage eval
    conf = np.zeros((args.n_test, cfg.num_stages), np.float32)
    correct = np.zeros((args.n_test, cfg.num_stages), bool)
    for s, t in enumerate(temps):
        m = eval_exit_metrics(cfg, params, test, temperature=float(t))
        conf[:, s] = m["confidence"][:, s]
        correct[:, s] = m["correct"][:, s]
    accs = correct.mean(0)
    print("per-stage accuracy:", np.round(accs, 4),
          " mean confidence:", np.round(conf.mean(0), 4))
    # calibration sanity: confidence should track accuracy
    for s in range(cfg.num_stages):
        print(f"  stage {s}: acc={accs[s]:.3f} conf={conf[:, s].mean():.3f} "
              f"gap={abs(accs[s] - conf[:, s].mean()):.3f}")

    os.makedirs(ART, exist_ok=True)
    checkpoint.save(os.path.join(ART, "anytime_classifier.ckpt"), params,
                    {"config": cfg.name, "steps": args.steps,
                     "temperatures": [float(t) for t in temps]})
    np.savez(os.path.join(ART, "oracle_tables.npz"),
             confidence=conf, correct=correct,
             difficulty=test["difficulty"], labels=test["labels"],
             stage_acc=accs, temperatures=np.array(temps),
             features=test["inputs"]["features"])
    print("saved artifacts to", os.path.abspath(ART))
    return accs


if __name__ == "__main__":
    accs = main()
    assert accs[-1] > accs[0], "deeper stages must be more accurate"
