"""Tests for the traffic subsystem (repro.serving.traffic).

* arrival-generator determinism (same seed => identical sequence, all
  kinds) and empirical rates vs configured means
* per-class request mixes (shares, SLO stamping, deadline ranges)
* open-loop TrafficSource end-to-end through the Service facade
  (arrival schedule independent of completions)
* trace record/replay: JSONL round trip; replay reproduces arrival order
  and admission decisions bit-for-bit under the virtual clock
* overload control: bounded live intake with reject / shed-optional
  backpressure; windowed metrics streaming (flash-crowd transient)
* live-mode cancellation after admission (deadline pull-in) [satellite]
* rtdeepiot-weighted: gold-class requests win utility under overload
  [satellite]
* StreamSource tolerates unsorted input (property test) [satellite]
"""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import (Request, ServeSpec, Service, record_trace,
                           scenario_spec, verify_replay)
from repro.serving.runtime.sources import StreamSource
from repro.serving.traffic import (ARRIVAL_KINDS, SCENARIOS, RequestMix,
                                   TraceRecorder, TrafficSource, load_trace,
                                   make_arrival_process, nominal_rate)

STAGE_TIMES = (0.004, 0.007, 0.010)


def oracle_tables(n=200, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


# ---------------------------------------------------------------------------
# generators: determinism + empirical rates (satellite)
# ---------------------------------------------------------------------------

ARRIVAL_CONFIGS = {
    "poisson": dict(rate=120.0),
    "mmpp": dict(rate_on=300.0, rate_off=40.0, mean_on=0.4, mean_off=1.2),
    "diurnal": dict(base_rate=40.0, peak_rate=200.0, period=4.0),
    "flash-crowd": dict(base_rate=60.0, spike_rate=400.0, spike_at=1.0,
                        spike_len=1.0),
}


def test_every_registered_kind_has_a_config_under_test():
    assert set(ARRIVAL_CONFIGS) == set(ARRIVAL_KINDS)


@pytest.mark.parametrize("kind", sorted(ARRIVAL_CONFIGS))
def test_same_seed_same_arrival_sequence(kind):
    p = make_arrival_process(kind, **ARRIVAL_CONFIGS[kind])
    a = p.sample(np.random.default_rng(7), n=200)
    b = p.sample(np.random.default_rng(7), n=200)
    c = p.sample(np.random.default_rng(8), n=200)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert len(a) == 200
    assert np.all(np.diff(a) >= 0) and np.all(a >= 0)


@pytest.mark.parametrize("kind", ["poisson", "mmpp", "flash-crowd",
                                  "diurnal"])
def test_empirical_rate_within_tolerance_of_mean(kind):
    """Long-horizon empirical arrivals/second ~ the configured mean rate.

    flash-crowd's mean is defined over [0, spike_at + 2*spike_len], so it
    is sampled over exactly that window; the others average out over a
    long horizon.
    """
    p = make_arrival_process(kind, **ARRIVAL_CONFIGS[kind])
    if kind == "flash-crowd":
        horizon = p.spike_at + 2 * p.spike_len
    else:
        horizon = 60.0
    counts = [len(p.sample(np.random.default_rng(seed), horizon=horizon))
              for seed in range(5 if kind == "flash-crowd" else 3)]
    emp = np.mean(counts) / horizon
    assert emp == pytest.approx(p.mean_rate, rel=0.12)


def test_horizon_and_n_bounds_respected():
    p = make_arrival_process("poisson", rate=100.0)
    t = p.sample(np.random.default_rng(0), horizon=2.0)
    assert np.all(t < 2.0)
    t = p.sample(np.random.default_rng(0), n=50, horizon=1000.0)
    assert len(t) == 50
    with pytest.raises(ValueError, match="n and/or horizon"):
        p.sample(np.random.default_rng(0))
    with pytest.raises(KeyError, match="available"):
        make_arrival_process("fractal")


# ---------------------------------------------------------------------------
# request mixes
# ---------------------------------------------------------------------------

def test_mix_shares_slo_and_deadline_ranges():
    mix = RequestMix([{"slo": "gold", "share": 3.0},
                      {"slo": "bronze", "share": 1.0,
                       "rel_range": [0.05, 0.1]}], n_samples=50)
    rng = np.random.default_rng(0)
    reqs = [r for _, r in mix.stream(rng, np.linspace(0, 1, 400))]
    gold = [r for r in reqs if r.slo == "gold"]
    bronze = [r for r in reqs if r.slo == "bronze"]
    assert len(gold) + len(bronze) == 400
    assert 0.65 <= len(gold) / 400 <= 0.85          # ~0.75 share
    assert all(r.rel_deadline is None for r in gold)   # SLO class supplies
    assert all(0.05 <= r.rel_deadline <= 0.1 for r in bronze)
    assert all(0 <= r.sample < 50 for r in reqs)
    with pytest.raises(ValueError, match="share"):
        RequestMix([{"share": 0.0}], n_samples=5)


# ---------------------------------------------------------------------------
# open-loop source end-to-end
# ---------------------------------------------------------------------------

def test_traffic_source_is_open_loop():
    """Arrival offsets are a pure function of (arrival, mix, seed) — the
    engine's completions cannot shift them (unlike ClosedLoopSource)."""
    p = make_arrival_process("poisson", rate=200.0)
    expect = p.sample(np.random.default_rng(5), n=40)
    mix = RequestMix([{"slo": "gold"}], n_samples=10)
    src = TrafficSource(p, mix, lambda req, now: req, n_requests=40, seed=5)
    assert np.allclose(src.offsets, expect)


def test_traffic_scenario_through_service():
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", policy="edf", stage_times=STAGE_TIMES,
                         n_requests=60, seed=2)
    assert ServeSpec.from_json(spec.to_json()) == spec    # JSON round trip
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    assert res.n_requests == 60
    assert res.components["source"] == "traffic"
    # the three-tier mix showed up in the per-class breakdown
    assert set(res.per_class) <= {"gold", "silver", "bronze"}
    assert sum(c["n"] for c in res.per_class.values()) == 60
    # steady 0.6x load: nearly everything should be served in time
    assert res.miss_rate < 0.1


def test_traffic_source_requires_sizing_args():
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", stage_times=STAGE_TIMES)
    spec.source_args.pop("n_requests")
    with pytest.raises(ValueError, match="n_requests"):
        Service.from_spec(spec, conf_table=conf, correct_table=correct).run()


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_catalog_builds_and_validates(name):
    spec = scenario_spec(name, stage_times=STAGE_TIMES, n_requests=10)
    spec.validate()
    args = spec.source_args
    nom = nominal_rate(STAGE_TIMES)
    # rates are scaled by the nominal service rate; durations stay put
    assert any(v >= 0.3 * nom for k, v in args["arrival"].items()
               if k.endswith("rate") or k == "rate")


# ---------------------------------------------------------------------------
# trace record/replay (tentpole acceptance: bit-for-bit under virtual clock)
# ---------------------------------------------------------------------------

def _replay_of(spec, metrics, conf, correct, tmp_path):
    path = str(tmp_path / "trace.jsonl")
    record_trace(metrics, path, source=spec.source, spec=spec)
    header, events = load_trace(path)
    assert header["n_events"] == len(events) == metrics.n_requests
    rspec = dataclasses.replace(spec, source="replay",
                                source_args={"path": path})
    res = Service.from_spec(rspec, conf_table=conf,
                            correct_table=correct).run()
    return header, res


def test_replay_reproduces_overloaded_run_bitwise(tmp_path):
    conf, correct = oracle_tables()
    spec = scenario_spec("flash-crowd", policy="rtdeepiot",
                         admission={"mode": "reject"},
                         stage_times=STAGE_TIMES, n_requests=150, seed=3)
    orig = Service.from_spec(spec, conf_table=conf,
                             correct_table=correct).run()
    assert orig.rejected > 0             # the run has admission decisions
    header, rep = _replay_of(spec, orig, conf, correct, tmp_path)
    v = verify_replay(orig.per_request, rep.per_request)
    assert v == {"arrival_order": True, "admission_decisions": True,
                 "bitwise": True}
    # headline aggregates carry over exactly
    assert rep.miss_rate == orig.miss_rate
    assert rep.rejected == orig.rejected
    assert rep.accuracy == orig.accuracy
    # the stored spec round-trips for later regression runs
    assert ServeSpec.from_dict(header["spec"]) == spec


def test_trace_jsonl_schema(tmp_path):
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", policy="edf", stage_times=STAGE_TIMES,
                         n_requests=12, seed=0)
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    path = str(tmp_path / "t.jsonl")
    record_trace(res, path, source="traffic")
    lines = [json.loads(x) for x in open(path)]
    assert lines[0]["type"] == "header" and lines[0]["n_events"] == 12
    ev = lines[1]
    assert {"offset", "sample", "client", "slo", "rel_deadline",
            "outcome"} <= set(ev)
    assert {"depth", "missed", "rejected", "latency",
            "deadline"} <= set(ev["outcome"])
    offsets = [e["offset"] for e in lines[1:]]
    assert offsets == sorted(offsets)        # admission order == arrival order


def test_replay_source_needs_a_trace():
    conf, correct = oracle_tables()
    spec = scenario_spec("steady", stage_times=STAGE_TIMES, n_requests=5)
    spec = dataclasses.replace(spec, source="replay", source_args={})
    with pytest.raises(KeyError, match="trace"):
        Service.from_spec(spec, conf_table=conf, correct_table=correct).run()


def test_trace_capture_of_closed_loop_run_replays_load_shape(tmp_path):
    """Closed-loop traces carry the effective (already adjusted) slack —
    replay is not bit-exact (the factory re-adjusts), but every arrival
    must survive the round trip in order."""
    from repro.core import Workload
    conf, correct = oracle_tables()
    spec = ServeSpec(policy="edf", executor="oracle", clock="virtual",
                     source="closed-loop",
                     batching={"mode": "none",
                               "stage_times": list(STAGE_TIMES)})
    wl = Workload(n_clients=4, d_lo=0.05, d_hi=0.3, n_requests=30, seed=1)
    res = Service.from_spec(spec, workload=wl, conf_table=conf,
                            correct_table=correct).run()
    rec = TraceRecorder(source="closed-loop")
    rec.capture(res)
    assert len(rec.events) == 30
    assert all(ev.rel_deadline is not None and ev.rel_deadline > 0
               for ev in rec.events)
    rspec = dataclasses.replace(spec, source="replay")
    rep = Service.from_spec(rspec, conf_table=conf, correct_table=correct,
                            trace=rec.events).run()
    assert rep.n_requests == 30


# ---------------------------------------------------------------------------
# overload control: bounded intake backpressure
# ---------------------------------------------------------------------------

def live_spec(**source_args):
    return ServeSpec(
        policy="edf", executor="oracle", clock="virtual", source="live",
        source_args=source_args,
        batching={"mode": "none", "stage_times": list(STAGE_TIMES)},
        slo_classes={"gold": {"rel_deadline": 0.5, "utility_weight": 2.0}},
        default_slo="gold")


def test_backpressure_reject_fails_fast():
    conf, correct = oracle_tables()
    svc = Service.from_spec(live_spec(bound=2, overflow="reject"),
                            conf_table=conf, correct_table=correct)
    handles = [svc.submit(Request(None, sample=i), at=0.0) for i in range(5)]
    # over-bound submissions resolve immediately, rejected, no engine trip
    assert [h.done() for h in handles] == [False, False, True, True, True]
    for h in handles[2:]:
        r = h.result()
        assert r.rejected and r.missed and r.depth == 0 and r.slo == "gold"
    met = svc.drain()
    assert met.n_requests == 2                 # only the admitted ones ran
    assert met.rejected == 3
    assert met.per_class["gold"]["rejected"] == 3
    assert met.per_class["gold"]["n"] == 2
    assert handles[0].result().depth == 3


def test_backpressure_shed_optional_drops_depth_not_requests():
    conf, correct = oracle_tables()
    svc = Service.from_spec(live_spec(bound=1, overflow="shed-optional"),
                            conf_table=conf, correct_table=correct)
    h1 = svc.submit(Request(None, sample=1), at=0.0)
    h2 = svc.submit(Request(None, sample=2), at=0.0)
    h3 = svc.submit(Request(None, sample=3), at=0.0)
    met = svc.drain()
    assert met.n_requests == 3 and met.rejected == 0
    assert h1.result().depth == 3              # under bound: untouched
    assert h2.result().depth == 1              # shed to mandatory
    assert h3.result().depth == 1
    assert not h2.result().missed
    assert met.capped == 2


def test_shed_pin_survives_admission_depth_cap():
    """Admission control must only ever *tighten* an existing depth cap:
    a shed-optional request pinned to mandatory stays at mandatory even
    when admission's own solo-feasibility cap would allow deeper."""
    conf, correct = oracle_tables()
    spec = dataclasses.replace(live_spec(bound=1, overflow="shed-optional"),
                               admission={"mode": "depth_cap"},
                               slo_classes={"gold": {"rel_deadline": 0.035}})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    h1 = svc.submit(Request(None, sample=1), at=0.0)
    h2 = svc.submit(Request(None, sample=2), at=0.0)   # over bound: shed
    svc.drain()
    # 0.035s slack allows ~depth 2 solo (admission would cap there), but
    # the shed pin to mandatory (depth 1) must win
    assert h1.result().depth >= 1
    assert h2.result().depth == 1 and not h2.result().missed


def test_slo_depth_cap_survives_admission_depth_cap():
    """Same invariant for SLO-class caps: bronze pinned to depth 1 must
    not be re-opened by admission's deadline-capped decision."""
    conf, correct = oracle_tables()
    spec = ServeSpec(
        policy="edf", executor="oracle", clock="virtual", source="live",
        batching={"mode": "none", "stage_times": list(STAGE_TIMES)},
        admission={"mode": "depth_cap"},
        slo_classes={"bronze": {"rel_deadline": 0.035, "depth_cap": 1}},
        default_slo="bronze")
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    h = svc.submit(Request(None, sample=4), at=0.0)
    svc.drain()
    assert h.result().depth == 1


def test_backpressure_spec_validation():
    with pytest.raises(ValueError, match="overflow"):
        live_spec(bound=2, overflow="explode").validate()
    with pytest.raises(ValueError, match="bound"):
        live_spec(bound=0).validate()
    with pytest.raises(ValueError, match="metrics_interval"):
        dataclasses.replace(live_spec(), metrics_interval=-1.0).validate()


# ---------------------------------------------------------------------------
# overload control: windowed metrics streaming
# ---------------------------------------------------------------------------

def test_metrics_streaming_captures_flash_crowd_transient():
    conf, correct = oracle_tables()
    snaps = []
    spec = scenario_spec("flash-crowd", policy="edf",
                         stage_times=STAGE_TIMES, n_requests=150, seed=1,
                         metrics_interval=0.5)
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct,
                            on_metrics=snaps.append)
    res = svc.run()
    assert snaps and svc.snapshots == snaps
    ts = [s.t for s in snaps]
    assert ts == sorted(ts)
    assert sum(s.n for s in snaps) == res.n_requests
    for s in snaps:
        assert 0.0 <= s.utilization <= 1.0
        assert s.queue_depth >= 0
        assert s.accuracy is None or 0.0 <= s.accuracy <= 1.0
    # the spike (t in [2.0, 3.5]) must be visible as a windowed transient
    # even though it is invisible in steady pre-spike windows
    pre = [s for s in snaps if s.t <= 2.0]
    spike = [s for s in snaps if 2.0 < s.t <= 4.5]
    assert spike, "no snapshot windows covered the spike"
    assert max(s.miss_rate for s in spike) > max(
        (s.miss_rate for s in pre), default=0.0)


def test_streaming_requires_positive_interval():
    from repro.serving.traffic import MetricsStreamer
    with pytest.raises(ValueError, match="interval"):
        MetricsStreamer(0.0, None)


# ---------------------------------------------------------------------------
# live-mode cancellation after admission (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.wallclock
def test_cancel_after_admission_sheds_optional_stages():
    conf, correct = oracle_tables()
    spec = ServeSpec(
        policy="edf", executor="oracle", clock="wall", source="live",
        batching={"mode": "none", "stage_times": [0.04, 0.04, 0.04]},
        slo_classes={"gold": {"rel_deadline": 5.0}}, default_slo="gold")
    with Service.from_spec(spec, conf_table=conf,
                           correct_table=correct) as svc:
        h = svc.submit(Request(None, sample=3))
        first = next(h.stages(timeout=10.0))     # admitted + one exit landed
        assert first.depth == 1
        assert h.cancel()                        # post-admission: pull-in
        res = h.result(timeout=10.0)
        met = svc.drain()
    # the anytime contract survives: a partial (not cancelled) result,
    # short of the full 3 stages (one in-flight stage may still commit)
    assert not h.cancelled()
    assert not res.missed
    assert 1 <= res.depth < 3
    assert met.cancelled == 1
    assert met.n_requests == 1


# ---------------------------------------------------------------------------
# rtdeepiot-weighted: gold wins utility under overload (satellite)
# ---------------------------------------------------------------------------

def test_weighted_policy_favors_gold_under_overload():
    conf, correct = oracle_tables()
    rate = 2.0 * nominal_rate(STAGE_TIMES)
    spec = ServeSpec(
        policy="rtdeepiot-weighted",
        policy_args={"predictor": "exp"},
        executor="oracle", clock="virtual", source="traffic",
        source_args={"arrival": {"kind": "poisson", "rate": rate},
                     "mix": [{"slo": "gold", "share": 0.5},
                             {"slo": "bronze", "share": 0.5}],
                     "n_requests": 250, "seed": 4},
        batching={"mode": "none", "stage_times": list(STAGE_TIMES)},
        # same deadline, different importance: depth is pure contention
        slo_classes={"gold": {"rel_deadline": 0.12, "utility_weight": 4.0},
                     "bronze": {"rel_deadline": 0.12,
                                "utility_weight": 1.0}})
    res = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    gold, bronze = res.per_class["gold"], res.per_class["bronze"]
    assert res.components["policy"] == "rtdeepiot-weighted"
    # contended optional stages go to the heavy class
    assert gold["mean_depth"] > bronze["mean_depth"]
    assert gold["miss_rate"] <= bronze["miss_rate"]


# ---------------------------------------------------------------------------
# StreamSource tolerates unsorted input (satellite)
# ---------------------------------------------------------------------------

def _run_stream(reqs, conf, correct):
    spec = ServeSpec(policy="edf", executor="oracle", clock="virtual",
                     source="stream",
                     batching={"mode": "none",
                               "stage_times": list(STAGE_TIMES)})
    return Service.from_spec(spec, conf_table=conf,
                             correct_table=correct).run(reqs)


def test_stream_source_shuffled_offsets_match_sorted():
    conf, correct = oracle_tables()
    rng = np.random.default_rng(0)
    offs = np.cumsum(rng.uniform(0.001, 0.02, 40))
    reqs = [(float(t), Request(None, 0.15, sample=i))
            for i, t in enumerate(offs)]
    shuffled = [reqs[i] for i in rng.permutation(len(reqs))]
    r_sorted = _run_stream(reqs, conf, correct)
    r_shuffled = _run_stream(shuffled, conf, correct)
    key = lambda recs: sorted((r["sample"], r["offset"], r["depth"],  # noqa: E731
                               r["missed"], r["latency"])
                              for r in recs)
    assert key(r_sorted.per_request) == key(r_shuffled.per_request)
    assert r_sorted.miss_rate == r_shuffled.miss_rate


@given(st.permutations(list(range(12))))
@settings(max_examples=20, deadline=None)
def test_stream_source_property_any_order_sorts(perm):
    """Property: whatever order (offset, request) pairs arrive in, the
    source admits them in offset order (stable for equal offsets)."""
    offs = [round(0.01 * (i // 2), 6) for i in range(12)]   # ties included
    reqs = [(offs[i], Request(None, 0.5, sample=i)) for i in range(12)]
    src = StreamSource([reqs[i] for i in perm], lambda req, now: req)
    popped = [src.pop(0.0) for _ in range(12)]
    assert [r.arrival for r in popped] == sorted(offs)
    # stability: among equal offsets, the *input* order of the shuffled
    # stream is preserved
    for off in set(offs):
        got = [r.sample for r in popped if r.arrival == off]
        expect = [reqs[i][1].sample for i in perm if reqs[i][0] == off]
        assert got == expect
