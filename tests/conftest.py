import jax
import pytest

# Tests run single-device on CPU (the 512-device dry-run is subprocess-only,
# per the assignment: XLA_FLAGS must NOT be set globally here).
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running subprocess tests")

# hypothesis is an optional dependency: when absent, install a stub so the
# property-test modules still *collect* — @given tests turn into skips and
# every plain test in those modules keeps running.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import sys
    import types

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _Strategies()
    sys.modules["hypothesis"] = _hyp


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_inputs(cfg, key, batch, seq):
    """Shape-correct smoke inputs for any modality."""
    if cfg.modality == "features":
        from repro.models.model import FEATURE_DIM
        return {"features": jax.random.normal(key, (batch, seq, FEATURE_DIM))}
    if cfg.modality == "vision_stub":
        n_text = max(1, seq - cfg.num_patches)
        return {
            "tokens": jax.random.randint(key, (batch, n_text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(
                key, (batch, cfg.num_patches, cfg.d_model)),
        }
    if cfg.modality == "audio_stub":
        return {"tokens": jax.random.randint(
            key, (batch, cfg.num_codebooks, seq), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
