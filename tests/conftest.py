import jax
import pytest

# Tests run single-device on CPU (the 512-device dry-run is subprocess-only,
# per the assignment: XLA_FLAGS must NOT be set globally here).
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocesses, jax compiles)")
    config.addinivalue_line(
        "markers", "wallclock: real-time tests (threads, sleeps, live "
        "clocks) — the deflake CI leg repeats these 20x")


def pytest_addoption(parser):
    # minimal stand-in for pytest-repeat's --count when the plugin is
    # absent; when pytest-repeat IS installed (CI) its own option wins
    # and this registration raises ValueError — ignore it.
    try:
        parser.addoption("--count", action="store", default=1, type=int,
                         help="run each test N times (pytest-repeat "
                              "fallback)")
    except ValueError:
        pass


def pytest_generate_tests(metafunc):
    count = int(metafunc.config.getoption("--count", 1) or 1)
    if count > 1 and "__repeat__" not in metafunc.fixturenames \
            and not metafunc.config.pluginmanager.hasplugin("pytest_repeat"):
        metafunc.fixturenames.append("__repeat__")
        metafunc.parametrize("__repeat__", range(count),
                             ids=[f"rep{i}" for i in range(count)])

# hypothesis is an optional dependency: when absent, install a stub so the
# property-test modules still *collect* — @given tests turn into skips and
# every plain test in those modules keeps running.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import sys
    import types

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _Strategies()
    sys.modules["hypothesis"] = _hyp


def wait_until(predicate, timeout=10.0, interval=0.005, desc="condition"):
    """Bounded polling for wall-clock tests: spin on ``predicate`` until it
    returns truthy or ``timeout`` elapses (then fail loudly).  Replaces
    bare ``time.sleep(...)`` synchronization, which is the classic flake:
    too short on a loaded CI box, dead time everywhere else."""
    import time
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        v = predicate()
        if v:
            return v
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {desc}")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_inputs(cfg, key, batch, seq):
    """Shape-correct smoke inputs for any modality."""
    if cfg.modality == "features":
        from repro.models.model import FEATURE_DIM
        return {"features": jax.random.normal(key, (batch, seq, FEATURE_DIM))}
    if cfg.modality == "vision_stub":
        n_text = max(1, seq - cfg.num_patches)
        return {
            "tokens": jax.random.randint(key, (batch, n_text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(
                key, (batch, cfg.num_patches, cfg.d_model)),
        }
    if cfg.modality == "audio_stub":
        return {"tokens": jax.random.randint(
            key, (batch, cfg.num_codebooks, seq), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
