import jax
import pytest

# Tests run single-device on CPU (the 512-device dry-run is subprocess-only,
# per the assignment: XLA_FLAGS must NOT be set globally here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_inputs(cfg, key, batch, seq):
    """Shape-correct smoke inputs for any modality."""
    import jax.numpy as jnp
    if cfg.modality == "features":
        from repro.models.model import FEATURE_DIM
        return {"features": jax.random.normal(key, (batch, seq, FEATURE_DIM))}
    if cfg.modality == "vision_stub":
        n_text = max(1, seq - cfg.num_patches)
        return {
            "tokens": jax.random.randint(key, (batch, n_text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(
                key, (batch, cfg.num_patches, cfg.d_model)),
        }
    if cfg.modality == "audio_stub":
        return {"tokens": jax.random.randint(
            key, (batch, cfg.num_codebooks, seq), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
