"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the same
family (2+ layers, d_model<=512, <=4 experts) and runs one forward / train
step on CPU, asserting output shapes and absence of NaNs.  Full configs are
exercised only via the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import make_inputs
from repro.configs import all_arch_ids, get_config
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, stage_forward, stage_layouts)

ARCHS = list(all_arch_ids())


def _expected_label_shape(cfg, batch, seq):
    if cfg.modality == "features":
        return (batch,)
    if cfg.modality == "audio_stub":
        return (batch, cfg.num_codebooks, seq)
    return (batch, seq)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_nans(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.num_experts <= 4
    params = init_params(cfg, rng)
    B, S = 2, 32
    inputs = make_inputs(cfg, jax.random.PRNGKey(1), B, S)
    out = forward(cfg, params, inputs, mode="train")
    n_stages = len(stage_layouts(cfg))
    assert len(out.logits) == n_stages
    for lg, conf in zip(out.logits, out.confidences):
        if cfg.modality == "features":
            assert lg.shape == (B, cfg.vocab_size)
            assert conf.shape == (B,)
        elif cfg.modality == "audio_stub":
            assert lg.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
        elif cfg.modality == "vision_stub":
            assert lg.shape[0] == B and lg.shape[-1] == cfg.vocab_size
        else:
            assert lg.shape == (B, S, cfg.vocab_size)
        assert not bool(jnp.isnan(lg).any())
        assert not bool(jnp.isnan(conf).any())
        assert bool((conf >= 0).all()) and bool((conf <= 1.0 + 1e-6).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch, rng):
    """One SGD step decreases nothing catastrophically & produces finite grads."""
    from repro.training.loop import make_loss_fn

    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng)
    B, S = 2, 16
    inputs = make_inputs(cfg, jax.random.PRNGKey(1), B, S)
    labels = jax.random.randint(jax.random.PRNGKey(2),
                                _expected_label_shape(cfg, B, S), 0,
                                cfg.vocab_size)
    loss_fn = make_loss_fn(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params,
                                              {"inputs": inputs,
                                               "labels": labels})
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # at least some gradient signal reaches the embedding
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    if cfg.modality == "features":
        pytest.skip("classifier has no decode path")
    params = init_params(cfg, rng)
    B = 2
    cache = init_decode_cache(cfg, B, slots=8)
    tok = (jnp.zeros((B, cfg.num_codebooks), jnp.int32)
           if cfg.modality == "audio_stub" else jnp.zeros((B,), jnp.int32))
    ex, new_cache = decode_step(cfg, params, cache, tok,
                                jnp.zeros((B,), jnp.int32))
    for lg in ex.logits:
        assert lg.shape[0] == B and lg.shape[-1] == cfg.vocab_size
        assert not bool(jnp.isnan(lg).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


CONSISTENCY_ARCHS = ["qwen3-4b", "gemma3-4b", "xlstm-1.3b",
                     "jamba-1.5-large-398b", "deepseek-v3-671b",
                     "musicgen-medium", "mistral-large-123b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_full_forward(arch, rng):
    """Token-by-token decode reproduces the full forward's last-position
    logits (capacity factor raised for MoE archs: GShard capacity drops are
    a prefill-only semantic and would otherwise differ by construction)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, rng)
    B, S = 2, 16
    inputs = make_inputs(cfg, jax.random.PRNGKey(1), B, S)
    out = forward(cfg, params, inputs, mode="train")
    full_last = out.logits[-1][:, -1]
    cache = init_decode_cache(cfg, B, slots=S)
    toks = inputs["tokens"]
    for t in range(S):
        tok = toks[:, :, t] if cfg.modality == "audio_stub" else toks[:, t]
        ex, cache = decode_step(cfg, params, cache, tok,
                                jnp.full((B,), t, jnp.int32))
    import numpy as np
    np.testing.assert_allclose(np.asarray(ex.logits[-1]),
                               np.asarray(full_last), rtol=5e-3, atol=5e-3)


def test_ring_buffer_cache_matches_window_mask(rng):
    """swa-8192 analog: a ring cache of W slots must equal full attention
    with an explicit W-token sliding window."""
    import dataclasses

    import numpy as np
    cfg = get_config("gemma3-4b").reduced()
    cfg = dataclasses.replace(cfg, period=("attn_local",), num_layers=2,
                              sliding_window=8, num_stages=1)
    params = init_params(cfg, rng)
    B, S, W = 2, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    out = forward(cfg, params, {"tokens": toks}, mode="train")
    cache = init_decode_cache(cfg, B, slots=W)   # ring of W slots
    for t in range(S):
        ex, cache = decode_step(cfg, params, cache, toks[:, t],
                                jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(ex.logits[-1]),
                               np.asarray(out.logits[-1][:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_stage_forward_composes_to_full_forward(rng):
    """The scheduler's stage-granular dispatch equals the monolithic
    forward — the property that makes imprecise computation exact."""
    import numpy as np
    cfg = get_config("anytime-classifier")
    params = init_params(cfg, rng)
    B, S = 3, 16
    inputs = make_inputs(cfg, jax.random.PRNGKey(1), B, S)
    ref = forward(cfg, params, inputs, mode="train")

    h = inputs
    for s in range(cfg.num_stages):
        h, lg, conf = stage_forward(cfg, params, s, h, mode="train")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref.logits[s]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(conf),
                                   np.asarray(ref.confidences[s]),
                                   rtol=1e-5, atol=1e-5)


def test_param_counts_match_assignment_scale():
    """Analytic parameter counts are in the advertised ballpark."""
    from repro.models import count_params_analytic
    expect = {
        "mistral-large-123b": (100e9, 150e9),
        "nemotron-4-340b": (300e9, 380e9),
        "deepseek-v3-671b": (600e9, 750e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "jamba-1.5-large-398b": (330e9, 480e9),
        "pixtral-12b": (10e9, 15e9),
        "qwen3-4b": (3e9, 5e9),
        "gemma3-4b": (3e9, 5.5e9),
        "xlstm-1.3b": (1.0e9, 2.5e9),   # multi-exit heads + 3-stage structure
                                        # add params over the bare 1.3B stack
        "musicgen-medium": (1.3e9, 2.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params_analytic(get_config(arch))
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)


def test_moe_active_params():
    from repro.models import count_params_analytic
    cfg = get_config("deepseek-v3-671b")
    total = count_params_analytic(cfg)
    active = count_params_analytic(cfg, active_only=True)
    assert 25e9 <= active <= 45e9          # ~37B active
    assert active < 0.1 * total
