"""Substrate tests: data pipeline, optimizer, checkpointing, serving engine,
confidence calibration plumbing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.training import (AdamW, DifficultyDataset, checkpoint,
                            lm_token_stream, make_train_step, warmup_cosine)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_difficulty_dataset_deterministic():
    ds = DifficultyDataset(seed=3)
    a = ds.sample(32, seed=7)
    b = ds.sample(32, seed=7)
    np.testing.assert_array_equal(a["labels"], b["labels"])
    np.testing.assert_allclose(a["inputs"]["features"],
                               b["inputs"]["features"])


def test_difficulty_dataset_label_follows_chain():
    """The label must be the value at the true terminal of the pointer chain
    from cell 0 — re-derive it from the (noiseless) feature encoding."""
    ds = DifficultyDataset(seed=0, noise=0.0)
    d = ds.sample(64, seed=5)
    x = d["inputs"]["features"]
    sub = ds.feature_dim // 4
    # decode vals/ptrs from embeddings by nearest neighbour
    def nearest(block, table):
        d2 = ((block[:, :, None, :] - table[None, None]) ** 2).sum(-1)
        return d2.argmin(-1)
    vals = nearest(x[:, :, sub:2 * sub], ds.val_emb)
    ptrs = nearest(x[:, :, 2 * sub:3 * sub], ds.pos_emb)
    term = nearest(x[:, :, 3 * sub:], ds.term_emb)
    for i in range(x.shape[0]):
        cur = 0
        for _ in range(ds.seq_len + 1):
            if term[i, cur] == 1:
                break
            cur = ptrs[i, cur]
        assert vals[i, cur] == d["labels"][i]


def test_difficulty_bands_cover_spread():
    ds = DifficultyDataset(seed=0)
    d = ds.sample(512, seed=1)
    lens = d["difficulty"]
    assert lens.min() <= 2 and lens.max() >= 8


def test_lm_stream_learnable_structure():
    gen = lm_token_stream(vocab=64, seed=0)
    b = gen(4, 32, step_seed=1)
    assert b["inputs"]["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # next token is drawn from <= branching options given context
    assert b["labels"].max() < 64


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_gradients():
    opt = AdamW(learning_rate=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    upd, _ = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(jnp.abs(upd["w"]).max()) < 10.0


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.array(0))) == pytest.approx(0.0)
    assert float(sched(jnp.array(10))) == pytest.approx(1.0, abs=0.02)
    assert float(sched(jnp.array(100))) == pytest.approx(0.1, abs=0.02)


def test_bf16_state_dtype():
    opt = AdamW(learning_rate=0.1, state_dtype="bfloat16")
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    upd, state2 = opt.update({"w": jnp.ones(4)}, state, params)
    assert state2.nu["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("anytime-classifier")
    params = init_params(cfg, rng)
    path = os.path.join(tmp_path, "x.ckpt")
    checkpoint.save(path, params, {"step": 7})
    restored, meta = checkpoint.load(path, params)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path, rng):
    cfg = get_config("anytime-classifier")
    params = init_params(cfg, rng)
    path = os.path.join(tmp_path, "x.ckpt")
    checkpoint.save(path, params)
    bad = jax.tree.map(lambda x: jnp.zeros((*x.shape, 2), x.dtype), params)
    with pytest.raises(ValueError):
        checkpoint.load(path, bad)


# ---------------------------------------------------------------------------
# training decreases loss on the real pipeline
# ---------------------------------------------------------------------------

def test_train_decreases_loss(rng):
    cfg = get_config("anytime-classifier")
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    params = init_params(cfg, rng)
    opt = AdamW(learning_rate=2e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for i in range(30):
        b = ds.sample(64, seed=100 + i)
        params, opt_state, m = step(params, opt_state,
                                    {"inputs": b["inputs"],
                                     "labels": b["labels"]})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# ---------------------------------------------------------------------------
# serving engine (wall clock, real stage fns)
# ---------------------------------------------------------------------------

@pytest.mark.slow                    # jax compile dominates; no 20x repeat
@pytest.mark.wallclock
def test_serving_engine_end_to_end(rng):
    from repro.serving import (ServeSpec, Service, closed_loop_stream,
                               make_stage_fns, profile_stages)

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, rng)
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(40, seed=9)
    fns = make_stage_fns(cfg)
    sample = jax.tree.map(lambda x: x[:1], test["inputs"])
    wcet, _, _ = profile_stages(cfg, params, fns, sample, n_runs=5)
    spec = ServeSpec(policy="rtdeepiot",
                     policy_args={"predictor": "exp",
                                  "prior_curve": [.5, .7, .85]},
                     executor="device-single", clock="wall", source="stream",
                     batching={"mode": "none",
                               "stage_times": [float(x) for x in wcet]})
    svc = Service.from_spec(spec, cfg=cfg, params=params, stage_fns=fns)
    # paper-like ratio: relative deadlines are many multiples of one stage
    # (their GPU stages ~10-25ms vs 10-300ms deadlines); our CPU stages are
    # ~1ms so host dispatch is a visible fraction — scale accordingly
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=3,
                                d_lo=float(8 * wcet.max()),
                                d_hi=float(25 * wcet.max()), n_requests=12)
    svc.run(stream)
    responses = svc.responses
    assert len(responses) == 12
    done = [r for r in responses if not r.missed]
    assert len(done) >= 7            # generous deadlines: most complete
    for r in done:
        assert 1 <= r.depth <= cfg.num_stages
        assert 0.0 <= r.confidence <= 1.0


@pytest.mark.slow                    # jax compile dominates; no 20x repeat
@pytest.mark.wallclock
def test_serving_engine_tight_deadlines_shed_stages(rng):
    from repro.serving import (ServeSpec, Service, closed_loop_stream,
                               make_stage_fns, profile_stages)

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, rng)
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(40, seed=9)
    fns = make_stage_fns(cfg)
    sample = jax.tree.map(lambda x: x[:1], test["inputs"])
    wcet, _, _ = profile_stages(cfg, params, fns, sample, n_runs=5)
    spec = ServeSpec(policy="rtdeepiot",
                     policy_args={"predictor": "exp",
                                  "prior_curve": [.5, .7, .85]},
                     executor="device-single", clock="wall", source="stream",
                     batching={"mode": "none",
                               "stage_times": [float(x) for x in wcet]})
    svc = Service.from_spec(spec, cfg=cfg, params=params, stage_fns=fns)
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=6,
                                d_lo=float(3.5 * wcet.max()),
                                d_hi=float(7 * wcet.max()), n_requests=18)
    svc.run(stream)
    depths = [r.depth for r in svc.responses if not r.missed]
    assert depths and np.mean(depths) < cfg.num_stages  # shedding happened
