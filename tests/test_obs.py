"""Tests for the observability layer (repro.serving.obs).

The two load-bearing properties:

* **Passivity** — a traced run schedules bit-for-bit identically to an
  untraced one on the virtual clock (all four policies): the Tracer only
  appends engine-computed timestamps, never charges host time.
* **Attributability** — every rejected / shed / depth-capped request in
  the 2x-overload scenario has an audit-log entry naming the rule that
  fired and the numbers behind it, for every rejection path (admission
  reasons, intake bound, intake shed, tenant quota).

Plus: span typing/ordering, time-split bookkeeping, Chrome trace_event
schema validity, JSONL round trip + planectl subcommands, the metrics
registry feeding ServiceSnapshot, per-request emit-only-when-set
fields, the per-run counter-reset regression, and a wall-clock
device-batched smoke.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Workload
from repro.serving import ServeSpec, Service
from repro.serving.engine import Request
from repro.serving.obs import (MetricsRegistry, Tracer, load_obs,
                               validate_chrome_trace)
from repro.serving.traffic import scenario_spec

STAGE_TIMES = [0.004, 0.007, 0.010]


def oracle_tables(n=600, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def _spec(policy, trace, **kw):
    args = {}
    if policy == "rtdeepiot":
        args = {"delta": 0.3}
    base = dict(policy=policy, policy_args=args,
                batching={"stage_times": STAGE_TIMES,
                          "buckets": [1, 2, 4, 8], "marginal": 0.15},
                source_args={"n_clients": 12, "d_lo": 0.01, "d_hi": 0.25,
                             "n_requests": 200},
                trace=trace)
    base.update(kw)
    return ServeSpec(**base)


def _run(spec):
    conf, correct = oracle_tables()
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    return svc, svc.run()


# per-request keys only the tracer adds — excluded from the parity diff
OBS_KEYS = ("queue_wait", "host_time", "device_time", "decision")


def _strip(rows):
    # tid is a process-global counter, so runs are compared by row order,
    # not by tid
    out = []
    for r in rows:
        d = {k: v for k, v in r.items() if k not in OBS_KEYS and k != "tid"}
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# passivity: tracing on == tracing off, bit for bit (virtual clock)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["rtdeepiot", "edf", "lcf", "rr"])
def test_tracing_is_bitwise_invisible(policy):
    _, off = _run(_spec(policy, {}, admission={"mode": "depth_cap"}))
    svc, on = _run(_spec(policy, {"enabled": True},
                         admission={"mode": "depth_cap"}))
    assert (on.accuracy, on.miss_rate, on.mean_depth, on.mean_conf,
            on.makespan, on.throughput) == \
        (off.accuracy, off.miss_rate, off.mean_depth, off.mean_conf,
         off.makespan, off.throughput)
    assert on.n_dispatches == off.n_dispatches
    assert _strip(on.per_request) == _strip(off.per_request)
    # ... and the traced run actually recorded everything
    assert len(svc.obs.traces) == on.n_requests


def test_trace_disabled_by_default():
    svc, _ = _run(_spec("edf", {}))
    assert svc.obs is None
    svc2, res = _run(_spec("edf", {"enabled": False, "export": "/nope"}))
    assert svc2.obs is None
    assert "decision" not in res.per_request[0]


# ---------------------------------------------------------------------------
# span typing, ordering, time splits
# ---------------------------------------------------------------------------

def test_span_ordering_and_time_splits():
    svc, res = _run(_spec("rtdeepiot", {"enabled": True}))
    assert len(svc.obs.traces) == res.n_requests
    for tr in svc.obs.traces.values():
        names = tr.span_names()
        assert names[0] == "queued"
        assert names[-1] in ("retire", "expire")
        # chronological, with the typed tie-break order
        ts = [s.t0 for s in tr.spans]
        assert ts == sorted(ts)
        if not tr.rejected:
            assert "admitted" in names
        # every dispatch seat has its batched twin and vice versa
        assert names.count("batched") == names.count("dispatch")
        # served requests rode exactly depth device windows
        if not tr.missed and not tr.rejected:
            assert names.count("device-window") >= tr.depth
            assert names.count("stage-exit") == tr.depth
        # time splits: non-negative and bounded by latency
        assert tr.queue_wait >= 0 and tr.device_time >= 0 \
            and tr.host_time >= 0
        assert tr.queue_wait + tr.device_time + tr.host_time \
            <= tr.latency + 1e-9
    # device windows carry seating: bucket >= n for every closed window
    assert svc.obs.windows
    for w in svc.obs.windows:
        assert w["bucket"] >= w["n"] >= 1
        assert w["t1"] >= w["t0"]


def test_per_request_rows_emit_only_when_set():
    """Traced rows gain queue_wait/host_time/device_time/decision;
    untraced rows don't carry the keys at all (Record-style emit-only-
    when-set, so existing trace JSON keeps loading)."""
    _, off = _run(_spec("edf", {}))
    for r in off.per_request:
        assert not any(k in r for k in OBS_KEYS)
    svc, on = _run(_spec("edf", {"enabled": True}))
    for r in on.per_request:
        assert all(k in r for k in OBS_KEYS)
        assert r["decision"] == "admitted"   # no admission controller
    # rows stay JSON-serializable (the Record codec contract)
    json.dumps(on.per_request)


# ---------------------------------------------------------------------------
# audit log: every rejection path names its rule and inputs
# ---------------------------------------------------------------------------

def test_audit_covers_every_shed_request_at_2x_overload():
    conf, correct = oracle_tables()
    for mode, rules in (("reject", {"overload", "mandatory-infeasible"}),
                        ("depth_cap", {"overload-capped",
                                       "deadline-capped",
                                       "mandatory-infeasible"})):
        spec = scenario_spec("2x-overload", stage_times=STAGE_TIMES,
                             n_requests=300, admission={"mode": mode},
                             trace={"enabled": True})
        svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
        svc.run()
        audited = {row["tid"] for row in svc.obs.audit_log}
        degraded = [tr for tr in svc.obs.traces.values()
                    if tr.rejected or tr.depth_cap is not None]
        assert degraded, "overload scenario must shed something"
        for tr in degraded:
            assert tr.tid in audited, \
                f"request {tr.tid} ({tr.decision}) has no audit entry"
        for row in svc.obs.audit_log:
            assert row["rule"] in rules
            assert "slack" in row["detail"]   # the numbers behind the rule
            if row["rule"] in ("overload", "overload-capped"):
                assert "backlog" in row["detail"]


def test_audit_reason_intake_bound_and_shed():
    conf, correct = oracle_tables()

    def live_spec(overflow):
        return ServeSpec(policy="edf", source="live",
                         batching={"stage_times": STAGE_TIMES,
                                   "buckets": [1, 2, 4], "marginal": 0.15},
                         source_args={"bound": 2, "overflow": overflow},
                         trace={"enabled": True})

    for overflow, rule, kindcount in (("reject", "intake-bound", 3),
                                      ("shed-optional", "intake-shed", 3)):
        svc = Service.from_spec(live_spec(overflow), conf_table=conf,
                                correct_table=correct)
        for i in range(5):
            svc.submit(Request(inputs=None, rel_deadline=0.5, sample=i,
                               client=0, arrival=0.0), at=0.001 * i,
                       request_id=f"q{i}")
        svc.drain()
        rows = [r for r in svc.obs.audit_log if r["rule"] == rule]
        assert len(rows) == kindcount
        for r in rows:
            assert r["detail"]["bound"] == 2
            assert r["detail"]["intake_depth"] >= 2
            assert r["request_id"].startswith("q")
        # counted exactly once in the registry
        reg = svc.obs.registry
        key = "requests_rejected" if rule == "intake-bound" \
            else "requests_capped"
        assert reg.counter(key).value == kindcount


def test_audit_reason_tenant_quota():
    from repro.serving.plane import FrontDoor
    conf, correct = oracle_tables()
    spec = ServeSpec(policy="edf", source="frontdoor",
                     batching={"stage_times": STAGE_TIMES,
                               "buckets": [1, 2, 4], "marginal": 0.15},
                     tenants={"a": {"rate": 1.0, "burst": 1.0},
                              "b": {"weight": 1.0}},
                     trace={"enabled": True})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    fd = FrontDoor(svc)
    # burst 1, rate 1/s: the second same-instant submission breaks quota
    for i in range(3):
        fd.submit(Request(inputs=None, rel_deadline=0.5, sample=i,
                          client=0, arrival=0.0), tenant="a", at=0.0,
                  request_id=f"a{i}")
    fd.submit(Request(inputs=None, rel_deadline=0.5, sample=3, client=0,
                      arrival=0.0), tenant="b", at=0.0, request_id="b0")
    svc.drain()
    rows = [r for r in svc.obs.audit_log if r["rule"] == "tenant-quota"]
    assert len(rows) == 2 and all(r["tenant"] == "a" for r in rows)
    for r in rows:
        assert r["detail"]["rate"] == 1.0 and r["detail"]["burst"] == 1.0
    # exactly one audit row + one registry count per quota reject
    assert svc.obs.registry.counter("requests_rejected").value == 2


def test_audit_cancel_pullin():
    conf, correct = oracle_tables()
    spec = ServeSpec(policy="edf", source="live",
                     batching={"stage_times": [0.05, 0.05, 0.05],
                               "buckets": [1, 2], "marginal": 0.2},
                     trace={"enabled": True})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    h = svc.submit(Request(inputs=None, rel_deadline=1.0, sample=0,
                           client=0, arrival=0.0), at=0.0)
    h2 = svc.submit(Request(inputs=None, rel_deadline=1.0, sample=1,
                            client=0, arrival=0.0), at=0.0)
    assert h is not None and h2.cancel() is not None
    svc.drain()
    # the buffered-live cancel path resolves before the engine runs, so a
    # pull-in row appears only when the cancel raced an admitted task;
    # either way the log stays consistent with the registry counter
    pullins = [r for r in svc.obs.audit_log if r["rule"] == "cancel-pullin"]
    assert len(pullins) == svc.obs.registry.counter("pullins").value


# ---------------------------------------------------------------------------
# exports: JSONL round trip + Chrome trace_event schema
# ---------------------------------------------------------------------------

def test_jsonl_round_trip_and_chrome_schema(tmp_path):
    out = tmp_path / "obs.jsonl"
    chrome = tmp_path / "trace.json"
    svc, res = _run(_spec("rtdeepiot",
                          {"enabled": True, "export": str(out),
                           "chrome": str(chrome)},
                          admission={"mode": "depth_cap"}))
    obs = load_obs(str(out))
    assert obs["header"]["obs_version"] == 1
    assert len(obs["traces"]) == res.n_requests == obs["header"]["n_traces"]
    assert len(obs["audit"]) == len(svc.obs.audit_log)
    assert len(obs["windows"]) == len(svc.obs.windows)
    assert obs["metrics"]["requests_admitted"]["value"] == res.n_requests
    # histograms survive with their explicit buckets
    h = obs["metrics"]["latency"]
    assert h["type"] == "histogram" and h["n"] == res.n_requests \
        and sum(h["counts"]) == h["n"]
    doc = json.loads(chrome.read_text())
    assert validate_chrome_trace(doc) == []
    kinds = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "M"} <= kinds
    # per-device-window lanes: every window event lives on a named lane
    lanes = {e["tid"] for e in doc["traceEvents"]
             if e["ph"] == "X" and e["pid"] == 1}
    named = {e["tid"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["pid"] == 1
             and e["name"] == "thread_name"}
    assert lanes and lanes <= named
    # lanes never overlap (the Perfetto-lane invariant)
    per_lane = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X" and e["pid"] == 1:
            per_lane.setdefault(e["tid"], []).append(
                (e["ts"], e["ts"] + e["dur"]))
    for spans in per_lane.values():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-6


def test_validate_chrome_trace_flags_bad_docs():
    assert validate_chrome_trace([]) == ["document is not a JSON object"]
    assert validate_chrome_trace({}) == ["missing traceEvents array"]
    bad = {"traceEvents": [{"ph": "X", "name": "w", "pid": 1, "tid": 0,
                            "ts": -5, "dur": 1},
                           {"ph": "?", "name": "x"}]}
    problems = validate_chrome_trace(bad)
    assert len(problems) == 2


def test_planectl_trace_why_top(tmp_path):
    conf, correct = oracle_tables()
    out = tmp_path / "obs.jsonl"
    spec = ServeSpec(policy="edf", source="live",
                     batching={"stage_times": STAGE_TIMES,
                               "buckets": [1, 2, 4], "marginal": 0.15},
                     admission={"mode": "reject", "headroom": 2.0},
                     trace={"enabled": True, "export": str(out)})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    for i in range(12):
        svc.submit(Request(inputs=None, rel_deadline=0.05, sample=i,
                           client=0, arrival=0.0), at=i * 0.003,
                   request_id=f"req-{i}")
    svc.drain()
    tool = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "planectl.py")
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(tool), "..", "src"))

    def run(*args):
        return subprocess.run([sys.executable, tool, *args], env=env,
                              capture_output=True, text=True)

    r = run("trace", str(out), "req-0")
    assert r.returncode == 0 and "req-0" in r.stdout \
        and "queued" in r.stdout
    r = run("why", str(out), "req-11")
    assert r.returncode == 0
    r = run("top", str(out), "-n", "3", "--by", "latency")
    assert r.returncode == 0 and "total 12 traced" in r.stdout
    r = run("trace", str(out), "no-such-request")
    assert r.returncode == 1


# ---------------------------------------------------------------------------
# metrics registry + streamer integration + reset regression
# ---------------------------------------------------------------------------

def test_registry_instruments():
    reg = MetricsRegistry()
    c = reg.counter("a")
    c.inc()
    c.inc(2)
    assert reg.counter("a").value == 3
    g = reg.gauge("g")
    g.set(7)
    assert reg.gauge("g").value == 7.0
    h = reg.histogram("h", buckets=[1, 2, 4])
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [1, 1, 1, 1] and h.n == 4
    assert h.mean == pytest.approx(105.0 / 4)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=[3, 1])
    d = reg.to_dict()
    assert d["a"]["value"] == 3 and d["h"]["buckets"] == [1.0, 2.0, 4.0]


def test_snapshots_read_registry_counters():
    """With tracing on, ServiceSnapshot's rejected/capped windows come
    from the obs registry — and match the untraced (legacy-derived)
    stream exactly."""
    def run(trace):
        spec = scenario_spec("2x-overload", stage_times=STAGE_TIMES,
                             n_requests=250,
                             admission={"mode": "reject", "headroom": 3.0},
                             metrics_interval=0.2, trace=trace)
        conf, correct = oracle_tables()
        svc = Service.from_spec(spec, conf_table=conf,
                                correct_table=correct)
        svc.run()
        return svc.snapshots

    legacy = [(s.t, s.rejected, s.capped) for s in run({})]
    traced = [(s.t, s.rejected, s.capped) for s in run({"enabled": True})]
    assert traced == legacy
    assert sum(r for _, r, _ in traced) > 0


def test_streamer_counters_reset_on_service_reuse():
    """Regression (telemetry reset satellite): intake/backpressure
    counters are fresh per run on a reused Service, so a second run's
    metrics and first snapshot window don't inherit the first run's
    rejects."""
    conf, correct = oracle_tables()
    spec = ServeSpec(policy="edf", source="live",
                     batching={"stage_times": STAGE_TIMES,
                               "buckets": [1, 2], "marginal": 0.15},
                     source_args={"bound": 1, "overflow": "reject"},
                     metrics_interval=0.1)
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)

    def cycle(n):
        for i in range(n):
            svc.submit(Request(inputs=None, rel_deadline=0.5, sample=i,
                               client=0, arrival=0.0), at=0.0)
        return svc.drain()

    m1 = cycle(3)
    assert m1.rejected == 2
    assert sum(s.rejected for s in svc.snapshots) == 2
    m2 = cycle(1)
    assert m2.rejected == 0, "second run inherited first run's rejects"
    assert sum(s.rejected for s in svc.snapshots) == 0
    assert m2.cancelled == 0 and m2.capped == 0


def test_spec_trace_validation():
    with pytest.raises(ValueError, match="unknown trace keys"):
        ServeSpec(trace={"enable": True}).validate()
    with pytest.raises(ValueError, match="file path"):
        ServeSpec(trace={"enabled": True, "export": 7}).validate()
    # round-trips like every other spec field
    spec = ServeSpec(trace={"enabled": True, "spans": False})
    assert ServeSpec.from_json(spec.to_json()).trace == spec.trace


def test_trace_spans_off_keeps_time_splits():
    svc, res = _run(_spec("edf", {"enabled": True, "spans": False}))
    assert svc.obs.traces == {}          # span retention gated off
    assert all("queue_wait" in r for r in res.per_request)
    assert svc.obs.registry.counter("requests_admitted").value \
        == res.n_requests


# ---------------------------------------------------------------------------
# wall-clock smoke: obs under the device-batched executor
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.wallclock
def test_wall_clock_device_batched_obs_smoke():
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import closed_loop_stream
    from repro.serving.batch import BatchTimeModel
    from repro.training import DifficultyDataset

    cfg = get_config("anytime-classifier")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    test = ds.sample(30, seed=9)
    tm = BatchTimeModel.linear((0.002, 0.003, 0.004), (1, 2, 4),
                               marginal=0.25)
    spec = ServeSpec(policy="rtdeepiot",
                     policy_args={"predictor": "exp",
                                  "prior_curve": [.5, .7, .85]},
                     executor="device-batched", clock="wall",
                     source="stream", trace={"enabled": True})
    svc = Service.from_spec(spec, cfg=cfg, params=params, time_model=tm)
    stream = closed_loop_stream(test["inputs"], test["labels"], n_clients=4,
                                d_lo=0.2, d_hi=0.5, n_requests=10, seed=1)
    svc.run(stream)
    assert len(svc.responses) == 10
    assert len(svc.obs.traces) == 10
    for tr in svc.obs.traces.values():
        assert tr.span_names()[0] == "queued"
        # wall-clock device windows really cost time
        if not tr.missed:
            assert tr.device_time > 0
    assert validate_chrome_trace(svc.obs.chrome_trace()) == []
