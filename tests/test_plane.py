"""Durable request plane (repro.serving.plane): journal codec + WAL
semantics, idempotent durable submission, crash recovery (bit-for-bit
redo, kill -9 subprocess), multi-tenant front door (quotas, DRR
fairness, weight composition), and the health surfaces."""
import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import (DurableQueue, FrontDoor, Journal, Record,
                           ServeSpec, Service, journal_stats, recover,
                           scan_journal, verify_recovery)
from repro.serving.engine import Request
from repro.serving.plane.frontdoor import FrontDoorSource, TokenBucket
from repro.serving.runtime import OracleExecutor
from repro.serving.traffic.trace import TRACE_VERSION, load_trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STAGE_TIMES = (0.004, 0.007, 0.010)


def oracle_tables(n=120, L=3, seed=0):
    rng = np.random.default_rng(seed)
    conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
    correct = rng.uniform(size=(n, L)) < conf
    return conf, correct.astype(bool)


def live_spec(**overrides):
    kw = dict(policy="edf", executor="oracle", clock="virtual",
              source="live", default_slo="gold",
              slo_classes={"gold": {"rel_deadline": 0.2}},
              batching={"mode": "none", "stage_times": list(STAGE_TIMES)})
    kw.update(overrides)
    return ServeSpec(**kw)


def truncate_after_retires(journal_dir, keep):
    """Crash simulation: drop every terminal record after the keep-th
    (line-boundary truncation of a single-segment journal)."""
    seg = os.path.join(journal_dir, "wal-000000.jsonl")
    out, n_term = [], 0
    with open(seg) as f:
        for line in f:
            if '"kind": "RETIRE"' in line or '"kind": "REJECT"' in line:
                n_term += 1
                if n_term > keep:
                    continue
            out.append(line)
    with open(seg, "w") as f:
        f.writelines(out)


# ---------------------------------------------------------------------------
# record codec
# ---------------------------------------------------------------------------

def test_record_roundtrip_all_fields():
    r = Record(offset=1.25, sample=7, client=2, slo="gold",
               rel_deadline=0.1, outcome={"depth": 2, "missed": False},
               kind="RETIRE", tenant="acme", request_id="r-1", seq=42)
    back = Record.from_dict(json.loads(r.to_json()))
    assert back == r
    with pytest.raises(ValueError, match="unknown record kind"):
        Record.from_dict({"offset": 0.0, "kind": "NOPE"})


def test_record_event_serializes_as_version1():
    """A plain EVENT row must stay byte-identical to the version-1 trace
    schema: no kind/tenant/request_id/seq keys on disk."""
    r = Record(offset=0.5, sample=3, client=1, slo="gold", rel_deadline=0.2,
               outcome={"depth": 1})
    d = json.loads(r.to_json())
    assert set(d) == {"offset", "sample", "client", "slo", "rel_deadline",
                      "outcome"}
    assert Record.from_dict(d).kind == "EVENT"


def test_record_model_field_roundtrips_and_stays_v1_compatible():
    """The model-zoo id follows the emit-only-when-set rule: a record
    carrying one round-trips it exactly, a record without one serializes
    byte-identically to the pre-zoo schema and reads back as None."""
    r = Record(offset=0.75, sample=4, kind="SUBMIT", request_id="r-9",
               model="llm")
    d = json.loads(r.to_json())
    assert d["model"] == "llm"
    back = Record.from_dict(d)
    assert back == r and back.request().model == "llm"
    plain = Record(offset=0.75, sample=4, kind="SUBMIT", request_id="r-9")
    dp = json.loads(plain.to_json())
    assert "model" not in dp                    # v2-without-model byte compat
    assert Record.from_dict(dp).model is None
    v1 = Record(offset=0.5, sample=3, client=1, slo="gold", rel_deadline=0.2)
    assert v1.to_json() == json.dumps(dict(
        offset=0.5, sample=3, client=1, slo="gold", rel_deadline=0.2))


def test_record_request_carries_plane_fields():
    r = Record(offset=2.0, sample=5, slo="gold", rel_deadline=0.3,
               kind="SUBMIT", tenant="t0", request_id="rid-5")
    req = r.request()
    assert (req.arrival, req.sample, req.slo) == (2.0, 5, "gold")
    assert (req.tenant, req.request_id) == ("t0", "rid-5")


def test_record_dedup_key_shapes():
    assert Record(offset=0.0).dedup_key() is None
    a = Record(offset=0.0, kind="RETIRE", request_id="x")
    assert a.dedup_key() == ("RETIRE", "x")
    s1 = Record(offset=0.0, kind="STAGE", request_id="x",
                outcome={"depth": 1})
    s2 = Record(offset=0.0, kind="STAGE", request_id="x",
                outcome={"depth": 2})
    assert s1.dedup_key() != s2.dedup_key()


@settings(max_examples=50, deadline=None)
@given(offset=st.floats(min_value=0, max_value=1e6, allow_nan=False),
       sample=st.integers(min_value=0, max_value=10**6),
       client=st.integers(min_value=0, max_value=10**4),
       kind=st.sampled_from(("SUBMIT", "ADMIT", "STAGE", "RETIRE",
                             "REJECT", "EVENT")),
       tenant=st.one_of(st.none(), st.text(min_size=1, max_size=20)),
       rid=st.one_of(st.none(), st.text(min_size=1, max_size=40)),
       seq=st.one_of(st.none(), st.integers(min_value=0, max_value=2**40)),
       rel=st.one_of(st.none(), st.floats(min_value=1e-6, max_value=100,
                                          allow_nan=False)))
def test_record_codec_roundtrip_property(offset, sample, client, kind,
                                         tenant, rid, seq, rel):
    """Property: any record (unicode tenant ids included) survives the
    JSONL round trip exactly."""
    r = Record(offset=offset, sample=sample, client=client, slo=None,
               rel_deadline=rel, outcome=None, kind=kind, tenant=tenant,
               request_id=rid, seq=seq)
    assert Record.from_dict(json.loads(r.to_json())) == r


# ---------------------------------------------------------------------------
# journal WAL semantics
# ---------------------------------------------------------------------------

def test_journal_rotation_dedup_and_reopen(tmp_path):
    d = str(tmp_path / "j")
    spec = live_spec()
    with Journal(d, spec=spec, fsync_every=2, segment_records=4) as j:
        for i in range(10):
            j.append("SUBMIT", offset=i * 0.1, sample=i,
                     request_id=f"r{i}")
        # idempotent: same (kind, request_id) refuses
        assert j.append("SUBMIT", offset=9.9, request_id="r3") is None
        assert j.counts["SUBMIT"] == 10
        first_seq = j.next_seq
    segs = sorted(p for p in os.listdir(d) if p.startswith("wal-"))
    assert len(segs) == 3          # 4+4+2 records across rotated segments
    # every segment carries a header with the spec
    for seg in segs:
        with open(os.path.join(d, seg)) as f:
            h = json.loads(f.readline())
        assert h["type"] == "header" and "spec" in h
    # reopen: seq continues, dedup index rebuilt from disk
    with Journal(d) as j2:
        assert j2.next_seq == first_seq
        assert j2.spec is not None and j2.spec.source == spec.source
        assert j2.append("SUBMIT", offset=0.0, request_id="r5") is None
        assert j2.append("RETIRE", offset=1.0, request_id="r5",
                         outcome={"depth": 1}) is not None
    header, records = scan_journal(d)
    assert header["version"] == TRACE_VERSION
    assert [r.seq for r in records] == list(range(len(records)))


def test_journal_torn_tail_tolerated_corruption_not(tmp_path):
    d = str(tmp_path / "j")
    with Journal(d, spec=live_spec(), segment_records=4) as j:
        for i in range(6):         # two segments
            j.append("SUBMIT", offset=float(i), request_id=f"r{i}")
    segs = sorted(p for p in os.listdir(d) if p.startswith("wal-"))
    # a torn final line on the *last* segment is a crash artifact: ignored
    with open(os.path.join(d, segs[-1]), "a") as f:
        f.write('{"kind": "RETIRE", "request_id": "r5", "of')
    _, records = scan_journal(d)
    assert len(records) == 6
    # reopen after the torn tail keeps appending (the partial line is
    # not a record; its rid stays un-deduped)
    with Journal(d) as j2:
        assert j2.append("RETIRE", offset=9.0, request_id="r5",
                         outcome={"depth": 1}) is not None
    # the same damage mid-journal is corruption, not a crash artifact
    with open(os.path.join(d, segs[0]), "a") as f:
        f.write('{"broken')
    with pytest.raises(ValueError, match="corrupt journal line"):
        scan_journal(d)


def test_journal_lag_and_sync(tmp_path):
    d = str(tmp_path / "j")
    j = Journal(d, spec=live_spec(), fsync_every=100)
    for i in range(5):
        j.append("SUBMIT", offset=float(i), request_id=f"r{i}")
    assert j.lag() == 5
    j.append("RETIRE", offset=9.0, request_id="r0", outcome={}, sync=True)
    assert j.lag() == 0            # sync=True flushes the whole batch
    j.close()


def test_scan_journal_missing_dir():
    with pytest.raises(FileNotFoundError):
        scan_journal("/nonexistent/journal/dir")


# ---------------------------------------------------------------------------
# durable queue: idempotent submission
# ---------------------------------------------------------------------------

def test_durable_queue_idempotent_submission(tmp_path):
    conf, correct = oracle_tables()
    spec = live_spec()
    with Journal(str(tmp_path / "j"), spec=spec, fsync_every=1) as j:
        svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
        q = DurableQueue(svc, j)
        h1 = q.submit(Request(None, sample=1, request_id="a"), at=0.0)
        h2 = q.submit(Request(None, sample=1, request_id="a"), at=0.5)
        assert h2 is h1                       # same handle object
        assert j.counts["SUBMIT"] == 1        # single journal entry
        with pytest.raises(ValueError, match="request_id"):
            q.submit(Request(None, sample=2))
        met = svc.drain()
    assert met.n_requests == 1
    assert met.per_request[0]["request_id"] == "a"


def test_durable_queue_replayed_duplicate_noops(tmp_path):
    """A duplicate submitted against a *reopened* journal (fresh queue,
    no in-memory handle) must not create a second SUBMIT record."""
    conf, correct = oracle_tables()
    spec = live_spec()
    d = str(tmp_path / "j")
    with Journal(d, spec=spec, fsync_every=1) as j:
        svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
        DurableQueue(svc, j).submit(Request(None, sample=1, request_id="a"),
                                    at=0.0)
        svc.drain()
    with Journal(d) as j2:
        assert j2.append("SUBMIT", offset=0.0, request_id="a") is None
        assert j2.counts["SUBMIT"] == 1


# ---------------------------------------------------------------------------
# crash recovery: bit-for-bit redo under the virtual clock
# ---------------------------------------------------------------------------

def _durable_run(journal_dir, spec, conf, correct, n=12):
    with Journal(journal_dir, spec=spec, fsync_every=1) as j:
        svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
        q = DurableQueue(svc, j)
        for i in range(n):
            q.submit(Request(None, sample=i % conf.shape[0],
                             request_id=f"r{i:03d}"), at=i * 0.006)
        return svc.drain()


def test_recovery_reproduces_uncrashed_run_bitwise(tmp_path):
    conf, correct = oracle_tables()
    spec = live_spec()
    ref = _durable_run(str(tmp_path / "ref"), spec, conf, correct)
    crash = str(tmp_path / "crash")
    _durable_run(crash, spec, conf, correct)
    truncate_after_retires(crash, keep=4)     # die after the 4th terminal

    res = recover(crash, conf_table=conf, correct_table=correct)
    rep = verify_recovery(ref.per_request, res)
    assert rep["recovered"] and rep["bitwise"] and rep["overlap_consistent"]
    assert len(res.already_delivered) == 4
    assert len(res.responses) == 12 - 4
    assert res.delivered_once
    assert set(res.responses).isdisjoint(res.already_delivered)
    # the redo completed the journal: a second recovery redelivers nothing
    res2 = recover(crash, conf_table=conf, correct_table=correct)
    assert res2.report["n_redelivered"] == 0
    assert verify_recovery(ref.per_request, res2)["recovered"]


def test_recovery_spec_from_header_and_override(tmp_path):
    conf, correct = oracle_tables()
    spec = live_spec()
    d = str(tmp_path / "j")
    _durable_run(d, spec, conf, correct, n=4)
    truncate_after_retires(d, keep=0)
    res = recover(d, conf_table=conf, correct_table=correct)
    assert res.metrics.components["policy"] == spec.policy
    assert res.report["n_redelivered"] == 4
    # a spec-less journal demands an explicit spec
    d2 = str(tmp_path / "nospec")
    with Journal(d2, spec=None) as j:
        j.append("SUBMIT", offset=0.0, sample=0, request_id="x",
                 rel_deadline=0.2)
    with pytest.raises(ValueError, match="no spec"):
        recover(d2, conf_table=conf, correct_table=correct)
    res2 = recover(d2, spec=spec, conf_table=conf, correct_table=correct)
    assert res2.report["n_redelivered"] == 1


def test_recovery_through_frontdoor_keeps_discipline(tmp_path):
    """A frontdoor journal recovers through the same DRR arbitration the
    original run used, not a plain stream."""
    conf, correct = oracle_tables()
    spec = live_spec(
        source="frontdoor",
        source_args={"discipline": "drr", "run_queue": 2},
        tenants={"gold": {"weight": 5.0}, "free": {"weight": 1.0}})
    ref_dir, crash = str(tmp_path / "ref"), str(tmp_path / "crash")

    def run(d):
        with Journal(d, spec=spec, fsync_every=1) as j:
            svc = Service.from_spec(spec, conf_table=conf,
                                    correct_table=correct)
            door = FrontDoor(svc, journal=j)
            for i in range(14):
                door.submit(Request(None, sample=i),
                            tenant="gold" if i % 2 else "free",
                            request_id=f"r{i:03d}", at=i * 0.004)
            return svc.drain()

    ref = run(ref_dir)
    run(crash)
    truncate_after_retires(crash, keep=3)
    res = recover(crash, conf_table=conf, correct_table=correct)
    rep = verify_recovery(ref.per_request, res)
    assert rep["recovered"] and rep["overlap_consistent"], rep
    assert res.metrics.per_tenant.keys() == {"gold", "free"}


@pytest.mark.slow
@pytest.mark.wallclock
def test_crash_recovery_kill9_subprocess(tmp_path):
    """Real crash: a wall-clock live run is SIGKILLed mid-stream; the
    journal alone must recover the rest — every request delivered exactly
    once, no duplicate journal entries, redo bitwise-equal to an
    uncrashed virtual run over the same journaled arrivals."""
    d = str(tmp_path / "j")
    script = textwrap.dedent(f"""
        import os, signal, time
        import numpy as np
        from repro.serving import DurableQueue, Journal, ServeSpec, Service
        from repro.serving.engine import Request

        rng = np.random.default_rng(0)
        conf = np.sort(rng.uniform(0.3, 1.0, (120, 3)), axis=1)
        correct = rng.uniform(size=(120, 3)) < conf
        spec = ServeSpec(
            policy="edf", executor="oracle", clock="wall", source="live",
            default_slo="gold", slo_classes={{"gold": {{"rel_deadline": 2.0}}}},
            batching={{"mode": "none", "stage_times": [0.004, 0.007, 0.01]}})
        j = Journal({d!r}, spec=spec, fsync_every=1)
        svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
        q = DurableQueue(svc, j)
        for i in range(40):
            q.submit(Request(None, sample=i % 120, request_id=f"r{{i:03d}}"))
            time.sleep(0.004)
        deadline = time.monotonic() + 15.0
        while j.counts.get("RETIRE", 0) < 5 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert j.counts.get("RETIRE", 0) >= 5, j.counts
        os.kill(os.getpid(), signal.SIGKILL)   # no drain, no close, no flush
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=420,
                          env=env)
    assert proc.returncode == -signal.SIGKILL, proc.stderr

    header, records = scan_journal(d)
    submits = [r for r in records if r.kind == "SUBMIT"]
    assert len(submits) == 40
    pre = {r.request_id for r in records if r.kind in ("RETIRE", "REJECT")}
    assert 5 <= len(pre) < 40      # genuinely mid-stream

    conf, correct = oracle_tables()
    res = recover(d, conf_table=conf, correct_table=correct)
    # exactly-once across the crash: pre-crash terminals plus the redo's
    # deliveries partition the submitted set
    assert res.delivered_once
    assert set(res.responses) | set(res.already_delivered) \
        == {f"r{i:03d}" for i in range(40)}
    # no duplicate terminal entries in the (now-complete) journal
    _, after = scan_journal(d)
    term = [(r.kind, r.request_id) for r in after
            if r.kind in ("RETIRE", "REJECT")]
    assert len(term) == len(set(term)) == 40
    # an uncrashed virtual run over the same journaled arrivals is the
    # ground truth the redo must match bit-for-bit
    import dataclasses
    spec = ServeSpec.from_dict(header["spec"])
    spec = dataclasses.replace(spec, clock="virtual", clock_args={},
                               source="durable",
                               source_args={"path": d})
    ref = Service.from_spec(spec, conf_table=conf,
                            correct_table=correct).run()
    assert verify_recovery(ref.per_request, res)["recovered"]
    assert journal_stats(d)["queue_depth"] == 0


# ---------------------------------------------------------------------------
# front door: quotas, DRR fairness, weight composition
# ---------------------------------------------------------------------------

def test_token_bucket_deterministic_refill():
    b = TokenBucket(rate=10.0, burst=2)
    assert b.allow(0.0) and b.allow(0.0)
    assert not b.allow(0.0)        # burst exhausted at t=0
    assert b.allow(0.1)            # one token back after 0.1s
    assert not b.allow(0.1)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0)


def test_frontdoor_drr_release_order_weighted():
    class _Clock:
        realtime = False

    src = FrontDoorSource(lambda req, now: req, _Clock(),
                          tenants={"big": {"weight": 3.0},
                                   "small": {"weight": 1.0}},
                          discipline="drr")
    for i in range(12):
        src.push(0.0, Request(None, sample=i, tenant="big"))
    for i in range(8):
        src.push(0.0, Request(None, sample=100 + i, tenant="small"))
    order = []
    while src.qsize():
        order.append(src.pop(0.0).tenant)
    # while both backlogged, releases approach the 3:1 weight ratio
    head = order[:12]
    assert head.count("big") == 9 and head.count("small") == 3
    assert sorted(src.tenant_depths().items()) == []


def test_frontdoor_quota_rejects_fail_fast(tmp_path):
    conf, correct = oracle_tables()
    spec = live_spec(source="frontdoor", source_args={},
                     tenants={"a": {"weight": 1.0, "rate": 10.0,
                                    "burst": 2}})
    with Journal(str(tmp_path / "j"), spec=spec, fsync_every=1) as j:
        svc = Service.from_spec(spec, conf_table=conf,
                                correct_table=correct)
        door = FrontDoor(svc, journal=j)
        hs = [door.submit(Request(None, sample=i), tenant="a", at=0.0,
                          request_id=f"r{i}") for i in range(5)]
        # burst=2 at t=0: three quota rejects, resolved without running
        rejected = [h for h in hs if h.done() and h.result().rejected]
        assert len(rejected) == 3
        assert j.counts.get("REJECT", 0) == 3
        assert j.counts["SUBMIT"] == 2        # rejects are never SUBMITs
        met = svc.drain()
    assert met.per_tenant["a"]["rejected"] == 3
    assert met.per_tenant["a"]["served"] == 2
    assert door.counts["a"] == {"submitted": 5, "quota_rejected": 3}
    assert journal_stats(str(tmp_path / "j"))["queue_depth"] == 0


def test_drr_protects_light_tenant_fifo_starves_it():
    """The fairness claim in miniature: under ~2x overload with the
    light (low-rate, high-weight) tenant at its fair share, DRR serves
    it nearly fully while global-FIFO release order starves it."""
    conf, correct = oracle_tables(n=400)

    def run(discipline):
        spec = live_spec(
            source="frontdoor",
            source_args={"discipline": discipline, "run_queue": 2},
            tenants={"light": {"weight": 10.0}, "heavy": {"weight": 1.0}},
            admission={"mode": "reject", "headroom": 5.0},
            slo_classes={"gold": {"rel_deadline": 0.08}})
        svc = Service.from_spec(spec, conf_table=conf,
                                correct_table=correct)
        for i in range(190):
            svc.submit(Request(None, sample=i % 400, tenant="heavy",
                               request_id=f"h{i}"), at=i * (2.0 / 190))
        for i in range(8):
            svc.submit(Request(None, sample=(200 + i) % 400, tenant="light",
                               request_id=f"l{i}"), at=i * 0.25)
        met = svc.drain()
        return met.per_tenant["light"]["served"] / 8, met.admitted_miss_rate

    drr_frac, drr_miss = run("drr")
    fifo_frac, fifo_miss = run("fifo")
    assert drr_frac >= 0.9, (drr_frac, fifo_frac)
    assert fifo_frac <= 0.6, (drr_frac, fifo_frac)
    assert drr_miss <= 0.01 and fifo_miss <= 0.01


def test_tenant_weight_composes_with_slo_weight():
    conf, correct = oracle_tables()
    spec = live_spec(
        source="frontdoor", source_args={},
        tenants={"vip": {"weight": 4.0}, "std": {"weight": 1.0}},
        slo_classes={"gold": {"rel_deadline": 0.2, "utility_weight": 3.0}})
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    svc.submit(Request(None, sample=0, tenant="vip", request_id="a"),
               at=0.0)
    svc.submit(Request(None, sample=1, tenant="std", request_id="b"),
               at=0.0)
    met = svc.drain()
    w = {r["tenant"]: r["weight"] for r in met.per_request}
    assert w == {"vip": 12.0, "std": 3.0}     # slo 3.0 x tenant {4, 1}


def test_frontdoor_validation():
    with pytest.raises(ValueError, match="weight must be > 0"):
        live_spec(tenants={"a": {"weight": 0.0}}).validate()
    with pytest.raises(ValueError, match="discipline"):
        live_spec(source="frontdoor",
                  source_args={"discipline": "lifo"}).validate()
    with pytest.raises(ValueError, match="run_queue"):
        live_spec(source="frontdoor",
                  source_args={"run_queue": 0}).validate()
    with pytest.raises(ValueError, match="spec.source"):
        conf, correct = oracle_tables()
        FrontDoor(Service.from_spec(live_spec(), conf_table=conf,
                                    correct_table=correct))


# ---------------------------------------------------------------------------
# drain()/close() robustness
# ---------------------------------------------------------------------------

class _BoomExecutor:
    """Delegating wrapper whose submit always raises — the regression
    target: a raising executor must not wedge close()."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def submit(self, stage, tasks, now):
        raise RuntimeError("boom")


@pytest.mark.wallclock
def test_close_survives_raising_executor_wall_clock():
    from repro.serving.batch import BatchTimeModel
    conf, correct = oracle_tables()
    tm = BatchTimeModel.linear(STAGE_TIMES, (1,))
    spec = live_spec(clock="wall",
                     slo_classes={"gold": {"rel_deadline": 0.5}})
    svc = Service.from_spec(
        spec, executor=_BoomExecutor(OracleExecutor(tm, conf)),
        time_model=tm, conf_table=conf, correct_table=correct)
    h = svc.submit(Request(None, sample=0))
    with pytest.raises(RuntimeError):
        h.result(timeout=10.0)     # handle resolved with the error
    svc.close()                    # swallows the engine error, returns
    assert svc._closed and svc._live is None
    svc.close()                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(Request(None, sample=1))


def test_drain_raises_once_then_recovers_virtual():
    from repro.serving.batch import BatchTimeModel
    conf, correct = oracle_tables()
    tm = BatchTimeModel.linear(STAGE_TIMES, (1,))
    svc = Service.from_spec(
        live_spec(), executor=_BoomExecutor(OracleExecutor(tm, conf)),
        time_model=tm, conf_table=conf, correct_table=correct)
    h = svc.submit(Request(None, sample=0), at=0.0)
    with pytest.raises(RuntimeError, match="boom"):
        svc.drain()                # buffered virtual drain surfaces it
    with pytest.raises(RuntimeError):
        h.result(timeout=0.1)      # ... after failing the handle
    svc.drain()                    # idempotent: no buffered work left
    svc.close()


def test_drain_idempotent_after_success():
    conf, correct = oracle_tables()
    svc = Service.from_spec(live_spec(), conf_table=conf,
                            correct_table=correct)
    svc.submit(Request(None, sample=0), at=0.0)
    met = svc.drain()
    assert svc.drain() is met      # second drain: same metrics, no rerun
    svc.close()


# ---------------------------------------------------------------------------
# snapshots: uniform intake depth + per-tenant breakdown
# ---------------------------------------------------------------------------

def test_snapshot_intake_depth_and_per_tenant():
    conf, correct = oracle_tables()
    spec = live_spec(
        source="frontdoor",
        source_args={"discipline": "drr", "run_queue": 1},
        tenants={"a": {"weight": 2.0}, "b": {"weight": 1.0}},
        metrics_interval=0.02)
    svc = Service.from_spec(spec, conf_table=conf, correct_table=correct)
    for i in range(16):
        svc.submit(Request(None, sample=i, tenant="a" if i % 2 else "b",
                           request_id=f"r{i}"), at=i * 0.001)
    met = svc.drain()
    snaps = svc.snapshots
    assert snaps, "windowed metrics must have streamed"
    assert sum(s.n for s in snaps) == met.n_requests
    assert all(s.intake_depth >= s.queue_depth for s in snaps)
    # run_queue=1 with a burst of 16: early windows must see a backlog
    assert max(s.intake_depth for s in snaps) > 0
    seen = set()
    for s in snaps:
        seen.update(s.per_tenant)
        for t, row in s.per_tenant.items():
            assert set(row) == {"queued", "n"}
    assert seen == {"a", "b"}
    d = snaps[0].to_dict()
    assert "intake_depth" in d and "per_tenant" in d


# ---------------------------------------------------------------------------
# trace schema unification (v1 read path)
# ---------------------------------------------------------------------------

def test_load_trace_reads_version1_files(tmp_path):
    p = tmp_path / "v1.jsonl"
    lines = [json.dumps({"type": "header", "version": 1, "n_events": 2,
                         "source": "test"})]
    for i in range(2):
        lines.append(json.dumps({
            "offset": i * 0.1, "sample": i, "client": 0, "slo": "gold",
            "rel_deadline": 0.2,
            "outcome": {"depth": 1, "missed": False, "rejected": False}}))
    p.write_text("\n".join(lines) + "\n")
    header, events = load_trace(str(p))
    assert header["version"] == 1
    assert [e.kind for e in events] == ["EVENT", "EVENT"]
    assert events[1].request().sample == 1
    # a future version refuses loudly
    p2 = tmp_path / "v99.jsonl"
    p2.write_text(json.dumps({"type": "header", "version": 99,
                              "n_events": 0}) + "\n")
    with pytest.raises(ValueError, match="version 99"):
        load_trace(str(p2))


def test_checked_in_mini_trace_still_old_format():
    """The checked-in regression trace stays on the version-1 format and
    the old read path keeps replaying it (examples/traffic_replay.py
    --trace covers the bit-for-bit outcome check)."""
    path = os.path.join(REPO, "examples", "data", "mini_trace.jsonl")
    header, events = load_trace(path)
    assert header["version"] == 1
    assert len(events) == header["n_events"] > 0
    assert all(e.kind == "EVENT" for e in events)
    with open(path) as f:
        for line in f:
            d = json.loads(line)
            assert "kind" not in d and "tenant" not in d


# ---------------------------------------------------------------------------
# planectl CLI
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_planectl_cli(tmp_path):
    conf, correct = oracle_tables()
    d = str(tmp_path / "j")
    _durable_run(d, live_spec(), conf, correct, n=6)
    truncate_after_retires(d, keep=2)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    tool = os.path.join(REPO, "tools", "planectl.py")

    out = subprocess.run([sys.executable, tool, "stats", d, "--json"],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert out.returncode == 0, out.stderr
    st = json.loads(out.stdout)
    assert st["queue_depth"] == 4 and st["counts"]["SUBMIT"] == 6

    out = subprocess.run([sys.executable, tool, "pending", d],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert out.returncode == 1     # pending work -> exit 1
    assert len(out.stdout.split()) == 4

    out = subprocess.run([sys.executable, tool, "tail", d, "-n", "3"],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert out.returncode == 0
    assert len(out.stdout.strip().splitlines()) == 3

    # recovery drains it: stats agree, pending exits 0
    recover(d, conf_table=conf, correct_table=correct)
    out = subprocess.run([sys.executable, tool, "pending", d],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert out.returncode == 0 and not out.stdout.strip()
