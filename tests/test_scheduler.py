"""Core scheduler tests: Algorithm 1 FPTAS, Eq. 7 greedy, EDF dispatch,
utility predictors — including hypothesis property tests against the
exhaustive optimum (Theorem 1's (1-ε) bound)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EDF, LCF, RR, DepthPlanner, RTDeepIoT, Task,
                        Workload, brute_force_plan, greedy_update,
                        make_predictor, simulate)
from repro.core.utility import ExpIncrease, LinIncrease, MaxIncrease

PRIOR = [0.5, 0.75, 0.875]


def mk_task(deadline, times, executed=0, confs=(), mandatory=1, sample=0):
    t = Task(arrival=0.0, deadline=deadline, stage_times=tuple(times),
             mandatory=mandatory, sample=sample)
    t.executed = executed
    t.confidences = list(confs)
    return t


# ---------------------------------------------------------------------------
# utility predictors
# ---------------------------------------------------------------------------

def test_exp_predictor_halves_distance():
    p = ExpIncrease(PRIOR)
    t = mk_task(1.0, [0.1] * 4, executed=2, confs=[0.4, 0.6])
    assert p.predict(t, 2) == pytest.approx(0.6)
    assert p.predict(t, 3) == pytest.approx(0.8)      # 0.6 + 0.5*0.4
    assert p.predict(t, 4) == pytest.approx(0.9)


def test_max_predictor_jumps_to_one():
    p = MaxIncrease(PRIOR)
    t = mk_task(1.0, [0.1] * 3, executed=1, confs=[0.3])
    assert p.predict(t, 2) == 1.0
    assert p.predict(t, 3) == 1.0
    assert p.predict(t, 1) == pytest.approx(0.3)


def test_lin_predictor_time_proportional():
    p = LinIncrease(PRIOR)
    t = mk_task(1.0, [0.1, 0.1, 0.2], executed=1, confs=[0.4])
    assert p.predict(t, 2) == pytest.approx(0.8)      # 0.4 * 0.2/0.1
    assert p.predict(t, 3) == pytest.approx(1.0)      # capped


def test_predictor_curves_monotone():
    for name in ("exp", "max", "lin"):
        p = make_predictor(name, prior_curve=PRIOR)
        t = mk_task(1.0, [0.1] * 3, executed=1, confs=[0.5])
        c = p.curve(t)
        assert all(c[i] <= c[i + 1] + 1e-9 for i in range(len(c) - 1))


# ---------------------------------------------------------------------------
# Algorithm 1 (DP / FPTAS)
# ---------------------------------------------------------------------------

def test_dp_single_task_runs_to_max_reward():
    p = make_predictor("exp", prior_curve=PRIOR)
    t = mk_task(deadline=1.0, times=[0.1, 0.1, 0.1])
    plan = DepthPlanner(delta=0.01).plan([t], 0.0, p)
    assert plan[t.tid] == 3


def test_dp_respects_deadline():
    p = make_predictor("exp", prior_curve=PRIOR)
    t = mk_task(deadline=0.15, times=[0.1, 0.1, 0.1])
    plan = DepthPlanner(delta=0.01).plan([t], 0.0, p)
    assert plan[t.tid] == 1


def test_dp_infeasible_task_dropped():
    p = make_predictor("exp", prior_curve=PRIOR)
    t = mk_task(deadline=0.05, times=[0.1, 0.1, 0.1])
    plan = DepthPlanner(delta=0.01).plan([t], 0.0, p)
    assert plan[t.tid] == 0


def test_dp_prefers_high_value_under_contention():
    """Two tasks, time for only one to go deep: the one with more headroom
    (lower current confidence under Exp) gets the stages."""
    p = make_predictor("exp", prior_curve=PRIOR)
    # time for exactly ONE extra stage across both tasks (EDF: a before b)
    a = mk_task(0.16, [0.15, 0.15, 0.15], executed=1, confs=[0.95], sample=0)
    b = mk_task(0.16, [0.15, 0.15, 0.15], executed=1, confs=[0.30], sample=1)
    plan = DepthPlanner(delta=0.01).plan([a, b], 0.0, p)
    # b's next stage is worth +0.35; a's only +0.025
    assert plan[b.tid] == 2
    assert plan[a.tid] == 1


def test_dp_edf_prefix_feasibility():
    """Chosen depths must be schedulable as EDF prefixes."""
    p = make_predictor("exp", prior_curve=PRIOR)
    rng = np.random.default_rng(42)
    tasks = [mk_task(float(rng.uniform(0.05, 0.5)),
                     rng.uniform(0.01, 0.08, 3), sample=i)
             for i in range(8)]
    plan = DepthPlanner(delta=0.05).plan(tasks, 0.0, p)
    cum = 0.0
    for t in sorted(tasks, key=lambda t: t.deadline):
        d = plan[t.tid]
        if d > 0:
            cum += t.cum_time(d)
            assert cum <= t.deadline + 1e-9


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_fptas_bound_property(data):
    """Theorem 1: with Δ = εR/N the DP achieves >= (1-ε) of the exhaustive
    optimum (exact rewards, random instances, random partial execution)."""
    n = data.draw(st.integers(1, 4))
    eps = data.draw(st.sampled_from([0.05, 0.1, 0.25]))
    rng_seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(rng_seed)
    p = make_predictor("exp", prior_curve=PRIOR)
    tasks = []
    for i in range(n):
        L = int(rng.integers(1, 4))
        t = mk_task(float(rng.uniform(0.02, 0.6)),
                    rng.uniform(0.01, 0.1, L), sample=i)
        if rng.uniform() < 0.4 and L >= 1:
            t.executed = 1
            t.confidences = [float(rng.uniform(0.2, 0.9))]
        tasks.append(t)
    delta = eps * 1.0 / n
    plan = DepthPlanner(delta=delta).plan(tasks, 0.0, p)
    reward = sum(p.predict(t, plan[t.tid]) for t in tasks if plan[t.tid] > 0)
    opt, _ = brute_force_plan(tasks, 0.0, p)
    assert reward >= (1 - eps) * opt - 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6))
def test_dp_incremental_matches_fresh(seed):
    """Incremental row reuse (Algorithm 1's from-k update) must equal a
    from-scratch plan."""
    rng = np.random.default_rng(seed)
    p = make_predictor("exp", prior_curve=PRIOR)
    planner = DepthPlanner(delta=0.1)
    tasks = []
    for i in range(6):
        tasks.append(mk_task(float(rng.uniform(0.05, 0.5)),
                             rng.uniform(0.01, 0.08, 3), sample=i))
        inc = planner.plan(tasks, 0.0, p)
        fresh = DepthPlanner(delta=0.1).plan(tasks, 0.0, p)
        assert inc == fresh


# ---------------------------------------------------------------------------
# greedy reassignment (Eq. 7)
# ---------------------------------------------------------------------------

def test_greedy_swaps_when_other_task_gains_more():
    p = make_predictor("exp", prior_curve=PRIOR)
    cur = mk_task(0.2, [0.05] * 3, executed=1, confs=[0.96])
    cur.assigned_depth = 3                          # 2 stages remaining = 0.1
    other = mk_task(0.4, [0.05] * 3, executed=1, confs=[0.3])
    other.assigned_depth = 1
    assert greedy_update(cur, [other], p)
    assert cur.assigned_depth == 1                  # stopped early
    assert other.assigned_depth >= 2                # got the budget


def test_greedy_keeps_plan_when_current_best():
    p = make_predictor("exp", prior_curve=PRIOR)
    cur = mk_task(0.2, [0.05] * 3, executed=1, confs=[0.3])
    cur.assigned_depth = 3
    other = mk_task(0.4, [0.05] * 3, executed=1, confs=[0.96])
    other.assigned_depth = 1
    assert not greedy_update(cur, [other], p)
    assert cur.assigned_depth == 3


def test_greedy_budget_constraint():
    """Swap target must fit within the freed budget (Eq. 7 s.t. clause)."""
    p = make_predictor("exp", prior_curve=PRIOR)
    cur = mk_task(0.2, [0.01, 0.01, 0.01], executed=1, confs=[0.9])
    cur.assigned_depth = 2                          # budget = 0.01
    other = mk_task(0.4, [0.01, 0.5, 0.5], executed=1, confs=[0.1])
    other.assigned_depth = 1                        # next stage costs 0.5
    assert not greedy_update(cur, [other], p)


# ---------------------------------------------------------------------------
# policies + simulator
# ---------------------------------------------------------------------------

def _oracle(n_samples=150, L=3, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.uniform(0.3, 0.9, (n_samples, 1))
    conf = np.clip(base + rng.uniform(0.02, 0.3, (n_samples, L)).cumsum(1),
                   0, 1)
    correct = rng.uniform(size=(n_samples, L)) < conf
    return conf, correct


def test_simulator_no_load_no_misses():
    """With generous deadlines everything completes at full depth."""
    conf, correct = _oracle()
    wl = Workload(n_clients=2, d_lo=1.0, d_hi=2.0, n_requests=40)
    res = simulate(EDF(), wl, [0.01] * 3, conf, correct)
    assert res.miss_rate == 0.0
    assert res.mean_depth == pytest.approx(3.0)


def test_rtdeepiot_beats_edf_under_overload():
    conf, correct = _oracle()
    wl = Workload(n_clients=12, d_lo=0.02, d_hi=0.15, n_requests=400)
    times = [0.02] * 3
    r_rt = simulate(RTDeepIoT(make_predictor("exp", prior_curve=conf.mean(0))),
                    wl, times, conf, correct)
    r_edf = simulate(EDF(), wl, times, conf, correct)
    assert r_rt.accuracy > r_edf.accuracy
    assert r_rt.miss_rate < r_edf.miss_rate


def test_oracle_upper_bounds_heuristics():
    conf, correct = _oracle(seed=3)
    wl = Workload(n_clients=10, d_lo=0.02, d_hi=0.2, n_requests=400, seed=1)
    times = [0.02] * 3
    accs = {}
    for name in ("exp", "max", "lin", "oracle"):
        pred = make_predictor(name, prior_curve=conf.mean(0),
                              oracle_table=conf if name == "oracle" else None)
        accs[name] = simulate(RTDeepIoT(pred), wl, times, conf,
                              correct).accuracy
    assert accs["oracle"] >= max(accs["exp"], accs["lin"]) - 0.03


def test_policies_never_run_past_deadline_start():
    """No stage is *dispatched* for a task whose deadline has passed."""
    conf, correct = _oracle()
    wl = Workload(n_clients=8, d_lo=0.01, d_hi=0.1, n_requests=200)
    for pol in (EDF(), LCF(), RR(),
                RTDeepIoT(make_predictor("exp", prior_curve=conf.mean(0)))):
        res = simulate(pol, wl, [0.02] * 3, conf, correct)
        for f in res.per_request:
            assert f["depth"] <= 3


def test_stage_counts_monotone_with_load():
    """More clients -> lower mean depth under RTDeepIoT (shedding kicks in)."""
    conf, correct = _oracle()
    times = [0.02] * 3
    depths = []
    for k in (2, 20):
        wl = Workload(n_clients=k, d_lo=0.02, d_hi=0.2, n_requests=300)
        pred = make_predictor("exp", prior_curve=conf.mean(0))
        depths.append(simulate(RTDeepIoT(pred), wl, times, conf,
                               correct).mean_depth)
    assert depths[1] <= depths[0] + 1e-9


# ---------------------------------------------------------------------------
# weighted accuracy (paper §II-A: "trivial to extend to weighted accuracy")
# ---------------------------------------------------------------------------

def test_weighted_task_wins_contention():
    """Under contention, a 3x-important task gets the depth budget."""
    p = make_predictor("exp", prior_curve=PRIOR)
    a = mk_task(0.16, [0.15] * 3, executed=1, confs=[0.5], sample=0)
    b = mk_task(0.16, [0.15] * 3, executed=1, confs=[0.5], sample=1)
    b.weight = 3.0
    plan = DepthPlanner(delta=0.01).plan([a, b], 0.0, p)
    assert plan[b.tid] == 2 and plan[a.tid] == 1


def test_weighted_fptas_bound_vs_bruteforce():
    """FPTAS bound still holds with weights (brute force sees them via the
    predictor curve x weight in the DP options)."""
    import numpy as np
    rng = np.random.default_rng(5)
    p = make_predictor("exp", prior_curve=PRIOR)
    tasks = []
    for i in range(4):
        t = mk_task(float(rng.uniform(0.05, 0.4)),
                    rng.uniform(0.01, 0.08, 3), sample=i)
        t.weight = float(rng.choice([1.0, 2.0]))
        tasks.append(t)
    plan = DepthPlanner(delta=0.02).plan(tasks, 0.0, p)
    reward = sum(t.weight * p.predict(t, plan[t.tid])
                 for t in tasks if plan[t.tid] > 0)
    # exhaustive search with weights
    import itertools
    best = 0.0
    choice_sets = []
    for t in tasks:
        opts = [(0, 0.0, 0.0)]
        for l in range(1, 4):
            opts.append((l, t.cum_time(l), t.weight * p.predict(t, l)))
        choice_sets.append(opts)
    for combo in itertools.product(*choice_sets):
        cum, rew, ok = 0.0, 0.0, True
        for t, (d, c, r) in zip(sorted(tasks, key=lambda t: t.deadline),
                                [combo[sorted(tasks, key=lambda t: t.deadline).index(t)] for t in sorted(tasks, key=lambda t: t.deadline)]):
            if d > 0:
                cum += c
                if cum > t.deadline:
                    ok = False
                    break
            rew += r if d > 0 else 0.0
        if ok:
            best = max(best, rew)
    assert reward >= (1 - 0.15) * best - 1e-9


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

def test_simulator_work_conserving_and_causal():
    """No request finishes before its arrival; every returned depth is
    consistent with the virtual time available."""
    conf, correct = _oracle(seed=11)
    wl = Workload(n_clients=10, d_lo=0.02, d_hi=0.2, n_requests=300, seed=2)
    pred = make_predictor("exp", prior_curve=conf.mean(0))
    res = simulate(RTDeepIoT(pred), wl, [0.01, 0.02, 0.03], conf, correct)
    for f in res.per_request:
        assert f["deadline"] > f["arrival"]
        # a request can never execute more stages than fit in its window
        max_possible = 0
        t = 0.0
        for st in (0.01, 0.02, 0.03):
            t += st
            if t <= (f["deadline"] - f["arrival"]) + 1e-9:
                max_possible += 1
        assert f["depth"] <= 3
    assert res.n_requests == 300
