"""Tests for the multi-model serving zoo (repro.serving.zoo).

Contracts held here:

* ``validate_models`` rejects malformed ``ServeSpec.models`` at spec
  time (unknown keys, non-positive costs, mismatched bucket sets, ...).
* The blended ``ZooTimeModel`` is the per-(bucket, stage) worst case
  over the member tables, ``for_model`` resolves the exact table, and a
  single-member blend *is* the member — the parity guarantee.
* The ``StageBatcher`` seats same-model co-runners only and prices the
  batch with the leader's model table, not the blend.
* ``ZooAdmissionController`` prices each request by its own model, so a
  cheap model is admitted where the blended worst case would reject it.
* ``rtdeepiot-zoo``: ``scope`` is validated, ``"siloed"`` plans each
  model partition with its own ``DepthPlanner``, and end to end under
  the ``model-mix`` overload scenario global cross-model shedding is at
  least as good as siloed planning on weighted admitted accuracy.
* A single-member zoo spec reproduces the plain oracle path bit for bit.
* ``Service.submit`` fails fast on a model id the zoo does not define.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import Task, make_predictor
from repro.serving import (ModelZoo, ServeSpec, Service,
                           ZooAdmissionController, ZooRTDeepIoT)
from repro.serving.batch import AdmissionController
from repro.serving.batch.batcher import StageBatcher
from repro.serving.engine import Request
from repro.serving.traffic import scenario_spec
from repro.serving.zoo import validate_models

LLM_TIMES = (0.006, 0.010, 0.014)
VISION_TIMES = (0.003, 0.005, 0.007)
ZOO = {
    "llm": {"stage_times": list(LLM_TIMES), "weight": 2.0},
    "vision": {"stage_times": list(VISION_TIMES)},
}
#: the model-mix scenario's capacity anchor (0.4 llm / 0.6 vision)
MIX_STAGE_TIMES = tuple(0.4 * a + 0.6 * b
                        for a, b in zip(LLM_TIMES, VISION_TIMES))
PRIOR = [0.5, 0.7, 0.85]


def mk_task(deadline, times, model=None, mandatory=1, now=0.0):
    t = Task(arrival=now, deadline=deadline, stage_times=tuple(times),
             mandatory=mandatory, model=model)
    t.assigned_depth = t.num_stages
    return t


def zoo_tables(models=("llm", "vision"), n=240, L=3, seed=0):
    out = {}
    for i, model in enumerate(sorted(models)):
        rng = np.random.default_rng(seed + i)
        conf = np.sort(rng.uniform(0.3, 1.0, (n, L)), axis=1)
        out[model] = {"conf": conf,
                      "correct": rng.uniform(size=(n, L)) < conf}
    return out


# ---------------------------------------------------------------------------
# spec-time validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("models,match", [
    ({"a": [0.01]}, "must be a dict"),
    ({"a": {"stage_times": [0.01], "wieght": 2.0}}, "unknown keys"),
    ({"a": {"weight": 1.0}}, "stage_times"),
    ({"a": {"stage_times": []}}, "positive"),
    ({"a": {"stage_times": [0.01, 0.0]}}, "positive"),
    ({"a": {"stage_times": [0.01], "buckets": [2, 1]}}, "ascending"),
    ({"a": {"times": [[0.01]], "buckets": [1, 2]}}, "row per bucket"),
    ({"a": {"stage_times": [0.01], "buckets": [1, 2]},
      "b": {"stage_times": [0.01], "buckets": [1, 4]}},
     "differ from the zoo's"),
    ({"a": {"stage_times": [0.01, 0.02], "mandatory": 3}}, "exceeds"),
    ({"a": {"stage_times": [0.01], "mandatory": 0}}, "integer >= 1"),
    ({"a": {"stage_times": [0.01], "weight": 0.0}}, "weight must be > 0"),
    ({"a": {"stage_times": [0.01], "utility": [1.5]}}, r"\[0, 1\]"),
    ({"a": {"stage_times": [0.01], "len_buckets": [16],
            "len_marginal": 2.0}}, "len_marginal"),
])
def test_validate_models_rejects_malformed(models, match):
    with pytest.raises(ValueError, match=match):
        validate_models(models)


def test_validate_models_accepts_the_reference_zoo():
    validate_models(ZOO)                       # no raise
    with pytest.raises(ValueError, match="at least one model"):
        ModelZoo.from_spec({})


# ---------------------------------------------------------------------------
# ZooTimeModel: blend + for_model dispatch
# ---------------------------------------------------------------------------

def test_blend_is_per_bucket_stage_worst_case():
    zoo = ModelZoo.from_spec(ZOO)
    tm = zoo.time_model
    llm, vis = tm.for_model("llm"), tm.for_model("vision")
    for b in tm.buckets:
        for s in range(tm.num_stages):
            assert tm.wcet(s, b) == max(llm.wcet(s, b), vis.wcet(s, b))
            # llm dominates vision stage-for-stage, so the blend IS llm
            assert tm.wcet(s, b) == llm.wcet(s, b)
    assert vis.wcet(0, 1) == VISION_TIMES[0]
    with pytest.raises(KeyError, match="unknown zoo model"):
        tm.for_model("nope")
    with pytest.raises(KeyError, match="unknown zoo model"):
        zoo.model("nope")


def test_single_member_blend_is_the_member():
    zoo = ModelZoo.from_spec(
        {"m": {"stage_times": list(LLM_TIMES), "buckets": [1, 2, 4],
               "marginal": 0.25}})
    tm = zoo.time_model
    member = tm.for_model("m")
    assert tm.buckets == member.buckets
    assert tm.times == member.times


def test_blend_spans_models_with_different_depths():
    zoo = ModelZoo.from_spec(
        {"short": {"stage_times": [0.010, 0.020]},
         "deep": {"stage_times": [0.004, 0.005, 0.006]}})
    tm = zoo.time_model
    assert tm.num_stages == 3
    # stage 2 exists only in "deep": the blend carries its row unmaxed
    assert tm.wcet(2, 1) == tm.for_model("deep").wcet(2, 1)
    assert tm.wcet(0, 1) == 0.010


# ---------------------------------------------------------------------------
# StageBatcher: model-aware seating + leader-model pricing
# ---------------------------------------------------------------------------

def test_batcher_seats_same_model_only():
    tm = ModelZoo.from_spec(ZOO).time_model
    batcher = StageBatcher(tm)
    leader = mk_task(1.0, LLM_TIMES, model="llm")
    cands = [mk_task(1.0, LLM_TIMES, model="llm"),
             mk_task(1.0, VISION_TIMES, model="vision"),
             mk_task(1.0, VISION_TIMES, model="vision")]
    batch = batcher.form(leader, cands, 0.0)
    assert len(batch) == 2
    assert all(t.model == "llm" for t in batch)


def test_batcher_prices_with_leaders_model_not_the_blend():
    tm = ModelZoo.from_spec(ZOO).time_model
    batcher = StageBatcher(tm)
    w_vis = tm.for_model("vision").wcet(0, 2)
    w_blend = tm.wcet(0, 2)
    assert w_blend > w_vis                    # the test is only meaningful so
    now, d = 0.0, w_vis + 1e-6                # fits vision pair, not blend pair
    leader = mk_task(d, VISION_TIMES, model="vision")
    mate = mk_task(d, VISION_TIMES, model="vision")
    batch = batcher.form(leader, [mate], now)
    assert len(batch) == 2                    # priced by vision's own table
    assert not leader.fits_batch(now, w_blend)


# ---------------------------------------------------------------------------
# zoo admission control
# ---------------------------------------------------------------------------

def test_zoo_admission_prices_each_model_by_its_own_table():
    tm = ModelZoo.from_spec(ZOO).time_model
    adm = ZooAdmissionController(tm, mode="reject")
    # a deadline between the two models' mandatory solo costs
    d = (VISION_TIMES[0] + LLM_TIMES[0]) / 2
    vis = mk_task(d, VISION_TIMES, model="vision")
    llm = mk_task(d, LLM_TIMES, model="llm")
    assert adm.decide([], vis, 0.0).admitted
    dec = adm.decide([], llm, 0.0)
    assert not dec.admitted and dec.reason == "mandatory-infeasible"
    # the model-blind controller prices everyone at the blend: it would
    # wrongly reject the cheap vision request too
    blind = AdmissionController(tm, mode="reject")
    assert not blind.decide([], vis, 0.0).admitted
    # a model-less task falls back to the blended worst case
    anon = mk_task(d, VISION_TIMES)
    assert not adm.decide([], anon, 0.0).admitted


# ---------------------------------------------------------------------------
# rtdeepiot-zoo policy: scope semantics
# ---------------------------------------------------------------------------

def test_zoo_policy_rejects_unknown_scope():
    pred = make_predictor("exp", prior_curve=PRIOR)
    with pytest.raises(ValueError, match="scope"):
        ZooRTDeepIoT(pred, scope="bogus")


def test_siloed_scope_plans_each_model_partition_separately():
    pred = make_predictor("exp", prior_curve=PRIOR)
    pol = ZooRTDeepIoT(pred, scope="siloed")
    active = [mk_task(1.0, LLM_TIMES, model="llm"),
              mk_task(1.0, VISION_TIMES, model="vision"),
              mk_task(1.0, VISION_TIMES)]          # model-less partition
    pol._replan(active, 0.0)
    assert set(pol._planners) == {"llm", "vision", None}
    assert all(t.assigned_depth == t.num_stages for t in active)
    glob = ZooRTDeepIoT(pred, scope="global")
    glob._replan(active, 0.0)
    assert glob._planners == {}                    # one joint FPTAS plan


# ---------------------------------------------------------------------------
# end to end: mixed-model overload, global vs siloed
# ---------------------------------------------------------------------------

def _weighted_admitted_acc(res, tables):
    num = den = 0.0
    for r in res.per_request:
        if r["rejected"]:
            continue
        w = float(r["weight"])
        den += w
        ok = (not r["missed"]) and r["depth"] >= 1 and bool(
            tables[r["model"]]["correct"][r["sample"], r["depth"] - 1])
        num += w * float(ok)
    return num / den if den else 0.0


def test_model_mix_global_shedding_beats_siloed():
    tables = zoo_tables()
    results = {}
    for scope in ("global", "siloed"):
        spec = dataclasses.replace(
            scenario_spec("model-mix", policy="rtdeepiot-zoo",
                          policy_args={"predictor": "exp", "scope": scope},
                          admission={"mode": "reject"},
                          stage_times=MIX_STAGE_TIMES, n_requests=120,
                          seed=0, models=ZOO),
            executor="zoo-oracle")
        results[scope] = Service.from_spec(
            spec, zoo_tables=tables,
            n_samples=tables["llm"]["conf"].shape[0]).run()
    for res in results.values():
        assert set(res.per_model) == {"llm", "vision"}
        assert sum(m["n"] for m in res.per_model.values()) == res.n_requests
        for row in res.per_model.values():
            assert row["weighted_accuracy"] is not None
            assert 0.0 <= row["weighted_accuracy"] <= 1.0
    g = _weighted_admitted_acc(results["global"], tables)
    s = _weighted_admitted_acc(results["siloed"], tables)
    assert g >= s - 1e-9, (g, s)
    assert results["global"].admitted_miss_rate \
        <= results["siloed"].admitted_miss_rate + 1e-9


# ---------------------------------------------------------------------------
# single-member zoo == the plain oracle path, bit for bit
# ---------------------------------------------------------------------------

def test_single_model_zoo_matches_plain_oracle_bitwise():
    # the reference is the weighted scheduler: ZooRTDeepIoT extends it, so
    # at scope="global" with one model the plans must coincide exactly
    rng = np.random.default_rng(11)
    conf = np.sort(rng.uniform(0.3, 1.0, (160, 3)), axis=1)
    correct = rng.uniform(size=(160, 3)) < conf
    st = (0.004, 0.007, 0.010)
    batching = {"buckets": [1, 2, 4], "stage_times": list(st),
                "marginal": 0.25}
    base = dataclasses.replace(
        scenario_spec("steady", policy="rtdeepiot-weighted",
                      policy_args={"predictor": "exp", "prior_curve": PRIOR},
                      stage_times=st, n_requests=60, seed=5),
        batching=batching)
    zspec = dataclasses.replace(
        base, executor="zoo-oracle", policy="rtdeepiot-zoo",
        models={"m": {"stage_times": list(st), "buckets": [1, 2, 4],
                      "marginal": 0.25, "utility": PRIOR}},
        source_args={**base.source_args,
                     "mix": [dict(c, model="m")
                             for c in base.source_args["mix"]]})
    res_base = Service.from_spec(base, conf_table=conf,
                                 correct_table=correct,
                                 n_samples=len(conf)).run()
    res_zoo = Service.from_spec(
        zspec, zoo_tables={"m": {"conf": conf, "correct": correct}},
        n_samples=len(conf)).run()

    def key(res):
        return [(r["sample"], r["depth"], r["conf"], r["missed"],
                 r["rejected"]) for r in res.per_request]
    assert key(res_zoo) == key(res_base)
    assert res_base.per_model == {}
    assert set(res_zoo.per_model) == {"m"}
    assert res_zoo.per_model["m"]["n"] == res_zoo.n_requests


# ---------------------------------------------------------------------------
# live-path fail-fast
# ---------------------------------------------------------------------------

def test_submit_rejects_unknown_zoo_model():
    spec = ServeSpec(policy="edf", executor="zoo-oracle", clock="virtual",
                     source="live",
                     batching={"mode": "none",
                               "stage_times": list(VISION_TIMES)},
                     models=ZOO)
    svc = Service.from_spec(spec, zoo_tables=zoo_tables())
    try:
        with pytest.raises(ValueError, match="unknown model 'nope'"):
            svc.submit(Request(inputs=None, sample=0, rel_deadline=1.0,
                               model="nope"))
        # a defined model is accepted (buffered until drain)
        svc.submit(Request(inputs=None, sample=1, rel_deadline=1.0,
                           model="vision"))
    finally:
        svc.close()
