"""Distributed-correctness tests.

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must not leak into this test process, per the assignment).  Each
script asserts that the sharded/shard_map implementation matches the
single-device reference numerically.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices_script(body: str, timeout=420):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        if not hasattr(jax, "set_mesh"):
            # jax < 0.6 compat: Mesh is itself the context manager
            jax.set_mesh = lambda m: m
        mesh = make_debug_mesh(2, 4)   # ('data' 2, 'model' 4)
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_flash_decode_matches_single_device():
    run_devices_script("""
        from repro.models.flash_decode import flash_decode, _partial_attend
        from repro.models.common import ParallelCtx
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        B, KV, G, S, hd = 4, 2, 3, 64, 16
        q = jax.random.normal(ks[0], (B, KV, G, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        slot_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        cur = jnp.array([10, 30, 50, 63])
        ref = flash_decode(q, k, v, slot_pos, cur, window=None,
                           softmax_scale=hd**-0.5, ctx=None)
        ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model",
                          seq_axes=("model",))
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: flash_decode(
                *a, window=None, softmax_scale=hd**-0.5, ctx=ctx))(
                q, k, v, slot_pos, cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        # windowed variant too
        ref_w = flash_decode(q, k, v, slot_pos, cur, window=16,
                             softmax_scale=hd**-0.5, ctx=None)
        with jax.set_mesh(mesh):
            out_w = jax.jit(lambda *a: flash_decode(
                *a, window=16, softmax_scale=hd**-0.5, ctx=ctx))(
                q, k, v, slot_pos, cur)
        np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref_w),
                                   rtol=2e-5, atol=2e-5)
        print("flash_decode distributed OK")
    """)


def test_moe_alltoall_matches_gather():
    run_devices_script("""
        import dataclasses
        from repro.configs import get_config
        from repro.configs.base import MoEConfig
        from repro.models import moe as moe_mod
        from repro.models.common import ParallelCtx
        cfg = get_config("qwen3-4b").reduced()
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=64.0))
        params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
        T, d = 32, cfg.d_model
        h = jax.random.normal(jax.random.PRNGKey(1), (T, d))
        y_ref, aux_ref = moe_mod.moe_gather(cfg, params, h, None)
        ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model",
                          seq_axes=("model",), moe_impl="alltoall")
        with jax.set_mesh(mesh):
            y, aux = jax.jit(lambda p, x: moe_mod.moe_alltoall(
                cfg, p, x, ctx))(params, h)
        # identical routing + huge capacity => identical outputs
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        print("moe alltoall == gather OK")
    """)


def test_sharded_train_step_matches_single_device():
    """One training step of the reduced qwen3 on the debug mesh must equal
    the unsharded step (same loss, same updated params)."""
    run_devices_script("""
        from repro.configs import get_config
        from repro.launch import steps as S
        from repro.launch.shardings import (batch_shardings, opt_shardings,
                                            param_shardings)
        from repro.models import init_params
        from repro.configs.shapes import InputShape
        import dataclasses
        cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                                  dtype="float32")
        shape = InputShape("t", 32, 8, "train")
        ctx = S.make_ctx(mesh, shape, multi_pod=False)
        step, opt = S.make_train_step_fn(cfg, ctx, q_chunk=32)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {"inputs": {"tokens": toks}, "labels": jnp.roll(toks, -1, 1)}
        # reference: no ctx, no mesh
        step_ref, _ = S.make_train_step_fn(cfg, dataclasses.replace(
            ctx, mesh=None) if False else ctx, q_chunk=32)
        from repro.training.loop import make_loss_fn
        loss_ref = make_loss_fn(cfg, ctx=None, q_chunk=32)(params, batch)
        with jax.set_mesh(mesh):
            p_sh = param_shardings(mesh, params)
            o_sh = opt_shardings(mesh, opt_state)
            b_sh = {"inputs": batch_shardings(mesh, batch["inputs"], ctx.dp),
                    "labels": batch_shardings(mesh, {"l": batch["labels"]},
                                              ctx.dp)["l"]}
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
            p2, o2, loss = fn(params, opt_state, batch)
        np.testing.assert_allclose(float(loss), float(loss_ref),
                                   rtol=2e-4, atol=2e-4)
        print("sharded train step OK, loss", float(loss))
    """)


def test_prefill_step_lowers_on_debug_mesh():
    run_devices_script("""
        from repro.configs import get_config
        from repro.configs.shapes import InputShape
        from repro.launch import steps as S
        from repro.launch.shardings import batch_shardings, param_shardings
        cfg = get_config("gemma3-4b").reduced()
        shape = InputShape("p", 128, 8, "prefill")
        ctx = S.make_ctx(mesh, shape, multi_pod=False)
        step = S.make_prefill_step_fn(cfg, ctx, q_chunk=64)
        params = S.abstract_params(cfg)
        specs = S.input_specs(cfg, shape)
        with jax.set_mesh(mesh):
            p_sh = param_shardings(mesh, params)
            b_sh = batch_shardings(mesh, specs["inputs"], ctx.dp)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                params, specs["inputs"])
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        print("prefill lowering OK")
    """)


@pytest.mark.slow
def test_dryrun_subprocess_one_combo():
    """The real 512-device dry-run entrypoint on one cheap combo."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-4b",
         "--shape", "decode_32k", "--mesh", "single", "--no-probes",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "0 failures" in r.stdout
