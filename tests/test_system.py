"""End-to-end behaviour tests for the paper's system: train a tiny anytime
model, verify confidence/utility structure, and validate the headline
scheduling claim (RTDeepIoT >= baselines) on the resulting oracle tables."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (EDF, LCF, RR, RTDeepIoT, Workload, make_predictor,
                        simulate)
from repro.models import init_params
from repro.training import (AdamW, DifficultyDataset, eval_exit_metrics,
                            make_train_step, warmup_cosine)


@pytest.fixture(scope="module")
def trained():
    """A quickly-trained anytime classifier + its oracle tables."""
    cfg = get_config("anytime-classifier")
    ds = DifficultyDataset(num_classes=cfg.vocab_size, seed=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, 250))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, exit_weights=(0.2, 0.3, 0.5)))
    for i in range(250):
        b = ds.sample(128, seed=50_000 + i)
        params, opt_state, m = step(params, opt_state,
                                    {"inputs": b["inputs"],
                                     "labels": b["labels"]})
    test = ds.sample(600, seed=123_456)
    metrics = eval_exit_metrics(cfg, params, test)
    return cfg, params, test, metrics


def test_training_learns_task(trained):
    _, _, _, m = trained
    assert m["correct"][:, -1].mean() > 0.35      # >> 10% chance


def test_confidence_correlates_with_correctness(trained):
    """The utility metric must be informative: mean confidence of correct
    predictions exceeds that of incorrect ones at every stage."""
    _, _, _, m = trained
    for s in range(m["correct"].shape[1]):
        c, conf = m["correct"][:, s], m["confidence"][:, s]
        if c.all() or (~c).any() is False:
            continue
        assert conf[c].mean() > conf[~c].mean() + 0.02


def test_difficulty_drives_depth_utility(trained):
    """Easy samples (short chains) are solved earlier than hard ones —
    the paper's core data-dependence premise."""
    _, _, test, m = trained
    easy = test["difficulty"] <= 2
    hard = test["difficulty"] >= 7
    # stage-1 accuracy gap between easy and hard inputs
    assert m["correct"][easy, 0].mean() > m["correct"][hard, 0].mean() + 0.1


def test_rtdeepiot_dominates_baselines_on_trained_tables(trained):
    _, _, _, m = trained
    conf, correct = m["confidence"], m["correct"]
    wl = Workload(n_clients=20, d_lo=0.01, d_hi=0.2, n_requests=400)
    times = (0.007, 0.007, 0.007)
    accs = {}
    for name, pol in [
        ("rtdeepiot", RTDeepIoT(make_predictor("exp",
                                               prior_curve=conf.mean(0)))),
        ("edf", EDF()), ("lcf", LCF()), ("rr", RR()),
    ]:
        accs[name] = simulate(pol, wl, times, conf, correct).accuracy
    assert accs["rtdeepiot"] >= max(accs["edf"], accs["lcf"],
                                    accs["rr"]) - 1e-9


def test_oracle_tables_artifact_consistency():
    """If the shipped artifact exists it must be structurally valid."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "oracle_tables.npz")
    if not os.path.exists(path):
        pytest.skip("artifact not built yet")
    z = np.load(path)
    conf, correct = z["confidence"], z["correct"]
    assert conf.shape == correct.shape and conf.shape[1] == 3
    assert (conf >= 0).all() and (conf <= 1).all()
    # deeper final stage must beat stage 1 on the shipped model
    assert correct[:, -1].mean() >= correct[:, 0].mean()
